"""Coverage for core/scheduler.py — the heterogeneity/asynchrony simulator
(§3.2/§3.3): event ordering, the α₀/(1+s) staleness rule, round-mask
bucketing, and synchronous-mode round latency."""
import numpy as np
import pytest

from repro.core.scheduler import (
    CloudSpec,
    events_to_round_masks,
    simulate_async_schedule,
    sync_round_time,
)


def _clouds():
    return [
        CloudSpec("aws", speed=1.0, link_latency_s=0.05, link_bandwidth=1e9),
        CloudSpec("gcp", speed=0.5, link_latency_s=0.20, link_bandwidth=5e8),
        CloudSpec("azure", speed=2.0, link_latency_s=0.10, link_bandwidth=2e9),
    ]


class TestAsyncSchedule:
    def test_event_times_non_decreasing(self):
        events = simulate_async_schedule(
            _clouds(), local_steps=4, n_rounds=30, sync_bytes=1e8
        )
        times = [e.time for e in events]
        assert times == sorted(times), "async merges must replay in wall order"

    def test_staleness_alpha_rule(self):
        """α_i(s) = α₀/(1+s) for every event, for two different α₀."""
        for base in (0.5, 0.9):
            events = simulate_async_schedule(
                _clouds(), local_steps=4, n_rounds=25, base_alpha=base
            )
            for e in events:
                assert e.alpha == pytest.approx(base / (1.0 + e.staleness))

    def test_staleness_counts_merges_since_pull(self):
        """With one fast and one slow cloud, the slow cloud's merge sees
        exactly the number of fast merges that landed while it computed."""
        clouds = [CloudSpec("fast", speed=4.0), CloudSpec("slow", speed=1.0)]
        events = simulate_async_schedule(clouds, local_steps=1, n_rounds=10)
        slow_events = [e for e in events if e.cloud == 1]
        assert slow_events, "slow cloud must eventually merge"
        # fast finishes at 0.25, 0.5, 0.75 before slow's 1.0 → staleness 3
        assert slow_events[0].staleness == 3
        fast_first = [e for e in events if e.cloud == 0][0]
        assert fast_first.staleness == 0

    def test_homogeneous_clouds_zero_initial_staleness(self):
        events = simulate_async_schedule(
            [CloudSpec("a"), CloudSpec("b")], local_steps=2, n_rounds=2
        )
        # both finish their first round before either pulls again
        assert {e.staleness for e in events[:2]} <= {0, 1}
        assert events[0].staleness == 0


class TestRoundMasks:
    def test_one_hot_rows_consistent_with_trace(self):
        events = simulate_async_schedule(_clouds(), local_steps=4, n_rounds=20)
        arrived, alphas = events_to_round_masks(events, 3, rounds=20)
        assert arrived.shape == (20, 3) and alphas.shape == (20, 3)
        # each round applies exactly one cloud's update…
        np.testing.assert_array_equal(arrived.sum(axis=1), np.ones(20))
        for k, ev in enumerate(events[:20]):
            assert arrived[k, ev.cloud], "mask row must match the event trace"
            assert alphas[k, ev.cloud] == pytest.approx(ev.alpha)
        # …and alphas vanish exactly where nothing arrived
        assert (alphas[~arrived] == 0).all()

    def test_truncates_to_requested_rounds(self):
        events = simulate_async_schedule(_clouds(), local_steps=4, n_rounds=30)
        arrived, _ = events_to_round_masks(events, 3, rounds=10)
        assert arrived.shape == (10, 3)
        np.testing.assert_array_equal(arrived.sum(axis=1), np.ones(10))


class TestSyncRoundTime:
    def test_slowest_compute_plus_slowest_transfer(self):
        clouds = _clouds()
        local_steps, step_time, sync_bytes = 8, 1.0, 2e9
        t = sync_round_time(clouds, local_steps, step_time, sync_bytes)
        compute = max(local_steps * step_time / c.speed for c in clouds)
        xfer = max(
            c.link_latency_s + sync_bytes / c.link_bandwidth for c in clouds
        )
        assert t == pytest.approx(compute + xfer)
        # the slow straggler (gcp, speed 0.5) dominates compute
        assert t >= 8 / 0.5

    def test_sync_slower_than_fastest_async_merge(self):
        """The async motivation in one assert: the first async merge always
        lands no later than the synchronous barrier round."""
        clouds = _clouds()
        events = simulate_async_schedule(
            clouds, local_steps=8, n_rounds=1, sync_bytes=2e9
        )
        t_sync = sync_round_time(clouds, 8, 1.0, 2e9)
        assert events[0].time <= t_sync
