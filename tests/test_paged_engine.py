"""Paged KV cache engine tests (launch/engine.py, paged_cache=True).

The contract, mirroring the rest of the engine suite: memory layout must be
INVISIBLE in the output. The contiguous-ring engine is the oracle — the
paged engine (shared page pool + per-slot page tables) must emit bitwise
token-identical output on every trace both can serve, through admission,
slot reuse, watermark throttling, OOM preemption + resume, sliding
windows, interleaved prefill, sampling, and the page-table decode kernel.
On top of identity, paged mode must do what rings cannot: serve a request
with ``prompt + gen > max_seq``."""
import jax
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.launch.engine import (
    AdmissionError,
    Request,
    ServeEngine,
    make_requests,
)
from repro.launch.sampling import SamplingParams

ARCH = "stablelm-1.6b"
P, G = 8, 6  # default prompt / generated tokens (ring cap 14)


@pytest.fixture(scope="module")
def model_and_params():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _build(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_seq", P + G)
    return ServeEngine(model, params, **kw)


def _reqs(cfg, lens, *, gen=G, uid0=0, seed=0):
    base = make_requests(
        cfg, n_requests=len(lens), prompt_len=max(lens), gen_tokens=gen,
        seed=seed,
    )
    return [
        Request(uid=uid0 + j, prompt=r.prompt[: lens[j]], max_new_tokens=gen)
        for j, r in enumerate(base)
    ]


def _assert_same_tokens(a, b):
    ref = {o.uid: o.tokens for o in b}
    assert len(a) == len(b)
    for o in a:
        assert o.tokens == ref[o.uid], (
            f"uid {o.uid}: {o.tokens} != {ref[o.uid]}"
        )


# ------------------------------------------------------- bitwise ring oracle
@pytest.mark.parametrize("page_size", [2, 4, 16])
def test_paged_matches_ring_bitwise(model_and_params, page_size):
    """Mixed prompt lengths + slot backfill: paged == ring token-for-token.
    The jnp paged read gathers pages then runs the ring math verbatim, so
    this holds BITWISE at any page size, including one larger than most
    prompts."""
    cfg, _, _ = model_and_params
    lens = [4, 8, 3, 7, 6]
    ring = _build(model_and_params).run(_reqs(cfg, lens))
    paged = _build(model_and_params, paged_cache=True, page_size=page_size)
    _assert_same_tokens(paged.run(_reqs(cfg, lens)), ring)


def test_identity_pool_size_is_ring_equivalent(model_and_params):
    """Auto pool (num_pages=0) sizes to ring-equivalent capacity: same KV
    budget as the rings it replaces, identical tokens — the degenerate
    page-table configuration reproducing today's engine."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, paged_cache=True, page_size=4)
    assert engine.pool.capacity == 2 * -(-(P + G) // 4)
    ring = _build(model_and_params).run(_reqs(cfg, [P] * 4))
    _assert_same_tokens(engine.run(_reqs(cfg, [P] * 4)), ring)
    assert engine.pool.in_use == 0  # every page returned on retirement


def test_placement_invariance(model_and_params):
    """The same trace with every physical page SHIFTED (a bystander holds
    the low pages, and the pool/table are wider): tokens must not move, on
    both the jnp gather path and the table kernel. This is the degenerate-
    vs-scattered page-table equivalence at engine level."""
    cfg, _, _ = model_and_params
    lens = [5, 8, 6]
    for kernel in (False, True):
        a = _build(
            model_and_params, paged_cache=True, page_size=4, use_kernel=kernel
        ).run(_reqs(cfg, lens))
        shifted = _build(
            model_and_params, paged_cache=True, page_size=4, num_pages=31,
            use_kernel=kernel,
        )
        held = shifted.pool.alloc(7)  # push all real allocations up 7 pages
        b = shifted.run(_reqs(cfg, lens))
        shifted.pool.free(held)
        _assert_same_tokens(a, b)


def test_windowed_paged_matches_windowed_ring(model_and_params):
    """Sliding window smaller than the prompt: prefill wraps each slot's
    logical ring across page boundaries."""
    cfg, _, _ = model_and_params
    w = 6
    lens = [P, 5, P, 7]
    ring = _build(model_and_params, window=w).run(_reqs(cfg, lens))
    paged = _build(model_and_params, window=w, paged_cache=True, page_size=2)
    assert paged.cap == w  # logical ring == window, split into pages
    _assert_same_tokens(paged.run(_reqs(cfg, lens)), ring)


def test_interleaved_paged_matches_ring(model_and_params):
    cfg, _, _ = model_and_params
    lens = [P, 4, 6, 5]
    ring = _build(model_and_params, prefill="interleaved").run(_reqs(cfg, lens))
    paged = _build(
        model_and_params, prefill="interleaved", paged_cache=True, page_size=4
    )
    _assert_same_tokens(paged.run(_reqs(cfg, lens)), ring)


def test_per_request_prefill_paged_matches_ring(model_and_params):
    """batch_prefill=False in paged mode routes through width-1
    prefill_slots (prefill_into_slot is ring-only) — same tokens, one
    dispatch per request."""
    cfg, _, _ = model_and_params
    lens = [5, 8, 3]
    ring = _build(model_and_params, batch_prefill=False).run(_reqs(cfg, lens))
    paged = _build(
        model_and_params, batch_prefill=False, paged_cache=True, page_size=4
    )
    outs = paged.run(_reqs(cfg, lens))
    assert paged.prefill_dispatches == len(lens)
    _assert_same_tokens(outs, ring)


@given(
    lens=st.lists(st.integers(2, P), min_size=1, max_size=6),
    page_size=st.sampled_from([2, 4, 8]),
)
@settings(max_examples=10, deadline=None)
def test_property_paged_bitwise_identical(model_and_params, lens, page_size):
    """Any trace that fits both engines: paged output is bitwise identical
    to the ring engine (shared-feasible traces, arbitrary page size)."""
    cfg, _, _ = model_and_params
    ring = _build(model_and_params).run(_reqs(cfg, lens, gen=3))
    paged = _build(model_and_params, paged_cache=True, page_size=page_size)
    _assert_same_tokens(paged.run(_reqs(cfg, lens, gen=3)), ring)


# --------------------------------------------------- beyond ring capacity
def test_oversubscribed_length_served_ring_rejects(model_and_params):
    """The acceptance case: prompt + gen > max_seq is a structured
    rejection in ring mode but serves fine from the paged pool, where a
    sequence is bounded by pool pages, not slot capacity. Tokens pinned
    against a ring engine with a large-enough max_seq."""
    cfg, _, _ = model_and_params
    big_gen = G + 10  # P + G + 10 == 24 > max_seq == 14
    big = lambda: _reqs(cfg, [P], gen=big_gen)

    ring = _build(model_and_params)
    with pytest.raises(AdmissionError, match="exceeds max_seq") as ei:
        ring.submit(big()[0])
    assert ei.value.reason == "exceeds_max_seq" and ei.value.uid == 0

    paged = _build(model_and_params, paged_cache=True, page_size=4)
    assert paged.cap > P + big_gen  # whole-pool logical capacity
    outs = paged.run(big())
    assert len(outs[0].tokens) == big_gen
    oracle = _build(model_and_params, max_seq=P + big_gen).run(big())
    _assert_same_tokens(outs, oracle)


def test_mixed_oversized_and_regular_share_pool(model_and_params):
    """An oversized request decodes alongside regular ones in the shared
    pool; each request matches its own feasible-ring oracle."""
    cfg, _, _ = model_and_params
    gens = [G + 10, G, G]
    base = _reqs(cfg, [P, P, P], gen=max(gens))
    reqs = lambda: [
        Request(uid=r.uid, prompt=r.prompt, max_new_tokens=gens[r.uid])
        for r in base
    ]
    paged = _build(model_and_params, paged_cache=True, page_size=4)
    outs = paged.run(reqs())
    oracle = _build(model_and_params, max_seq=P + max(gens)).run(reqs())
    _assert_same_tokens(outs, oracle)


# ------------------------------------------------- OOM preemption + resume
def test_oom_preempts_youngest_and_resumes_token_identical(model_and_params):
    """A pool too small for two full sequences: decode OOM preempts the
    youngest slot back to the waiting queue; its re-admission re-prefills
    prompt + generated and continues bit-exactly."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7]
    ample = _build(model_and_params, paged_cache=True, page_size=4)
    ref = ample.run(_reqs(cfg, lens))
    assert ample.preemptions == 0
    tight = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=6
    )  # 5 allocatable pages = 20 tokens for sequences needing 14 each
    outs = tight.run(_reqs(cfg, lens))
    assert tight.preemptions > 0, "tight pool must preempt"
    _assert_same_tokens(outs, ref)
    assert tight.pool.in_use == 0
    # the preempted request visited more than one slot epoch
    assert any(len(h) > 1 for h in tight.slot_history.values())


def test_preemption_preserves_sampling_streams(model_and_params):
    """Preemption must not replay or skip PRNG draws: sampled output under
    a preempting pool equals the ample-pool run stream-for-stream."""
    cfg, _, _ = model_and_params
    lens = [P, P, 6]

    def reqs():
        rs = _reqs(cfg, lens)
        for r in rs:
            r.sampling = SamplingParams(
                temperature=0.9, top_k=7, seed=100 + r.uid
            )
        return rs

    ref = _build(model_and_params, paged_cache=True, page_size=4).run(reqs())
    tight = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=6
    )
    outs = tight.run(reqs())
    assert tight.preemptions > 0
    _assert_same_tokens(outs, ref)


def test_interleaved_preemption_token_identical(model_and_params):
    """Interleaved prefill allocates pages lazily per teacher-forced step;
    preemption can strike mid-prompt and must still resume exactly."""
    cfg, _, _ = model_and_params
    lens = [P, P, 5]
    ref = _build(
        model_and_params, prefill="interleaved", paged_cache=True, page_size=4
    ).run(_reqs(cfg, lens))
    tight = _build(
        model_and_params, prefill="interleaved", paged_cache=True,
        page_size=4, num_pages=6,
    )
    outs = tight.run(_reqs(cfg, lens))
    _assert_same_tokens(outs, ref)


def test_watermark_throttles_admission(model_and_params):
    """watermark_pages holds back admissions while other slots are live,
    trading concurrency for fewer preemptions — output unchanged."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7]
    ref = _build(model_and_params, paged_cache=True, page_size=4).run(
        _reqs(cfg, lens)
    )
    throttled = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=8,
        watermark_pages=2,
    )
    outs = throttled.run(_reqs(cfg, lens))
    _assert_same_tokens(outs, ref)
    assert throttled.pool.in_use == 0


# ------------------------------------------------------ page-table kernel
def test_kernel_paged_engine_matches_ring_kernel_engine(model_and_params):
    """With page_size == the ring kernel's chunk (== ring cap here), the
    table kernel streams identical chunks in identical order — engine
    output is bitwise equal to the ring engine under the same kernel."""
    cfg, _, _ = model_and_params
    lens = [P, 5, 7, 6]
    ring = _build(model_and_params, use_kernel=True).run(_reqs(cfg, lens))
    paged = _build(
        model_and_params, use_kernel=True, paged_cache=True, page_size=P + G
    )
    _assert_same_tokens(paged.run(_reqs(cfg, lens)), ring)


def test_kernel_preemption_token_identical(model_and_params):
    cfg, _, _ = model_and_params
    lens = [P, P, 6]
    ref = _build(
        model_and_params, use_kernel=True, paged_cache=True, page_size=4
    ).run(_reqs(cfg, lens))
    tight = _build(
        model_and_params, use_kernel=True, paged_cache=True, page_size=4,
        num_pages=6,
    )
    outs = tight.run(_reqs(cfg, lens))
    assert tight.preemptions > 0
    _assert_same_tokens(outs, ref)


def test_kernel_windowed_paged_matches_ring_kernel(model_and_params):
    """Windowed, wrapping paged cache through the table kernel: with
    page_size == window the table kernel streams the one logical page the
    ring kernel streams as its one chunk — engine output is bitwise equal
    to the windowed ring engine under the same kernel. (Comparing kernel
    against the jnp path instead is only ~allclose in bf16 — online
    softmax reassociates — so the deterministic pin is kernel-vs-kernel.)"""
    cfg, _, _ = model_and_params
    w = 6
    lens = [P, 5, P, 7]
    ring = _build(model_and_params, window=w, use_kernel=True).run(
        _reqs(cfg, lens)
    )
    paged = _build(
        model_and_params, window=w, use_kernel=True, paged_cache=True,
        page_size=w,
    )
    _assert_same_tokens(paged.run(_reqs(cfg, lens)), ring)


def test_kernel_windowed_placement_invariance(model_and_params):
    """Windowed table kernel at sub-window page size: physical placement
    (different pool sizes) must be bitwise invisible even while the
    logical ring wraps across page boundaries every ``window`` tokens."""
    cfg, _, _ = model_and_params
    lens = [P, 5, P, 7]
    a = _build(
        model_and_params, window=6, use_kernel=True, paged_cache=True,
        page_size=2,
    ).run(_reqs(cfg, lens))
    shifted = _build(
        model_and_params, window=6, use_kernel=True, paged_cache=True,
        page_size=2, num_pages=17,
    )
    held = shifted.pool.alloc(5)  # different physical homes for every page
    b = shifted.run(_reqs(cfg, lens))
    shifted.pool.free(held)
    _assert_same_tokens(a, b)


# ------------------------------------------------- structured admission
def test_submit_rejection_is_structured_and_does_not_wedge(model_and_params):
    """An oversized submit raises AdmissionError (uid + reason attached)
    WITHOUT entering the queue; the engine then serves later requests
    normally — the scheduling round can never wedge on a doomed request."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params)
    with pytest.raises(AdmissionError) as ei:
        engine.submit(
            Request(uid=99, prompt=np.zeros(P, np.int32), max_new_tokens=G + 1)
        )
    assert ei.value.uid == 99
    assert ei.value.reason == "exceeds_max_seq"
    assert isinstance(ei.value, ValueError)  # legacy handler compatibility
    assert len(engine.waiting) == 0
    ring = _build(model_and_params).run(_reqs(cfg, [P, 5]))
    outs = engine.run(_reqs(cfg, [P, 5]))
    _assert_same_tokens(outs, ring)


def test_paged_submit_rejects_beyond_pool(model_and_params):
    """Paged mode still rejects what the POOL can never hold — with its
    own structured reason."""
    cfg, _, _ = model_and_params
    engine = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=4
    )  # cap = 3 pages × 4 = 12 tokens
    with pytest.raises(AdmissionError, match="pool capacity") as ei:
        engine.submit(
            Request(uid=7, prompt=np.zeros(P, np.int32), max_new_tokens=5)
        )
    assert ei.value.reason == "exceeds_pool" and ei.value.uid == 7
    # a fitting request still serves
    outs = engine.run(_reqs(cfg, [4], gen=4))
    assert len(outs[0].tokens) == 4


def test_submit_rejects_prompt_pool_can_never_hold(model_and_params):
    """Regression: a request within LOGICAL table capacity but whose pages
    can never all be physically resident (tight pool) used to wait at the
    queue head forever — alloc kept returning None while admission clamped
    to table_width. It must be a structured submit-time rejection, and the
    engine must keep serving."""
    cfg, _, _ = model_and_params
    engine = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=5
    )  # 4 allocatable pages = 16 resident tokens; logical cap is wider
    assert engine.cap > engine.pool.capacity * engine.page_size
    doomed = Request(
        uid=13, prompt=np.zeros(15, np.int32), max_new_tokens=3
    )  # 18 tokens <= cap, but ceil(18/4) = 5 pages > 4 allocatable
    with pytest.raises(AdmissionError, match="pool capacity") as ei:
        engine.submit(doomed)
    assert ei.value.reason == "exceeds_pool" and ei.value.uid == 13
    assert len(engine.waiting) == 0
    ring = _build(model_and_params).run(_reqs(cfg, [5, 4], gen=3))
    outs = engine.run(_reqs(cfg, [5, 4], gen=3))
    _assert_same_tokens(outs, ring)


def test_default_table_width_is_ring_equivalent(model_and_params):
    """Windowless table width defaults to num_slots × pages_per_ring (the
    jnp gather/attend work the ring engine paid), NOT the whole pool — an
    oversized pool must not widen every slot's logical ring. Whole-pool
    width is the ``long_requests`` / ``table_width=`` opt-in."""
    cfg, _, _ = model_and_params
    ppr = -(-(P + G) // 4)
    bounded = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=31
    )
    assert bounded.table_width == 2 * ppr  # not 30
    wide = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=31,
        long_requests=True,
    )
    assert wide.table_width == 30
    explicit = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=31,
        table_width=12,
    )
    assert explicit.table_width == 12 and explicit.cap == 48
    # same tokens at every width on a shared-feasible trace
    lens = [5, 8, 6]
    ring = _build(model_and_params).run(_reqs(cfg, lens))
    for eng in (bounded, wide, explicit):
        _assert_same_tokens(eng.run(_reqs(cfg, lens)), ring)


# ------------------------------------------------------------- bookkeeping
def test_pool_stats_and_occupancy_trace(model_and_params):
    cfg, _, _ = model_and_params
    ring = _build(model_and_params)
    assert ring.pool_stats is None
    engine = _build(model_and_params, paged_cache=True, page_size=4)
    engine.run(_reqs(cfg, [P, P, 5]))
    stats = engine.pool_stats
    assert stats["pages_in_use"] == 0
    assert stats["peak_pages_in_use"] > 0
    assert 0.0 < stats["occupancy_max"] <= 1.0
    assert len(engine.occupancy) == engine.steps
    engine.reset_metrics()
    assert engine.pool_stats["occupancy_max"] == 0.0
    assert engine.occupancy == []


def test_paged_cache_specs_shapes(model_and_params):
    """The dry-run spec helper mirrors the paged pool layout without
    allocating: KV bytes scale with num_pages, not num_slots × max_seq."""
    from repro.launch.specs import paged_cache_specs
    from repro.models import build_model

    cfg, model, _ = model_and_params
    specs = paged_cache_specs(
        model, num_slots=3, num_pages=9, page_size=4, table_width=8
    )
    assert specs["pos"].shape == (3,)
    assert specs["table"].shape == (3, 8)
    assert specs["k"].shape == (
        cfg.n_layers, 9, 4, cfg.n_kv_heads, cfg.resolved_head_dim
    )
    ssm = build_model(get_smoke_config("xlstm-125m"))
    with pytest.raises(ValueError, match="no paged-cache API"):
        paged_cache_specs(ssm, num_slots=2, num_pages=5, page_size=4,
                          table_width=4)


def test_retired_slot_drift_is_harmless(model_and_params):
    """After a slot retires, its device ``pos`` keeps advancing inside the
    jitted step while its table row points at the scratch page — live
    slots' pages must never be touched (pinned by serving a long request
    next to repeatedly retiring short ones)."""
    cfg, _, _ = model_and_params
    lens = [P, 3, 3, 3, 3]
    gens = [G + 8, 1, 1, 1, 1]  # slot 0 long-lived, slot 1 churns
    base = _reqs(cfg, lens, gen=max(gens))
    reqs = lambda: [
        Request(uid=r.uid, prompt=r.prompt[: lens[r.uid]],
                max_new_tokens=gens[r.uid])
        for r in base
    ]
    paged = _build(model_and_params, paged_cache=True, page_size=4)
    outs = paged.run(reqs())
    oracle = _build(model_and_params, max_seq=P + max(gens)).run(reqs())
    _assert_same_tokens(outs, oracle)


# ------------------------------------------------------ SLO-aware preemption
def test_priority_overrides_youngest_preemption(model_and_params):
    """Decode-OOM victim selection is lowest-priority-then-youngest: a
    LOW-priority OLD slot is preempted before a default-priority younger
    one (pre-SLO behavior picked the youngest unconditionally) — and the
    preempted request still resumes token-identically."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7]
    ample = _build(model_and_params, paged_cache=True, page_size=4)
    ref = ample.run(_reqs(cfg, lens))

    def reqs_with_prio():
        rs = _reqs(cfg, lens)
        rs[0].priority = -1  # oldest slot, but lowest priority
        return rs

    tight = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=6
    )
    outs = tight.run(reqs_with_prio())
    assert tight.preemptions > 0, "tight pool must preempt"
    _assert_same_tokens(outs, ref)
    # uid0 (not the youngest) paid the preemptions: it re-admitted at
    # least once, while the default-priority slots never did
    assert len(tight.slot_history[0]) > 1
    assert all(len(tight.slot_history[u]) == 1 for u in (1, 2))


def test_equal_priorities_preempt_youngest_as_before(model_and_params):
    """All-default-priority traffic must reproduce the pre-SLO victim
    order exactly: the YOUNGEST slot is preempted, never an older one."""
    cfg, _, _ = model_and_params
    lens = [P, P]
    tight = _build(
        model_and_params, paged_cache=True, page_size=4, num_pages=6
    )
    outs = tight.run(_reqs(cfg, lens))
    assert tight.preemptions > 0
    assert len(tight.slot_history[1]) > 1, "youngest must be the victim"
    assert len(tight.slot_history[0]) == 1
    ample = _build(model_and_params, paged_cache=True, page_size=4)
    _assert_same_tokens(outs, ample.run(_reqs(cfg, lens)))


# ----------------------------------------------------- migration export/import
def test_export_import_mid_decode_token_identical(model_and_params):
    """The failover primitive: strip a half-served engine's in-flight
    population (live slots + queue) and adopt it on a fresh engine; the
    merged outputs equal an uninterrupted single-engine run."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7, 6]
    ref = _build(model_and_params, paged_cache=True, page_size=4).run(
        _reqs(cfg, lens)
    )
    a = _build(model_and_params, paged_cache=True, page_size=4)
    for r in _reqs(cfg, lens):
        a.submit(r)
    early = []
    for _ in range(3):  # mid-decode: slots live, queue non-empty
        early += a.step()
    items = a.export_inflight()
    assert items and not a.has_work, "export must strip everything"
    assert a.pool.in_use - (
        0 if a.prefix is None else a.prefix.size
    ) == 0, "exported slots must release their pages"
    b = _build(model_and_params, paged_cache=True, page_size=4)
    b.import_inflight(items)
    outs = early + b.run()
    _assert_same_tokens(outs, ref)


def test_export_import_sampled_streams_continue(model_and_params):
    """Migration re-enters via the resume path: per-request PRNG streams
    continue where they stopped — no draw replayed or skipped."""
    cfg, _, _ = model_and_params
    lens = [P, 7]

    def reqs():
        rs = _reqs(cfg, lens)
        for r in rs:
            r.sampling = SamplingParams(
                temperature=0.9, top_k=7, seed=100 + r.uid
            )
        return rs

    ref = _build(model_and_params, paged_cache=True, page_size=4).run(reqs())
    a = _build(model_and_params, paged_cache=True, page_size=4)
    for r in reqs():
        a.submit(r)
    early = []
    for _ in range(3):
        early += a.step()
    b = _build(model_and_params, paged_cache=True, page_size=4)
    b.import_inflight(a.export_inflight())
    _assert_same_tokens(early + b.run(), ref)


def test_import_rejects_over_capacity(model_and_params):
    """A migrated request no replica-sized pool can hold is refused with a
    structured error, not silently truncated."""
    _, model, params = model_and_params
    cfg, _, _ = model_and_params
    big = _reqs(cfg, [P], gen=20)[0]
    small = ServeEngine(
        model, params, num_slots=1, max_seq=P + G,
        paged_cache=True, page_size=4,
    )
    with pytest.raises(AdmissionError) as ei:
        small.import_inflight([(big, None)])
    assert ei.value.reason == "exceeds_pool"
