"""Checkpointer round-trip tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


@pytest.fixture
def tree(rng):
    k1, k2 = jax.random.split(rng)
    return {
        "layers": {"w": jax.random.normal(k1, (8, 4), jnp.bfloat16)},
        "embed": jax.random.normal(k2, (16, 4), jnp.float32),
        "count": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(100, tree)
    restored = ck.restore(100, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_latest_and_gc(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, tree)
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]


def test_shape_mismatch_raises(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree)
    bad = dict(tree, embed=jnp.zeros((2, 2)))
    with pytest.raises(ValueError):
        ck.restore(1, bad)


def test_missing_leaf_raises(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"only": tree["embed"]})
    with pytest.raises(KeyError):
        ck.restore(1, tree)
