"""Data pipeline tests: determinism, learnability structure, non-IID skew."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.data.federated_data import cloud_sample_counts


class TestCorpus:
    def test_deterministic(self):
        c = SyntheticCorpus(vocab_size=64, n_domains=4)
        mix = jnp.ones(4) / 4
        a = c.sample(jax.random.PRNGKey(1), mix, 4, 16)
        b = c.sample(jax.random.PRNGKey(1), mix, 4, 16)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))

    def test_labels_shifted(self):
        c = SyntheticCorpus(vocab_size=64, n_domains=2, noise=0.0)
        out = c.sample(jax.random.PRNGKey(0), jnp.ones(2) / 2, 2, 10)
        # noiseless: label = (a·t + c) mod V for the sequence's domain
        a_all, c_all = c.domain_params()
        toks, labels, dom = out["tokens"], out["labels"], out["domain"]
        for i in range(2):
            expected = (a_all[dom[i]] * toks[i] + c_all[dom[i]]) % 64
            np.testing.assert_array_equal(np.asarray(labels[i]), np.asarray(expected))

    def test_tokens_in_vocab(self):
        c = SyntheticCorpus(vocab_size=32, n_domains=8, noise=0.5)
        out = c.sample(jax.random.PRNGKey(2), jnp.ones(8) / 8, 8, 64)
        t = np.asarray(out["tokens"])
        assert t.min() >= 0 and t.max() < 32

    def test_oracle_accuracy(self):
        c = SyntheticCorpus(vocab_size=100, n_domains=2, noise=0.2)
        assert c.oracle_accuracy() == pytest.approx(0.8 + 0.2 / 100)


class TestFederatedData:
    def test_dirichlet_simplex(self):
        mix = dirichlet_mixtures(jax.random.PRNGKey(0), 5, 8, beta=0.5)
        assert mix.shape == (5, 8)
        np.testing.assert_allclose(np.asarray(mix.sum(axis=1)), 1.0, rtol=1e-5)

    def test_beta_controls_skew(self):
        key = jax.random.PRNGKey(1)
        skewed = dirichlet_mixtures(key, 20, 8, beta=0.05)
        uniform = dirichlet_mixtures(key, 20, 8, beta=100.0)
        # max component much larger under low beta
        assert float(skewed.max(axis=1).mean()) > float(uniform.max(axis=1).mean()) + 0.3

    def test_degenerate_beta_zero(self):
        mix = dirichlet_mixtures(jax.random.PRNGKey(0), 3, 4, beta=0)
        np.testing.assert_array_equal(np.asarray(mix[0]), [1, 0, 0, 0])
        np.testing.assert_array_equal(np.asarray(mix[1]), [0, 1, 0, 0])

    def test_federated_batch_stacking(self):
        c = SyntheticCorpus(vocab_size=64, n_domains=4)
        mix = dirichlet_mixtures(jax.random.PRNGKey(0), 3, 4, beta=0.3)
        b = federated_batch(c, jax.random.PRNGKey(1), mix, 4, 16)
        assert b["tokens"].shape == (3, 4, 16)
        assert b["labels"].shape == (3, 4, 16)

    def test_non_iid_clouds_see_different_domains(self):
        c = SyntheticCorpus(vocab_size=64, n_domains=4)
        mix = dirichlet_mixtures(jax.random.PRNGKey(3), 3, 4, beta=0.01)
        b = federated_batch(c, jax.random.PRNGKey(2), mix, 32, 8)
        doms = np.asarray(b["domain"])
        # each cloud's dominant domain differs from at least one other cloud
        dominant = [np.bincount(doms[i], minlength=4).argmax() for i in range(3)]
        assert len(set(dominant)) > 1

    def test_sample_counts(self):
        u = cloud_sample_counts(jax.random.PRNGKey(0), 4, skew=0.0)
        np.testing.assert_array_equal(np.asarray(u), 10_000)
        s = cloud_sample_counts(jax.random.PRNGKey(0), 4, skew=1.0)
        assert len(set(np.asarray(s).tolist())) > 1
