"""Privacy layer tests (§3.1): DP clipping/noise, secure aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import privacy
from repro.utils.tree import tree_map, tree_norm


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {
        "a": scale * jax.random.normal(k1, (32, 16)),
        "b": scale * jax.random.normal(k2, (100,)),
    }


class TestDP:
    def test_clip_bounds_norm(self, rng):
        t = _tree(rng, scale=50.0)
        clipped, norm = privacy.clip_update(t, 1.0)
        assert float(norm) > 1.0
        assert float(tree_norm(clipped)) <= 1.0 + 1e-4

    def test_no_clip_below_threshold(self, rng):
        t = _tree(rng, scale=1e-3)
        clipped, _ = privacy.clip_update(t, 10.0)
        for k in t:
            np.testing.assert_allclose(np.asarray(clipped[k]), np.asarray(t[k]), rtol=1e-5)

    @given(scale=st.floats(0.01, 100.0), clip=st.floats(0.1, 10.0))
    @settings(max_examples=30, deadline=None)
    def test_clip_invariant(self, scale, clip):
        t = _tree(jax.random.PRNGKey(7), scale=scale)
        clipped, _ = privacy.clip_update(t, clip)
        assert float(tree_norm(clipped)) <= min(clip, float(tree_norm(t))) * (1 + 1e-3)

    def test_noise_statistics(self, rng):
        t = {"w": jnp.zeros((100_000,))}
        out = privacy.add_gaussian_noise(t, rng, stddev=0.5)["w"]
        assert abs(float(jnp.std(out)) - 0.5) < 0.01
        assert abs(float(jnp.mean(out))) < 0.01

    def test_noise_stddev_scales_with_clouds(self):
        assert privacy.dp_noise_stddev(1.0, 2.0, 4) == pytest.approx(0.5)


class TestSecureAggregation:
    def test_masks_cancel_exactly(self, rng):
        """Σ masked_i == Σ update_i bit-exactly in fixed point."""
        n = 4
        updates = [_tree(jax.random.fold_in(rng, i)) for i in range(n)]
        agg_secure = privacy.secure_aggregate(updates, round_idx=3)
        plain = updates[0]
        for u in updates[1:]:
            plain = tree_map(lambda a, b: a + b, plain, u)
        for k in plain:
            # fixed-point quantization error only: n · 2^-17 per element
            np.testing.assert_allclose(
                np.asarray(agg_secure[k]), np.asarray(plain[k]),
                atol=n / privacy.FIXED_POINT_SCALE,
            )

    def test_individual_update_is_masked(self, rng):
        """A single masked transmission looks nothing like the raw update."""
        u = _tree(rng)
        masked = privacy.mask_update(privacy.to_fixed(u), 0, 3, round_idx=0)
        raw = privacy.to_fixed(u)
        # correlation between masked and raw is ~0 (mask is uniform int32)
        a = np.asarray(masked["a"], np.float64).ravel()
        b = np.asarray(raw["a"], np.float64).ravel()
        corr = np.corrcoef(a, b)[0, 1]
        assert abs(corr) < 0.1

    def test_round_binding(self, rng):
        """Masks differ between rounds (no replay)."""
        u = privacy.to_fixed(_tree(rng))
        m1 = privacy.mask_update(u, 0, 3, round_idx=0)
        m2 = privacy.mask_update(u, 0, 3, round_idx=1)
        assert not np.array_equal(np.asarray(m1["a"]), np.asarray(m2["a"]))

    def test_two_clouds_minimum(self, rng):
        updates = [_tree(jax.random.fold_in(rng, i)) for i in range(2)]
        out = privacy.secure_aggregate(updates, round_idx=0)
        plain = tree_map(lambda a, b: a + b, *updates)
        for k in plain:
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(plain[k]), atol=1e-3
            )
