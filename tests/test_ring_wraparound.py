"""Ring-cache wrap-around audit across the non-transformer cache consumers.

The seed's ``fill_cache`` rolled the surviving tail the wrong direction when
a prompt exceeded the ring capacity; the transformer path is regression-
pinned in ``test_engine.py``. These tests pin the OTHER consumers ROADMAP
flags — the Griffin hybrid's local-attention ring (``rglru.py``) and the
whisper decoder self-attention cache (``whisper.py``, including its
``offset`` sinusoidal-position decode path) — by checking prefill-then-
decode against all-decode (sequential single-token writes) with prompts
that wrap the ring, at the exact-capacity boundary, and across multiple
wraps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import rglru, whisper

W = 6          # ring/window capacity — smaller than most prompts below
GEN = 3        # decode continuation length
# prompt lengths: no wrap, exact fit, wrap by one, multi-wrap
PROMPT_LENS = [5, 6, 7, 15]


def _logits_close(a, b, vocab):
    a = np.asarray(a, np.float32)[..., :vocab]
    b = np.asarray(b, np.float32)[..., :vocab]
    np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


@pytest.fixture(scope="module")
def rglru_parts():
    cfg = get_smoke_config("recurrentgemma-2b")
    return cfg, rglru.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def whisper_parts():
    cfg = get_smoke_config("whisper-medium")
    params = whisper.init_params(cfg, jax.random.PRNGKey(0))
    audio = jax.random.normal(
        jax.random.PRNGKey(2), (2, cfg.encoder_seq, cfg.d_model), cfg.dtype
    )
    return cfg, params, audio


@pytest.mark.parametrize("s", PROMPT_LENS)
def test_rglru_prefill_matches_sequential_decode_writes(rglru_parts, s):
    """Griffin hybrid: chunked prefill (ring filled via ``fill_cache``, LRU
    state via the associative scan) continued by decode must match teacher-
    forcing the whole prompt through single-token decode steps — including
    prompts that wrap the local-attention ring (s > window)."""
    cfg, params = rglru_parts
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s + GEN), 0, cfg.vocab_size)
    dec = jax.jit(
        lambda p, c, t: rglru.decode_step(cfg, p, c, t, window=W)
    )

    cache = rglru.init_decode_cache(cfg, 2, s + GEN, window=W)
    seq_logits = []
    for i in range(s + GEN):
        cache, lg = dec(params, cache, toks[:, i : i + 1])
        seq_logits.append(lg)

    cache2, lg0 = rglru.prefill(
        cfg, params, toks[:, :s], window=W, cache_window=s + GEN
    )
    pf_logits = [lg0]
    for i in range(s, s + GEN):
        cache2, lg = dec(params, cache2, toks[:, i : i + 1])
        pf_logits.append(lg)

    _logits_close(
        jnp.stack(seq_logits[s - 1 :], 1), jnp.stack(pf_logits, 1), cfg.vocab_size
    )


@pytest.mark.parametrize("s", PROMPT_LENS)
def test_whisper_prefill_matches_sequential_decode_writes(whisper_parts, s):
    """Whisper decoder: prefill (self-attn ring via ``fill_cache``, sinusoid
    positions from 0) continued by decode (``offset=pos`` positional path)
    must match all-decode — including prompts that wrap the window ring."""
    cfg, params, audio = whisper_parts
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s + GEN), 0, cfg.vocab_size)
    dec = jax.jit(
        lambda p, c, t: whisper.decode_step(cfg, p, c, t, window=W)
    )

    cache = whisper.init_decode_cache(cfg, params, audio, s + GEN, window=W)
    seq_logits = []
    for i in range(s + GEN):
        cache, lg = dec(params, cache, toks[:, i : i + 1])
        seq_logits.append(lg)

    cache2, lg0 = whisper.prefill(
        cfg, params, {"tokens": toks[:, :s], "audio_embeds": audio},
        window=W, cache_window=W,
    )
    pf_logits = [lg0]
    for i in range(s, s + GEN):
        cache2, lg = dec(params, cache2, toks[:, i : i + 1])
        pf_logits.append(lg)

    _logits_close(
        jnp.stack(seq_logits[s - 1 :], 1), jnp.stack(pf_logits, 1), cfg.vocab_size
    )


def test_whisper_offset_positions_continue_prompt_positions(whisper_parts):
    """The decode-side ``sinusoid_positions(1, d, offset=pos)`` must continue
    exactly where the prefill-side dense positions stopped."""
    cfg = whisper_parts[0]
    d = cfg.d_model
    dense = whisper.sinusoid_positions(10, d)
    for pos in (0, 3, 9):
        step = whisper.sinusoid_positions(1, d, offset=pos)
        np.testing.assert_allclose(
            np.asarray(step[0]), np.asarray(dense[pos]), rtol=1e-6, atol=1e-6
        )


def test_whisper_full_attention_ring_headroom(whisper_parts):
    """window=0 with cache_window headroom (ring never wraps): prefill's
    last logits equal the teacher-forced decode path bitwise."""
    cfg, params, audio = whisper_parts
    s = 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, s), 0, cfg.vocab_size)
    cache, lg_pf = whisper.prefill(
        cfg, params, {"tokens": toks, "audio_embeds": audio}, cache_window=s + 2
    )
    cache2 = whisper.init_decode_cache(cfg, params, audio, s + 2)
    lg = None
    for i in range(s):
        cache2, lg = whisper.decode_step(cfg, params, cache2, toks[:, i : i + 1])
    _logits_close(lg_pf, lg, cfg.vocab_size)
    assert int(cache["pos"]) == int(cache2["pos"]) == s
