"""Sharding-rule tests: parameter partition specs over the production mesh
shapes (AbstractMesh — no devices needed)."""
import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import mesh as meshlib
from repro.launch.specs import microbatch_policy
from repro.configs import get_shape


def abstract_mesh(multi_pod=False):
    names = ("pod", "data", "model") if multi_pod else ("data", "model")
    sizes = (2, 16, 16) if multi_pod else (16, 16)
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


class TestParamRules:
    def test_dense_attention_specs(self):
        cfg = get_config("mistral-nemo-12b")
        mesh = abstract_mesh()
        # column parallel qkv
        s = meshlib.param_spec("layers/attn/wq", (40, 5120, 4096), cfg, mesh)
        assert s == P(None, "data", "model")  # fsdp on for nemo
        # row parallel out projection
        s = meshlib.param_spec("layers/attn/wo", (40, 4096, 5120), cfg, mesh)
        assert s == P(None, "model", "data")
        # vocab-parallel embedding
        s = meshlib.param_spec("embed/tok", (131072, 5120), cfg, mesh)
        assert s == P("model", "data")

    def test_moe_expert_parallel(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        mesh = abstract_mesh()
        s = meshlib.param_spec("layers/ffn/w_gate", (94, 128, 4096, 1536), cfg, mesh)
        assert s == P(None, "model", None, "data")
        s = meshlib.param_spec("layers/ffn/w_down", (94, 128, 1536, 4096), cfg, mesh)
        assert s == P(None, "model", "data", None)

    def test_gqa_kv_replicated_when_not_divisible(self):
        cfg = get_config("recurrentgemma-2b")  # kv_heads=1, head_dim 256
        mesh = abstract_mesh()
        # wk: (L, d, 1*256): 256 % 16 == 0 so it CAN shard; check fits logic
        s = meshlib.param_spec("periods/pos2/mix/wk", (8, 2560, 256), cfg, mesh)
        assert s == P(None, None, "model")
        # a dim that does not divide stays replicated
        s = meshlib.param_spec("layers/attn/wq", (2, 100, 10), cfg, mesh)
        assert s == P(None, None, None)

    def test_norms_replicated(self):
        cfg = get_config("stablelm-1.6b")
        mesh = abstract_mesh()
        assert meshlib.param_spec("layers/ln1/scale", (24, 2048), cfg, mesh) == P()

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_every_leaf_gets_valid_spec(self, arch):
        """All full-size configs: every param leaf's spec divides its dims."""
        from repro.models import build_model

        cfg = get_config(arch)
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        mesh = abstract_mesh()
        pspecs = meshlib.params_pspec_tree(params, cfg, mesh)
        sizes = dict(mesh.shape)

        def check(path, leaf, spec):
            assert len(spec) <= len(leaf.shape)
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                total = 1
                for a in axes:
                    total *= sizes[a]
                assert dim % total == 0, f"{path}: {leaf.shape} vs {spec}"

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), params, pspecs
        )


class TestMicrobatchPolicy:
    def test_big_archs_get_chunked(self):
        assert microbatch_policy(
            get_config("qwen3-moe-235b-a22b"), get_shape("train_4k")
        ) >= 8
        assert microbatch_policy(
            get_config("xlstm-125m"), get_shape("train_4k")
        ) <= 2

    def test_decode_never_chunked(self):
        assert microbatch_policy(
            get_config("qwen3-moe-235b-a22b"), get_shape("decode_32k")
        ) == 1

    def test_divides_local_batch(self):
        for arch in ARCH_IDS:
            mb = microbatch_policy(get_config(arch), get_shape("train_4k"))
            assert (256 // 16) % mb == 0
