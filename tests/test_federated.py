"""FederatedTrainer integration tests — the paper's full loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model
from repro.optim.adamw import adamw_init, adamw_update
from repro.utils.tree import tree_map


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.1)
    mix = dirichlet_mixtures(jax.random.PRNGKey(0), 3, 4, beta=0.3)
    return cfg, model, corpus, mix


def run_steps(trainer, corpus, mix, steps, seq=32, pcb=4, seed=0):
    state = trainer.init_state(jax.random.PRNGKey(seed))
    step = jax.jit(trainer.train_step)
    losses = []
    for i in range(steps):
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 9), i)
        batch = federated_batch(corpus, key, mix, pcb, seq)
        arrived = jnp.asarray([(i // trainer.fed.local_steps) % 3 == j for j in range(3)])
        alphas = jnp.full((3,), 0.5)
        state, m = step(state, batch, arrived, alphas)
        losses.append(float(m["loss"]))
    return state, losses


@pytest.mark.parametrize("aggregation", ["fedavg", "dynamic", "gradient", "async"])
def test_all_aggregators_learn(setup, aggregation):
    cfg, model, corpus, mix = setup
    fed = FederatedConfig(n_clouds=3, local_steps=2, aggregation=aggregation)
    tcfg = TrainConfig(steps=40, lr=3e-3, warmup_steps=4, grad_clip=1.0)
    trainer = FederatedTrainer(model, fed, tcfg)
    _, losses = run_steps(trainer, corpus, mix, 40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, (
        f"{aggregation} did not learn: {losses[:3]} → {losses[-3:]}"
    )


def test_single_cloud_h1_equals_centralized(setup):
    """Degenerate federated (1 cloud, sync every step, no compression) must
    match plain centralized AdamW training bit-for-bit-ish."""
    cfg, model, corpus, _ = setup
    fed = FederatedConfig(n_clouds=1, local_steps=1, aggregation="fedavg")
    tcfg = TrainConfig(steps=10, lr=1e-3, warmup_steps=2)
    trainer = FederatedTrainer(model, fed, tcfg)
    state = trainer.init_state(jax.random.PRNGKey(0))

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)

    step = jax.jit(trainer.train_step)

    @jax.jit
    def central_step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        return adamw_update(tcfg, grads, opt, params) + (loss,)

    mix1 = jnp.ones((1, 4)) / 4
    for i in range(5):
        key = jax.random.fold_in(jax.random.PRNGKey(5), i)
        batch = federated_batch(corpus, key, mix1, 4, 32)
        state, m = step(state, batch)
        single = {k: v[0] for k, v in batch.items() if k != "domain"}
        params, opt, loss = central_step(params, opt, single)
        # vmapped-over-clouds vs plain loss differ in reduction order; bf16
        # matmuls under a different batching layout drift ~1e-4 relative.
        np.testing.assert_allclose(float(m["loss"]), float(loss), rtol=5e-4)
    for (p1, p2) in zip(
        jax.tree_util.tree_leaves(state["global"]["params"]),
        jax.tree_util.tree_leaves(params),
    ):
        # Adam's m/√v amplifies the ~1-ulp bf16 gradient differences between
        # the vmapped and plain paths for near-zero gradients; after 5 steps
        # of lr=1e-3 the accumulated drift is a few 1e-3 in the worst leaf.
        np.testing.assert_allclose(
            np.asarray(p1, np.float32), np.asarray(p2, np.float32), atol=5e-3
        )


def test_clouds_diverge_between_syncs_and_converge_at_sync(setup):
    cfg, model, corpus, mix = setup
    fed = FederatedConfig(n_clouds=3, local_steps=4, aggregation="fedavg")
    trainer = FederatedTrainer(model, fed, TrainConfig(steps=8, lr=1e-3))
    state = trainer.init_state(jax.random.PRNGKey(1))
    step = jax.jit(trainer.train_step)

    def cloud_spread(state):
        leaf = jax.tree_util.tree_leaves(state["clouds"]["params"])[0]
        return float(jnp.max(jnp.abs(leaf[0].astype(jnp.float32) - leaf[1].astype(jnp.float32))))

    for i in range(3):  # steps 1..3: no sync yet
        batch = federated_batch(corpus, jax.random.fold_in(jax.random.PRNGKey(2), i), mix, 4, 32)
        state, m = step(state, batch)
        assert float(m["synced"]) == 0.0
    assert cloud_spread(state) > 0  # non-IID data → divergence
    batch = federated_batch(corpus, jax.random.fold_in(jax.random.PRNGKey(2), 3), mix, 4, 32)
    state, m = step(state, batch)  # step 4: sync round
    assert float(m["synced"]) == 1.0
    assert cloud_spread(state) == 0.0  # replicas identical after fedavg


def test_compression_reduces_bytes_and_still_learns(setup):
    cfg, model, corpus, mix = setup
    tcfg = TrainConfig(steps=40, lr=3e-3, warmup_steps=4)
    results = {}
    for compression in ("none", "topk"):
        fed = FederatedConfig(
            n_clouds=3, local_steps=2, aggregation="fedavg",
            compression=compression, topk_ratio=0.05,
        )
        trainer = FederatedTrainer(model, fed, tcfg)
        state, losses = run_steps(trainer, corpus, mix, 40, seed=3)
        results[compression] = {
            "loss": np.mean(losses[-5:]),
            "bytes": trainer.sync_bytes_per_cloud(state["global"]["params"]),
        }
    assert results["topk"]["bytes"] < results["none"]["bytes"] / 10
    assert results["topk"]["loss"] < 6.2  # still learns


def test_error_feedback_state_evolves(setup):
    cfg, model, corpus, mix = setup
    fed = FederatedConfig(
        n_clouds=3, local_steps=2, aggregation="fedavg",
        compression="topk", topk_ratio=0.01, error_feedback=True,
    )
    trainer = FederatedTrainer(model, fed, TrainConfig(steps=4, lr=1e-3))
    state = trainer.init_state(jax.random.PRNGKey(4))
    assert "ef" in state
    step = jax.jit(trainer.train_step)
    for i in range(2):
        batch = federated_batch(corpus, jax.random.fold_in(jax.random.PRNGKey(6), i), mix, 4, 32)
        state, _ = step(state, batch)
    ef_norm = sum(
        float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(state["ef"])
    )
    assert ef_norm > 0  # residuals are being carried


def test_dp_clip_and_noise_run(setup):
    cfg, model, corpus, mix = setup
    fed = FederatedConfig(
        n_clouds=3, local_steps=2, aggregation="fedavg",
        dp_clip=0.5, dp_noise_mult=0.1,
    )
    trainer = FederatedTrainer(model, fed, TrainConfig(steps=4, lr=1e-3))
    state, losses = run_steps(trainer, corpus, mix, 4, seed=5)
    assert all(np.isfinite(l) for l in losses)


def test_outer_nesterov_runs(setup):
    cfg, model, corpus, mix = setup
    fed = FederatedConfig(
        n_clouds=3, local_steps=4, aggregation="fedavg",
        outer_optimizer="nesterov", outer_lr=0.7,
    )
    trainer = FederatedTrainer(model, fed, TrainConfig(steps=8, lr=3e-3))
    state, losses = run_steps(trainer, corpus, mix, 8, seed=6)
    assert "momentum" in state["global"]["outer"]
    assert all(np.isfinite(l) for l in losses)


def test_dynamic_weights_favor_better_cloud(setup):
    """Cloud with 10× more noise gets lower dynamic weight."""
    cfg, model, corpus, mix = setup
    from repro.core.aggregation import dynamic_weights
    losses = jnp.asarray([2.0, 2.0, 4.5])
    w = np.asarray(dynamic_weights(losses))
    assert w[2] < w[0] / 3
