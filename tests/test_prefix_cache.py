"""Shared-prefix KV cache tests: the radix index (launch/prefix_cache.py),
refcounted page aliasing, copy-on-write splits, suffix-only prefill, and
the engine-level contract.

The contract mirrors the rest of the engine suite: SHARING MUST BE
INVISIBLE IN THE OUTPUT. The non-shared paged engine is the oracle — the
prefix-sharing engine must emit token-identical output on every trace,
through cold/warm indexes, full-prompt cache hits (CoW), LRU eviction
under pool pressure, preemption, and the page-table decode kernel — while
prefilling strictly fewer tokens on shared-prefix traffic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.launch.engine import PagePool, Request, ServeEngine
from repro.launch.prefix_cache import PrefixCache

ARCH = "stablelm-1.6b"
PS = 4  # page size used throughout the engine tests


@pytest.fixture(scope="module")
def model_and_params():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _build(model_and_params, *, prefix=True, **kw):
    _, model, params = model_and_params
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_seq", 24)
    kw.setdefault("paged_cache", True)
    kw.setdefault("page_size", PS)
    return ServeEngine(model, params, prefix_cache=prefix, **kw)


def _prompts(cfg, shape_seed=0):
    """Deterministic token material for hand-built prompts."""
    rng = np.random.default_rng(shape_seed)
    return lambda n: rng.integers(1, cfg.vocab_size, n).astype(np.int32)


def _reqs_shared(cfg, suffix_lens, *, prefix_tokens=12, gen=4, seed=0):
    """Requests sharing one common prefix (``prefix_tokens`` long) with
    per-request unique suffixes."""
    draw = _prompts(cfg, seed)
    common = draw(prefix_tokens)
    reqs = []
    for j, sl in enumerate(suffix_lens):
        prompt = np.concatenate([common, draw(sl)]) if sl else common.copy()
        reqs.append(Request(uid=j, prompt=prompt, max_new_tokens=gen))
    return reqs


def _assert_same_tokens(a, b):
    ref = {o.uid: o.tokens for o in b}
    assert len(a) == len(b)
    for o in a:
        assert o.tokens == ref[o.uid], f"uid {o.uid}: {o.tokens} != {ref[o.uid]}"


# ------------------------------------------------------------ index (unit)
def test_trie_match_insert_roundtrip():
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    toks = np.arange(100, 111, dtype=np.int32)  # 11 tokens → 2 full pages
    pages = pool.alloc(3)  # slot-held: 2 full + 1 partial
    assert cache.match(toks) == []
    assert cache.insert(toks, pages[:2]) == 2
    assert cache.size == 2
    # index holds its own refs; the slot's die without killing the pages
    pool.free(pages)
    assert pool.refcount(pages[0]) == 1 and pool.refcount(pages[1]) == 1
    assert pool.refcount(pages[2]) == 0
    assert cache.match(toks) == pages[:2]
    assert cache.match(toks[:8]) == pages[:2]   # exact 2-page prefix
    assert cache.match(toks[:7]) == pages[:1]   # only 1 full page matches
    assert cache.match(toks[:3]) == []          # shorter than a page
    divergent = toks.copy()
    divergent[5] = 999                          # differs inside page 2
    assert cache.match(divergent) == pages[:1]


def test_trie_insert_dedupes_to_existing_pages():
    """Re-publishing an indexed chunk keeps the FIRST physical page; the
    duplicate publisher's copy dies with its own refs."""
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    toks = np.arange(50, 58, dtype=np.int32)
    first = pool.alloc(2)
    cache.insert(toks, first)
    dup = pool.alloc(2)
    assert cache.insert(toks, dup) == 0          # nothing new
    pool.free(first)
    pool.free(dup)
    assert cache.match(toks) == first
    assert pool.refcount(dup[0]) == 0            # duplicate copy died


def test_trie_lru_leaf_eviction_order():
    """Eviction takes the LRU LEAF: interior nodes are pinned by their
    descendants, and a fresh match() refreshes the whole matched path."""
    pool = PagePool(num_pages=16, page_size=2)
    cache = PrefixCache(pool)
    a = np.asarray([1, 1, 2, 2], np.int32)       # chain A: [11][22]
    b = np.asarray([1, 1, 3, 3], np.int32)       # chain B: [11][33]
    pa = pool.alloc(2)
    cache.insert(a, pa)
    pb_tail = pool.alloc(1)
    cache.insert(b, [pa[0], pb_tail[0]])         # shares the [11] node
    pool.free(pa), pool.free(pb_tail)
    assert cache.size == 3
    cache.match(a)                               # A's leaf is now hottest
    assert cache.evict(1) == 1                   # evicts B's tail (LRU leaf)
    assert cache.match(b) == [pa[0]]             # B now misses its tail
    assert cache.match(a) == pa                  # A fully intact
    assert cache.evict(10) == 2                  # drains: A leaf then root [11]
    assert cache.size == 0 and pool.available == pool.capacity


def test_trie_eviction_respects_live_sharers():
    """Evicting an entry whose page a live slot still shares releases the
    index ref but frees no memory until the slot's ref drops."""
    pool = PagePool(num_pages=8, page_size=4)
    cache = PrefixCache(pool)
    toks = np.arange(8, dtype=np.int32)
    pages = pool.alloc(2)
    cache.insert(toks, pages)
    pool.share(pages[0])        # a live slot aliases page 0 of the prefix
    pool.free(pages)            # publisher's own refs drop
    freed = cache.evict(2)      # index drains fully...
    assert cache.size == 0
    assert freed == 1           # ...but only the unshared page came back
    assert pool.refcount(pages[0]) == 1
    pool.free([pages[0]])
    assert pool.available == pool.capacity


def test_trie_max_pages_cap():
    pool = PagePool(num_pages=32, page_size=2)
    cache = PrefixCache(pool, max_pages=3)
    for j in range(4):
        toks = np.asarray([j, j, j + 10, j + 10], np.int32)
        pages = pool.alloc(2)
        cache.insert(toks, pages)
        pool.free(pages)
        assert cache.size <= 3
    assert cache.size == 3


# ----------------------------------------------- suffix ring writes (unit)
def test_fill_cache_rows_with_starts_matches_fill_cache():
    """fill_cache_rows(starts=s) leaves each ring row exactly as the
    single-row fill_cache(start=s) oracle does, per row."""
    from repro.models.attention import fill_cache, fill_cache_rows

    rng = np.random.default_rng(0)
    cap, s_max, hkv, hd, n = 12, 7, 2, 4, 3
    base_k = rng.normal(size=(n, cap, hkv, hd)).astype(np.float32)
    base_v = rng.normal(size=(n, cap, hkv, hd)).astype(np.float32)
    k = rng.normal(size=(n, s_max, hkv, hd)).astype(np.float32)
    v = rng.normal(size=(n, s_max, hkv, hd)).astype(np.float32)
    lengths = np.asarray([7, 4, 0], np.int32)
    starts = np.asarray([5, 8, 0], np.int32)
    nk, nv = fill_cache_rows(
        jnp.asarray(base_k), jnp.asarray(base_v), jnp.asarray(k),
        jnp.asarray(v), jnp.asarray(lengths), starts=jnp.asarray(starts),
    )
    for r in range(n):
        if lengths[r] == 0:
            exp_k, exp_v = base_k[r], base_v[r]
        else:
            ref = fill_cache(
                {
                    "k": jnp.asarray(base_k[r : r + 1]),
                    "v": jnp.asarray(base_v[r : r + 1]),
                    "pos": jnp.asarray(int(starts[r]), jnp.int32),
                },
                jnp.asarray(k[r : r + 1, : lengths[r]]),
                jnp.asarray(v[r : r + 1, : lengths[r]]),
                start=int(starts[r]),
            )
            exp_k, exp_v = np.asarray(ref["k"][0]), np.asarray(ref["v"][0])
        np.testing.assert_array_equal(np.asarray(nk[r]), exp_k)
        np.testing.assert_array_equal(np.asarray(nv[r]), exp_v)


# ------------------------------------------------------ engine: the oracle
def test_warm_index_token_identical_to_nonshared(model_and_params):
    """Two admission generations over a common prefix: the second round
    maps cached pages and prefills only suffixes — tokens must match the
    non-shared paged engine exactly, with strictly fewer prefilled
    tokens."""
    cfg, _, _ = model_and_params
    lens = [5, 7, 3, 6, 4, 8]
    ref_engine = _build(model_and_params, prefix=False)
    ref = ref_engine.run(_reqs_shared(cfg, lens))
    engine = _build(model_and_params, prefix=True)
    outs = engine.run(_reqs_shared(cfg, lens))
    _assert_same_tokens(outs, ref)
    assert engine.prefix_hit_pages > 0, "warm rounds must hit the index"
    assert engine.prefill_tokens < ref_engine.prefill_tokens
    stats = engine.pool_stats
    assert 0 < stats["prefix_hit_rate"] < 1
    assert stats["prefix_pages_cached"] > 0


def test_fully_cached_prompt_splits_cow_page(model_and_params):
    """An identical page-aligned prompt re-submitted after retirement is a
    100% index hit: its final token re-prefills into a copy-on-write split
    of the last shared page, and the indexed original must stay bit-intact
    for later readers."""
    cfg, _, _ = model_and_params
    prompt = _prompts(cfg, 3)(4 * PS)  # 16 tokens, exactly 4 pages
    mk = lambda uid: Request(uid=uid, prompt=prompt.copy(), max_new_tokens=4)
    engine = _build(model_and_params, prefix=True)
    a = engine.run([mk(0)])
    b = engine.run([mk(1)])
    c = engine.run([mk(2)])  # hits the ORIGINAL pages again, post-CoW
    assert engine.cow_copies >= 2
    assert engine.pool_stats["prefix_hit_rate"] > 0
    ref = _build(model_and_params, prefix=False)
    ra, rb, rc = ref.run([mk(0)]), ref.run([mk(1)]), ref.run([mk(2)])
    _assert_same_tokens(a, ra)
    _assert_same_tokens(b, rb)
    _assert_same_tokens(c, rc)


def test_divergence_inside_shared_page_is_not_hit(model_and_params):
    """Prompts diverging INSIDE a page share only the full pages before
    it; the divergent page prefills fresh — tokens match the oracle."""
    cfg, _, _ = model_and_params
    draw = _prompts(cfg, 1)
    common = draw(2 * PS + 2)            # 2 full pages + 2 tokens
    tails = [draw(3), draw(3)]
    reqs = lambda: [
        Request(uid=j, prompt=np.concatenate([common, tails[j]]),
                max_new_tokens=4)
        for j in range(2)
    ]
    engine = _build(model_and_params, prefix=True, num_slots=1)
    outs = engine.run(reqs())
    # only the 2 FULL common pages are shareable; the mixed page is not
    assert engine.prefix_hit_pages == 2
    ref = _build(model_and_params, prefix=False, num_slots=1).run(reqs())
    _assert_same_tokens(outs, ref)


def test_eviction_under_pool_pressure_degrades_gracefully(model_and_params):
    """A pool too small to keep the index AND live slots resident: LRU
    eviction sheds index pages (before watermark throttling / preemption)
    and the engine keeps emitting oracle tokens."""
    cfg, _, _ = model_and_params
    lens = [5, 7, 3, 6, 4, 8, 2, 5]
    ref = _build(model_and_params, prefix=False).run(_reqs_shared(cfg, lens))
    tight = _build(model_and_params, prefix=True, num_pages=9)
    outs = tight.run(_reqs_shared(cfg, lens))
    _assert_same_tokens(outs, ref)
    assert tight.prefix.evicted_pages > 0, "tight pool must evict"
    assert tight.pool.in_use == tight.prefix.size  # only the index pins pages


def test_preemption_with_prefix_sharing_token_identical(model_and_params):
    """OOM preemption + resume composes with prefix sharing: the resumed
    request may re-admit THROUGH the index (its prompt is published) and
    must continue bit-exactly."""
    cfg, _, _ = model_and_params
    lens = [6, 7, 5]
    ref = _build(model_and_params, prefix=False).run(
        _reqs_shared(cfg, lens, gen=6)
    )
    tight = _build(model_and_params, prefix=True, num_pages=8)
    outs = tight.run(_reqs_shared(cfg, lens, gen=6))
    _assert_same_tokens(outs, ref)
    assert tight.pool.live_refs == tight.prefix.size


def test_kernel_decode_over_aliased_pages(model_and_params):
    """The page-table decode kernel reads slots whose tables alias the
    SAME physical pages — tokens equal the kernel engine without
    sharing."""
    cfg, _, _ = model_and_params
    lens = [5, 6, 4, 7]
    ref = _build(model_and_params, prefix=False, use_kernel=True).run(
        _reqs_shared(cfg, lens)
    )
    engine = _build(model_and_params, prefix=True, use_kernel=True)
    outs = engine.run(_reqs_shared(cfg, lens))
    assert engine.prefix_hit_pages > 0
    _assert_same_tokens(outs, ref)


def test_sampling_streams_survive_prefix_hits(model_and_params):
    """Suffix-only prefill must not perturb per-request PRNG streams."""
    from repro.launch.sampling import SamplingParams

    cfg, _, _ = model_and_params
    lens = [5, 7, 4, 6]

    def reqs():
        rs = _reqs_shared(cfg, lens)
        for r in rs:
            r.sampling = SamplingParams(temperature=0.8, top_k=9, seed=7 + r.uid)
        return rs

    ref = _build(model_and_params, prefix=False).run(reqs())
    engine = _build(model_and_params, prefix=True)
    outs = engine.run(reqs())
    assert engine.prefix_hit_pages > 0
    _assert_same_tokens(outs, ref)


def test_prefix_disabled_configs_fall_back(model_and_params):
    """Windowed / interleaved / ring configs silently run without the
    index (prefix sharing needs a non-wrapping chunked paged cache)."""
    engine = _build(model_and_params, prefix=True, window=6)
    assert engine.prefix is None and not engine.prefix_cache
    engine = _build(model_and_params, prefix=True, prefill="interleaved")
    assert engine.prefix is None
    engine = _build(model_and_params, prefix=True, paged_cache=False)
    assert engine.prefix is None and engine.pool_stats is None


def test_retirement_returns_only_unpublished_pages(model_and_params):
    """After a run, the pool holds exactly the index's pages — slot refs
    all dropped, partial tail pages freed, published pages pinned once."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, prefix=True)
    engine.run(_reqs_shared(cfg, [5, 7, 3]))
    assert engine.pool.in_use == engine.prefix.size
    assert engine.pool.live_refs == engine.prefix.size
    engine.prefix.clear()
    assert engine.pool.in_use == 0


@given(
    suffix_lens=st.lists(st.integers(0, 9), min_size=1, max_size=6),
    prefix_tokens=st.integers(1, 17),
    page_size=st.sampled_from([2, 4, 8]),
)
@settings(max_examples=8, deadline=None)
def test_property_sharing_token_identical(
    model_and_params, suffix_lens, prefix_tokens, page_size
):
    """Any shared-prefix trace, any page size: the sharing engine is
    token-identical to the non-shared paged engine (which PR 4 pinned
    bitwise to the ring engine)."""
    cfg, _, _ = model_and_params
    if suffix_lens[0] == 0 and prefix_tokens < 2:
        prefix_tokens = 2  # prompt of 1 token + full-hit needs a suffix
    kw = dict(max_seq=32, page_size=page_size, gen=3)
    reqs = lambda: _reqs_shared(
        cfg, suffix_lens, prefix_tokens=prefix_tokens, gen=3,
        seed=prefix_tokens,
    )
    ref = _build(
        model_and_params, prefix=False, max_seq=32, page_size=page_size
    ).run(reqs())
    engine = _build(
        model_and_params, prefix=True, max_seq=32, page_size=page_size
    )
    _assert_same_tokens(engine.run(reqs()), ref)

def test_probe_is_read_only():
    """``probe`` predicts hit depth for router affinity WITHOUT the side
    effects of ``match``: no lookup/hit accounting, and no LRU touch — a
    probed-but-never-matched chain must still be the eviction victim."""
    pool = PagePool(num_pages=16, page_size=2)
    cache = PrefixCache(pool)
    a = np.asarray([1, 1, 2, 2], np.int32)       # chain A: [11][22]
    b = np.asarray([1, 1, 3, 3], np.int32)       # chain B: [11][33]
    pa = pool.alloc(2)
    cache.insert(a, pa)
    pb_tail = pool.alloc(1)
    cache.insert(b, [pa[0], pb_tail[0]])
    pool.free(pa), pool.free(pb_tail)
    cache.match(b)                               # B hottest; A's leaf is LRU
    lookups, hits = cache.lookups, cache.hit_pages
    assert cache.probe(a) == 2                   # full chain indexed
    assert cache.probe(a[:2]) == 1
    assert cache.probe(np.asarray([9, 9], np.int32)) == 0
    for _ in range(5):
        cache.probe(a)                           # hammer A via probe only
    assert cache.lookups == lookups and cache.hit_pages == hits, (
        "probe must not count as a lookup"
    )
    assert cache.evict(1) == 1
    assert cache.match(a) == [pa[0]], (
        "probes touched the LRU clock: A's leaf should have been evicted"
    )
