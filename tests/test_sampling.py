"""Sampling tests: the single-row sampler's filters, and the engine's
per-request stream discipline (same seed → same tokens; slot reuse →
fresh stream; greedy requests untouched by sampling plumbing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.engine import Request, ServeEngine, make_requests
from repro.launch.sampling import SamplingParams, sample_token
from repro.models import build_model

ARCH = "stablelm-1.6b"
P, G = 8, 6


@pytest.fixture(scope="module")
def engine_parts():
    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _engine(parts, **kw):
    cfg, model, params = parts
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_seq", P + G)
    return ServeEngine(model, params, **kw)


# ----------------------------------------------------------- sampler filters
def test_top_k_one_is_greedy(rng):
    logits = jax.random.normal(rng, (64,))
    best = int(jnp.argmax(logits))
    for i in range(8):
        tok = sample_token(
            jax.random.fold_in(rng, i), logits, jnp.float32(1.0),
            jnp.int32(1), jnp.float32(1.0), 64,
        )
        assert int(tok) == best


def test_tiny_top_p_is_greedy(rng):
    logits = jax.random.normal(jax.random.fold_in(rng, 1), (64,))
    best = int(jnp.argmax(logits))
    for i in range(8):
        tok = sample_token(
            jax.random.fold_in(rng, 100 + i), logits, jnp.float32(1.0),
            jnp.int32(0), jnp.float32(1e-6), 64,
        )
        assert int(tok) == best


def test_top_k_restricts_support(rng):
    logits = jax.random.normal(jax.random.fold_in(rng, 2), (64,))
    top5 = set(np.asarray(jnp.argsort(-logits)[:5]).tolist())
    seen = set()
    for i in range(64):
        tok = sample_token(
            jax.random.fold_in(rng, 200 + i), logits, jnp.float32(2.0),
            jnp.int32(5), jnp.float32(1.0), 64,
        )
        seen.add(int(tok))
    assert seen <= top5
    assert len(seen) > 1, "temperature 2 over 5 tokens should mix"


def test_top_p_keeps_nucleus_only():
    # one dominant token (p ~ 0.88) + tail: top_p=0.5 must always take it
    logits = jnp.full((16,), 0.0).at[3].set(5.0)
    for i in range(16):
        tok = sample_token(
            jax.random.PRNGKey(i), logits, jnp.float32(1.0),
            jnp.int32(0), jnp.float32(0.5), 16,
        )
        assert int(tok) == 3


def test_sampling_params_validation():
    with pytest.raises(AssertionError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(AssertionError):
        SamplingParams(top_p=0.0)
    with pytest.raises(AssertionError):
        SamplingParams(top_k=-1)
    assert SamplingParams(temperature=0.0).is_greedy


# ------------------------------------------------------ engine stream rules
def test_same_seed_same_tokens(engine_parts):
    cfg = engine_parts[0]
    sp = SamplingParams(temperature=0.9, top_k=0, top_p=0.95, seed=42)

    def run():
        engine = _engine(engine_parts)
        reqs = make_requests(cfg, n_requests=3, prompt_len=P, gen_tokens=G, seed=0)
        for r in reqs:
            r.sampling = sp
        return [o.tokens for o in engine.run(reqs)]

    a, b = run(), run()
    assert a == b, "same sampling seed must reproduce the same tokens"


def test_slot_reuse_gets_fresh_stream(engine_parts):
    """Two identical prompts WITHOUT explicit seeds served back-to-back
    through ONE slot: the stream is keyed by request (engine seed + uid),
    so the second occupant must not replay the first one's tokens."""
    cfg = engine_parts[0]
    base = make_requests(cfg, n_requests=1, prompt_len=P, gen_tokens=G, seed=0)[0]
    reqs = [
        Request(uid=i, prompt=base.prompt, max_new_tokens=G,
                sampling=SamplingParams(temperature=5.0))
        for i in range(2)
    ]
    engine = _engine(engine_parts, num_slots=1, seed=7)
    outs = engine.run(reqs)
    assert outs[0].slot == outs[1].slot == 0
    assert outs[0].tokens != outs[1].tokens, (
        "slot reuse must not reuse the previous request's sampling stream"
    )


def test_same_explicit_seed_is_slot_independent(engine_parts):
    """The SAME request (same prompt + explicit seed) served from different
    slots produces identical tokens — streams belong to requests, not slots."""
    cfg = engine_parts[0]
    base = make_requests(cfg, n_requests=1, prompt_len=P, gen_tokens=G, seed=0)[0]
    sp = SamplingParams(temperature=0.9, seed=11)

    def run(n_slots, uid):
        engine = _engine(engine_parts, num_slots=n_slots, seed=uid * 100)
        # filler request occupies slot 0 so the probe lands in a different
        # slot when n_slots > 1
        reqs = [Request(uid=0, prompt=base.prompt, max_new_tokens=G)]
        if n_slots > 1:
            reqs.append(
                Request(uid=1, prompt=base.prompt, max_new_tokens=G, sampling=sp)
            )
        else:
            reqs[0] = Request(uid=1, prompt=base.prompt, max_new_tokens=G,
                              sampling=sp)
        outs = engine.run(reqs)
        probe = [o for o in outs if o.uid == 1][0]
        return probe.slot, probe.tokens

    slot_a, toks_a = run(1, 1)
    slot_b, toks_b = run(2, 2)
    assert slot_a != slot_b
    assert toks_a == toks_b


def test_greedy_requests_unaffected_by_sampling_neighbors(engine_parts):
    """A greedy request sharing the batch with sampling requests produces
    exactly its solo-greedy tokens (rows are independent)."""
    cfg = engine_parts[0]
    reqs = make_requests(cfg, n_requests=3, prompt_len=P, gen_tokens=G, seed=0)
    reqs[0].sampling = SamplingParams(temperature=1.5, seed=3)
    reqs[2].sampling = SamplingParams(temperature=1.5, seed=4)
    engine = _engine(engine_parts, num_slots=3)
    mixed = {o.uid: o.tokens for o in engine.run(reqs)}

    solo = _engine(engine_parts, num_slots=1)
    # same corpus draw (same n_requests) so uid 1 has the identical prompt
    ref = solo.run(make_requests(cfg, n_requests=3, prompt_len=P,
                                 gen_tokens=G, seed=0)[1:2])
    assert mixed[1] == ref[0].tokens


@pytest.mark.parametrize("prefill", ["chunked", "interleaved"])
def test_sampling_deterministic_across_prefill_modes(engine_parts, prefill):
    """The first sampled token comes from prefill logits (chunked) or the
    final teacher-forced decode step (interleaved) — same logits either way,
    so the whole sampled sequence is mode-independent."""
    cfg = engine_parts[0]

    def run(mode):
        engine = _engine(engine_parts, prefill=mode)
        reqs = make_requests(cfg, n_requests=2, prompt_len=P, gen_tokens=G, seed=0)
        for r in reqs:
            r.sampling = SamplingParams(temperature=0.8, top_k=50, seed=21 + r.uid)
        return [o.tokens for o in engine.run(reqs)]

    assert run("chunked") == run(prefill)
