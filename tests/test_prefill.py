"""Prefill-path tests: prefill == teacher-forced forward at the last
position, prefill→decode continuation == full teacher forcing, and a direct
regression for chunked cross-attention (query/key lengths must not be
conflated when the query side is chunked)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import build_model
from repro.models.common import padded_vocab

from tests.test_models_smoke import make_batch


def _relaxed(cfg):
    """MoE capacity drops make cached-vs-full comparisons inexact; open them."""
    if cfg.arch_type == "moe":
        return dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


# VLM prefill consumes the image prefix; its decode-side comparison needs the
# patch embeddings, exercised separately in its own example.
PREFILL_ARCHS = [a for a in ARCH_IDS if a != "pixtral-12b"]


@pytest.mark.parametrize("arch", PREFILL_ARCHS)
def test_prefill_matches_forward_last_logits(arch, rng):
    cfg = _relaxed(get_smoke_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    b, s = 2, 12
    batch = make_batch(cfg, jax.random.fold_in(rng, 7), b, s)
    cache, logits = jax.jit(model.prefill)(params, batch)
    fwd = model.forward(params, batch)
    a = np.asarray(logits, np.float32)[:, : cfg.vocab_size]
    f = np.asarray(fwd[:, -1], np.float32)[:, : cfg.vocab_size]
    tol = 0.02 if cfg.arch_type == "audio" else 5e-3
    err = np.max(np.abs(a - f)) / (np.max(np.abs(f)) + 1e-9)
    assert err < tol, f"prefill/forward mismatch rel err {err}"


@pytest.mark.parametrize("arch", PREFILL_ARCHS)
def test_prefill_then_decode_matches_teacher_forcing(arch, rng):
    """Prefill the first 8 tokens, decode the next 4 — every decoded logit
    must match the full-sequence teacher-forced forward."""
    cfg = _relaxed(get_smoke_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    b, s, k = 2, 12, 4
    batch = make_batch(cfg, jax.random.fold_in(rng, 8), b, s)
    prompt = {**batch, "tokens": batch["tokens"][:, : s - k],
              "labels": batch["labels"][:, : s - k]}
    # cache_window=s reserves ring headroom for the k-token continuation
    cache, logits = jax.jit(
        lambda p, b: model.prefill(p, b, cache_window=s)
    )(params, prompt)
    outs = [logits]
    dec = jax.jit(lambda p, c, t: model.decode(p, c, t))
    for i in range(s - k, s - 1):
        cache, lg = dec(params, cache, batch["tokens"][:, i : i + 1])
        outs.append(lg)
    a = np.asarray(jnp.stack(outs, 1), np.float32)[..., : cfg.vocab_size]
    fwd = np.asarray(model.forward(params, batch), np.float32)[
        :, s - k - 1 : s - 1, : cfg.vocab_size
    ]
    tol = 0.02 if cfg.arch_type == "audio" else 5e-3
    err = np.max(np.abs(a - fwd)) / (np.max(np.abs(fwd)) + 1e-9)
    assert err < tol, f"prefill+decode/forward mismatch rel err {err}"


# --------------------------------------------------------------- regression
def _tiny_cfg():
    return get_smoke_config("stablelm-1.6b")


def test_cross_attention_chunked_matches_unchunked(rng):
    """Regression: attend_full with cross-attention kv of a *different*
    length than the query side, with query chunking engaged. (The query
    positions fallback used to borrow the kv positions tensor, which has the
    wrong length — whisper train_4k dry-run failure.)"""
    cfg = _tiny_cfg()
    params = attn.init_attention(rng, cfg)
    b, sq, skv = 2, 16, 6
    hd = cfg.resolved_head_dim
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, sq, cfg.d_model), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 2), (b, skv, cfg.n_kv_heads, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(rng, 3), (b, skv, cfg.n_kv_heads, hd), jnp.float32)

    out_chunked = attn.attend_full(
        params, x, None, cfg, causal=False, kv=(k, v), q_chunk=4, rope=False
    )
    out_full = attn.attend_full(
        params, x, None, cfg, causal=False, kv=(k, v), q_chunk=sq, rope=False
    )
    np.testing.assert_allclose(
        np.asarray(out_chunked, np.float32),
        np.asarray(out_full, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_causal_self_attention_chunked_matches_unchunked(rng):
    cfg = _tiny_cfg()
    params = attn.init_attention(rng, cfg)
    b, s = 2, 16
    x = jax.random.normal(jax.random.fold_in(rng, 4), (b, s, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out_chunked = attn.attend_full(params, x, pos, cfg, causal=True, q_chunk=4)
    out_full = attn.attend_full(params, x, pos, cfg, causal=True, q_chunk=s)
    np.testing.assert_allclose(
        np.asarray(out_chunked, np.float32),
        np.asarray(out_full, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_attend_full_prefill_kernel_path_matches(rng):
    """attend_full with USE_PREFILL_KERNEL on == the jnp chunked path."""
    from repro.models import attention as attn
    cfg = _tiny_cfg()
    params = attn.init_attention(rng, cfg)
    b, s = 2, 64
    x = jax.random.normal(jax.random.fold_in(rng, 9), (b, s, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ref = attn.attend_full(params, x, pos, cfg, causal=True, q_chunk=16)
    attn.set_prefill_kernel(True)
    try:
        out = attn.attend_full(params, x, pos, cfg, causal=True, q_chunk=16)
    finally:
        attn.set_prefill_kernel(False)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-3, atol=5e-3,
    )


def test_forward_with_prefill_kernel_all_attention_archs(rng):
    """A full smoke forward through the flash kernel for a dense arch."""
    from repro.models import attention as attn
    cfg = get_smoke_config("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(cfg, jax.random.fold_in(rng, 10), 2, 32)
    ref = model.forward(params, batch)
    attn.set_prefill_kernel(True)
    try:
        out = model.forward(params, batch)
    finally:
        attn.set_prefill_kernel(False)
    a = np.asarray(out, np.float32)[..., : cfg.vocab_size]
    r = np.asarray(ref, np.float32)[..., : cfg.vocab_size]
    err = np.max(np.abs(a - r)) / (np.max(np.abs(r)) + 1e-9)
    # bf16 model: kernel vs jnp path round differently per block; drift
    # compounds over layers + unembed (the fp32 single-layer comparison
    # above pins 5e-3).
    assert err < 2e-2, err
