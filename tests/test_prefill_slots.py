"""Batched multi-slot prefill (``transformer.prefill_slots``) property tests.

The contract is BITWISE: prefilling n right-padded prompts into n slots in
one forward must leave the cache and last-position logits exactly equal to
looping ``prefill_slot`` over the same prompts — rows are independent under
causal masking, so padding is invisible and equality is exact, not close.
Runs on the ``tests/_hypothesis_compat.py`` shim (seeded random examples
when hypothesis is absent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as attn
from repro.models import build_model

from tests._hypothesis_compat import given, settings, st

ARCH = "stablelm-1.6b"
MAX_SEQ = 16


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32) for l in lens]


def _run_both(cfg, model, params, lens, window, seed=0):
    n = len(lens)
    num_slots = n + 1  # one live-looking extra row that must stay untouched
    prompts = _prompts(cfg, lens, seed)
    smax = max(lens)
    toks = np.zeros((n, smax), np.int32)
    for j, p in enumerate(prompts):
        toks[j, : p.size] = p
    # spread rows over non-contiguous, unordered slots to exercise the scatter
    slots = np.asarray(
        np.random.default_rng(seed + 1).permutation(num_slots)[:n], np.int32
    )

    cache_b = model.init_slot_cache(params, num_slots, MAX_SEQ, window=window)
    cache_l = model.init_slot_cache(params, num_slots, MAX_SEQ, window=window)
    cache_b, lg_b = model.prefill_slots(
        params, cache_b, jnp.asarray(toks), jnp.asarray(lens, jnp.int32),
        jnp.asarray(slots), window=window,
    )
    rows = []
    for j, p in enumerate(prompts):
        cache_l, lg = model.prefill_slot(
            params, cache_l, jnp.asarray(p[None, :]), int(slots[j]), window=window
        )
        rows.append(lg[0])
    return cache_b, lg_b, cache_l, jnp.stack(rows)


@settings(max_examples=6, deadline=None)
@given(
    lens=st.lists(st.integers(1, MAX_SEQ - 2), min_size=1, max_size=4),
    window=st.sampled_from([0, 5]),
)
def test_batched_prefill_bitwise_matches_looped(model_and_params, lens, window):
    """Random prompt-length mixes, with and without a sliding window (ring
    wrap-around when a length exceeds the window): cache k/v, per-slot pos,
    and last-position logits are bitwise identical to the per-slot loop."""
    cfg, model, params = model_and_params
    cache_b, lg_b, cache_l, lg_l = _run_both(
        cfg, model, params, lens, window, seed=sum(lens) * 31 + window
    )
    np.testing.assert_array_equal(np.asarray(cache_b["k"]), np.asarray(cache_l["k"]))
    np.testing.assert_array_equal(np.asarray(cache_b["v"]), np.asarray(cache_l["v"]))
    np.testing.assert_array_equal(
        np.asarray(cache_b["pos"]), np.asarray(cache_l["pos"])
    )
    np.testing.assert_array_equal(np.asarray(lg_b), np.asarray(lg_l))


def test_fill_cache_rows_matches_sequential_writes(rng):
    """``fill_cache_rows`` leaves every ring row in the exact state that
    row's length sequential one-token writes would — across no-wrap, exact
    fit, and multi-wrap lengths in one padded batch."""
    cfg = get_smoke_config(ARCH)
    cap = 6
    lens = [1, cap - 1, cap, cap + 1, 2 * cap + 3]
    n, smax = len(lens), max(lens)
    hd = cfg.resolved_head_dim
    k = jax.random.normal(rng, (n, smax, cfg.n_kv_heads, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(rng, 1), k.shape, jnp.float32)
    old_k = jax.random.normal(jax.random.fold_in(rng, 2), (n, cap, cfg.n_kv_heads, hd), jnp.float32)
    old_v = jax.random.normal(jax.random.fold_in(rng, 3), old_k.shape, jnp.float32)

    new_k, new_v = attn.fill_cache_rows(old_k, old_v, k, v, jnp.asarray(lens))

    for r, s in enumerate(lens):
        seq = {"k": old_k[r : r + 1], "v": old_v[r : r + 1],
               "pos": jnp.zeros((), jnp.int32)}
        for i in range(s):
            seq = attn.fill_cache(
                seq, k[r : r + 1, i : i + 1], v[r : r + 1, i : i + 1], start=i
            )
        np.testing.assert_array_equal(np.asarray(new_k[r]), np.asarray(seq["k"][0]))
        np.testing.assert_array_equal(np.asarray(new_v[r]), np.asarray(seq["v"][0]))


def test_prefill_slots_leaves_other_slots_untouched(model_and_params):
    """Live rows outside the admitted set keep their k/v and pos bitwise."""
    cfg, model, params = model_and_params
    cache = model.init_slot_cache(params, 3, MAX_SEQ, window=0)
    # make slot 1 "live" first
    p_live = _prompts(cfg, [7], seed=9)[0]
    cache, _ = model.prefill_slot(params, cache, jnp.asarray(p_live[None, :]), 1)
    before_k = np.asarray(cache["k"][:, 1])
    # batched-prefill slots 0 and 2 around it
    ps = _prompts(cfg, [4, 11], seed=10)
    toks = np.zeros((2, 11), np.int32)
    for j, p in enumerate(ps):
        toks[j, : p.size] = p
    cache, _ = model.prefill_slots(
        params, cache, jnp.asarray(toks), jnp.asarray([4, 11], jnp.int32),
        jnp.asarray([0, 2], jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(cache["k"][:, 1]), before_k)
    assert int(cache["pos"][1]) == 7
    assert int(cache["pos"][0]) == 4 and int(cache["pos"][2]) == 11
