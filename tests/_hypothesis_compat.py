"""Property-test shim: real ``hypothesis`` when installed, otherwise a
seeded random-example fallback with the same decorator surface.

The seed suite's property tests use a small, stable slice of the hypothesis
API — ``@given(**strategies)``, ``@settings(max_examples=, deadline=)`` and
the ``st.integers / st.floats / st.lists / st.sampled_from`` strategies.
When hypothesis is absent (this container doesn't ship it and the repo's
rules forbid installing it), the fallback below draws ``max_examples``
deterministic pseudo-random examples per test instead of erroring at
import. It is NOT a shrinker — failures report the drawn example in the
assertion message and are reproducible from the fixed per-test seed.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw, boundary=None):
            self._draw = draw
            # boundary examples tried before random ones (min/max probing)
            self._boundary = boundary or []

        def example(self, rng: random.Random, index: int):
            if index < len(self._boundary):
                return self._boundary[index]
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: rng.randint(min_value, max_value),
                boundary=[min_value, max_value],
            )

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, allow_infinity=False):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value),
                boundary=[min_value, max_value],
            )

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: rng.choice(elements), boundary=elements[:2]
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng, i + 2) for i in range(n)]

            return _Strategy(
                draw,
                boundary=[
                    [elements.example(random.Random(0), 0)] * max(min_size, 1),
                    [elements.example(random.Random(1), 1)] * max_size,
                ],
            )

    st = _Strategies()

    def settings(max_examples: int = 20, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            n_examples = getattr(fn, "_compat_max_examples", 20)
            # stable per-test seed so failures reproduce across runs
            # (str hash() is salted per process; crc32 is not)
            seed = zlib.crc32(fn.__qualname__.encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(seed)
                for i in range(n_examples):
                    drawn = {
                        name: strat.example(rng, i)
                        for name, strat in strategies.items()
                    }
                    try:
                        fn(*args, **drawn, **kwargs)
                    except AssertionError as e:
                        raise AssertionError(
                            f"falsifying example ({fn.__qualname__}, "
                            f"example {i}): {drawn!r}"
                        ) from e

            # hide the strategy-filled params from pytest's fixture
            # resolution: the wrapper's visible signature is the original
            # minus the given() kwargs (mirrors hypothesis behavior).
            sig = inspect.signature(fn)
            kept = [
                p for name, p in sig.parameters.items() if name not in strategies
            ]
            wrapper.__signature__ = sig.replace(parameters=kept)
            return wrapper

        return deco
