"""Hot-path perf machinery of the serve engine: shape-bucketed prefill
(bounded jit specializations), zero-copy donated cache stepping, and the
paged decode kernel threaded end-to-end.

Everything here is behavior-pinned the same way as test_engine.py: the
optimizations must be INVISIBLE in the tokens — only the compile counters,
buffer lifetimes, and dispatch counts may change."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.engine import (
    Request,
    ServeEngine,
    bucket_length,
    bucket_width,
    make_requests,
)

ARCH = "stablelm-1.6b"
G = 4  # generated tokens per request


@pytest.fixture(scope="module")
def model_and_params():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _build(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_seq", 32)
    return ServeEngine(model, params, **kw)


def _reqs(cfg, lens, *, uid0=0, gen=G, seed=0):
    """One request per entry of ``lens``, sliced from a shared corpus draw."""
    base = make_requests(
        cfg, n_requests=len(lens), prompt_len=max(lens), gen_tokens=gen,
        seed=seed,
    )
    return [
        Request(uid=uid0 + j, prompt=r.prompt[: lens[j]], max_new_tokens=gen)
        for j, r in enumerate(base)
    ]


# ------------------------------------------------------------ bucket helpers
def test_bucket_ladders():
    assert [bucket_width(n, 4) for n in (1, 2, 3, 4)] == [1, 2, 4, 4]
    assert [bucket_width(n, 6) for n in (1, 3, 5, 6)] == [1, 4, 6, 6]
    assert [bucket_length(s) for s in (1, 8, 9, 16, 17, 100)] == [
        8, 8, 16, 16, 32, 128,
    ]


# ------------------------------------------------------------ recompile guard
def test_recompile_guard_many_round_shapes(model_and_params):
    """≥ 20 distinct (round width, round max length) admission shapes must
    compile ``prefill_slots`` at most bucket-ladder-many times."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, num_slots=4)
    lens = [3, 5, 7, 9, 11, 13]
    shapes = [(w, l) for w in (1, 2, 3, 4) for l in lens][:21]
    assert len(shapes) >= 20
    uid = 0
    for w, l in shapes:
        # exactly one admission round of width w (all slots free each run)
        engine.run(_reqs(cfg, [l] * w, uid0=uid))
        uid += w
    n_buckets = len(
        {(bucket_width(w, 4), bucket_length(l)) for w, l in shapes}
    )
    compiled = engine.compiles["prefill_slots"]
    assert compiled <= n_buckets, (
        f"{len(shapes)} round shapes compiled prefill_slots {compiled} "
        f"times; bucket ladder allows {n_buckets}"
    )
    assert compiled < len(shapes)  # the unbucketed path would hit this
    # decode stays one specialization throughout
    assert engine.compiles["decode"] == 1

    # warm() has already covered every bucket: more traffic, zero new traces
    before = engine.compiles["prefill_slots"]
    engine.run(_reqs(cfg, [4, 6, 12], uid0=uid))
    assert engine.compiles["prefill_slots"] == before


def test_unbucketed_engine_compiles_per_shape(model_and_params):
    """Contrast fixture: bucket_prefill=False really does specialize per
    distinct round shape (the pre-bucketing behavior the guard exists for)."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, num_slots=4, bucket_prefill=False)
    shapes = [(1, 3), (1, 5), (2, 3), (2, 5), (3, 7)]
    for j, (w, l) in enumerate(shapes):
        engine.run(_reqs(cfg, [l] * w, uid0=100 * j))
    assert engine.compiles["prefill_slots"] == len(shapes)


# ------------------------------------------------------- bucket boundaries
@pytest.mark.parametrize("lens", [
    [8],            # exactly at the ladder floor
    [16],           # exactly at a ladder edge (no padding added)
    [9],            # one past an edge (max padding)
    [8, 16, 9],     # mixed round: pads to bucket_length(16) == 16
])
def test_bucketed_tokens_identical_at_ladder_edges(model_and_params, lens):
    cfg, _, _ = model_and_params
    a = _build(model_and_params).run(_reqs(cfg, lens))
    b = _build(model_and_params, bucket_prefill=False).run(_reqs(cfg, lens))
    for oa, ob in zip(a, b):
        assert oa.uid == ob.uid and oa.tokens == ob.tokens, f"uid {oa.uid}"


def test_one_row_rounds_identical(model_and_params):
    """Width-1 rounds pad to width bucket 1 — no padding rows at all — and
    staggered singleton admissions stay token-identical."""
    cfg, _, _ = model_and_params
    lens = [5, 11, 7]
    outs = {}
    for bucketed in (True, False):
        engine = _build(model_and_params, num_slots=1, bucket_prefill=bucketed)
        outs[bucketed] = engine.run(_reqs(cfg, lens))
    for oa, ob in zip(outs[True], outs[False]):
        assert oa.uid == ob.uid and oa.tokens == ob.tokens


def test_rounds_larger_than_slot_pool_identical(model_and_params):
    """More simultaneous requests than slots: rounds cap at the free-slot
    count, retirement backfills, and bucketing stays invisible."""
    cfg, _, _ = model_and_params
    lens = [3, 8, 5, 16, 9, 12, 7]  # 7 requests through 2 slots
    outs = {}
    for bucketed in (True, False):
        engine = _build(model_and_params, num_slots=2, bucket_prefill=bucketed)
        outs[bucketed] = engine.run(_reqs(cfg, lens))
        assert engine.cache["k"].shape[1] == 2  # pool never grew
    for oa, ob in zip(outs[True], outs[False]):
        assert oa.uid == ob.uid and oa.tokens == ob.tokens


def test_padding_rows_leave_live_slots_untouched(model_and_params):
    """A width-bucketed round (3 claimed → width 4) aims its padding row at
    a live slot; that slot's pos and ring rows must not move."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, num_slots=4)
    # occupy slot 0 with a long-running request
    engine.submit(_reqs(cfg, [6], gen=16)[0])
    engine.step()
    pos_before = int(engine.cache["pos"][0])
    k_before = np.asarray(engine.cache["k"][:, 0])
    # burst of 3 → claimed slots 1,2,3, width bucket 4 → padding row on slot 0
    for r in _reqs(cfg, [5, 5, 5], uid0=10, gen=1):
        engine.submit(r)
    engine._admit(engine._now(), respect_arrivals=False)
    assert int(engine.cache["pos"][0]) == pos_before
    np.testing.assert_array_equal(np.asarray(engine.cache["k"][:, 0]), k_before)
    engine.run()  # drain cleanly


# ------------------------------------------------------------- donation audit
def test_donated_cache_buffers_die_each_step(model_and_params):
    """Zero-copy stepping: the pre-step k/v buffers are consumed by the
    jitted step (donated), not kept alive as copy sources."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params)
    engine.submit(_reqs(cfg, [6], gen=3)[0])
    old_k, old_v = engine.cache["k"], engine.cache["v"]
    engine.step()  # admission round: donated prefill_slots consumes them
    assert old_k.is_deleted() and old_v.is_deleted()
    old_k, old_v = engine.cache["k"], engine.cache["v"]
    engine.step()  # decode step: donated decode consumes them
    assert old_k.is_deleted() and old_v.is_deleted()
    engine.run()


def test_no_donate_keeps_buffers(model_and_params):
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, donate_cache=False)
    engine.submit(_reqs(cfg, [6], gen=2)[0])
    old_k = engine.cache["k"]
    engine.step()
    assert not old_k.is_deleted()
    engine.run()


def test_donation_is_invisible_in_tokens(model_and_params):
    cfg, _, _ = model_and_params
    lens = [5, 9, 13, 7, 11]
    a = _build(model_and_params, num_slots=2).run(_reqs(cfg, lens))
    b = _build(model_and_params, num_slots=2, donate_cache=False).run(
        _reqs(cfg, lens)
    )
    for oa, ob in zip(a, b):
        assert oa.uid == ob.uid and oa.tokens == ob.tokens


# -------------------------------------------------------- paged decode engine
def test_paged_engine_matches_unpaged_kernel_engine(model_and_params):
    """use_kernel + paged_decode end-to-end == the unpaged kernel engine —
    slots at mixed depths (staggered admissions) exercise per-slot spans."""
    cfg, _, _ = model_and_params
    lens = [4, 12, 6, 16, 9]
    outs = {}
    for paged in (True, False):
        engine = _build(
            model_and_params, num_slots=2, use_kernel=True, paged_decode=paged
        )
        outs[paged] = engine.run(_reqs(cfg, lens, gen=G))
    for oa, ob in zip(outs[True], outs[False]):
        assert oa.uid == ob.uid and oa.tokens == ob.tokens


def test_paged_engine_matches_jnp_engine_windowed(model_and_params):
    """Sliding-window ring (wrap during prefill) through the paged kernel
    matches the jnp production path token-for-token."""
    cfg, _, _ = model_and_params
    lens = [8, 5, 8, 7]
    kern = _build(
        model_and_params, num_slots=2, window=6, use_kernel=True,
        paged_decode=True,
    ).run(_reqs(cfg, lens))
    ref = _build(model_and_params, num_slots=2, window=6).run(_reqs(cfg, lens))
    for oa, ob in zip(kern, ref):
        assert oa.uid == ob.uid and oa.tokens == ob.tokens


# ------------------------------------------------- paged-cache engine perf
def test_paged_cache_compile_gate(model_and_params):
    """CI regression gate: the PAGED engine stays within the SAME
    bucket-ladder compile bound as the ring engine — page tables ride the
    cache pytree (constant shapes), so memory paging must add zero jit
    specializations. ≥ 20 distinct admission shapes, bucket-many compiles,
    decode compiled exactly once."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, num_slots=4, paged_cache=True,
                    page_size=8)
    lens = [3, 5, 7, 9, 11, 13]
    shapes = [(w, l) for w in (1, 2, 3, 4) for l in lens][:21]
    assert len(shapes) >= 20
    uid = 0
    for w, l in shapes:
        engine.run(_reqs(cfg, [l] * w, uid0=uid))
        uid += w
    n_buckets = len(
        {(bucket_width(w, 4), bucket_length(l)) for w, l in shapes}
    )
    compiled = engine.compiles["prefill_slots"]
    assert compiled <= n_buckets, (
        f"paged engine compiled prefill_slots {compiled} times over "
        f"{len(shapes)} round shapes; bucket ladder allows {n_buckets}"
    )
    assert engine.compiles["decode"] == 1
    # covered buckets stay covered: more traffic, zero new traces
    before = engine.compiles["prefill_slots"]
    engine.run(_reqs(cfg, [4, 6, 12], uid0=uid))
    assert engine.compiles["prefill_slots"] == before


def test_prefix_suffix_rounds_stay_in_bucket_ladder(model_and_params):
    """Compile-count gate for PREFIX SHARING: suffix-only prefill rounds
    bucket their (width, padded SUFFIX length) exactly like full prompts,
    so the prefix engine's total prefill_slots specializations stay inside
    cold-ladder + suffix-ladder — NOT one per distinct (suffix length,
    start) pair (starts ride in as a traced array)."""
    import numpy as np

    cfg, _, _ = model_and_params
    engine = _build(model_and_params, num_slots=4, paged_cache=True,
                    page_size=4, prefix_cache=True, prefix_cache_pages=16)
    rng = np.random.default_rng(0)
    common = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)  # 4 pages
    # cold round: publishes the common prefix
    engine.run([Request(uid=0, prompt=common, max_new_tokens=2)])
    cold_shapes = {(bucket_width(1, 4), bucket_length(16))}
    # many suffix rounds: distinct (width, suffix length, start) combos —
    # every row hits the 4 shared pages, suffixes prefill from start 16
    suffix_shapes = set()
    uid = 1
    for w, sl in [(1, 3), (1, 5), (2, 3), (2, 7), (3, 5), (4, 9), (2, 11),
                  (1, 9), (3, 11), (4, 3)]:
        reqs = []
        for j in range(w):
            tail = rng.integers(1, cfg.vocab_size, sl).astype(np.int32)
            reqs.append(Request(uid=uid, max_new_tokens=2,
                                prompt=np.concatenate([common, tail])))
            uid += 1
        engine.run(reqs)
        suffix_shapes.add((bucket_width(w, 4), bucket_length(sl)))
    assert engine.prefix_hit_pages > 0, "suffix rounds must actually hit"
    # split dispatch: cold rounds trace prefill_slots, hit rounds trace
    # prefill_suffix — each bounded by its OWN ladder. Every suffix round
    # here hits the same 4 shared pages, so all land in one prefix-pages
    # bucket (bucket_pages(4, t_w) = 4) and the suffix ladder is exactly
    # the (width, length) bucket set.
    compiled_cold = engine.compiles["prefill_slots"]
    compiled_suffix = engine.compiles["prefill_suffix"]
    assert compiled_cold <= len(cold_shapes), (
        f"cold trace compiled {compiled_cold} times; ladder allows "
        f"{len(cold_shapes)}"
    )
    assert compiled_suffix <= len(suffix_shapes), (
        f"suffix trace compiled {compiled_suffix} times; "
        f"width×length ladder (one start bucket) allows {len(suffix_shapes)}"
    )
    assert engine.compiles["decode"] == 1
    # covered buckets stay covered: repeat traffic, zero new traces
    before = engine.prefill_compiles
    tail = rng.integers(1, cfg.vocab_size, 4).astype(np.int32)
    engine.run([Request(uid=uid, max_new_tokens=2,
                        prompt=np.concatenate([common, tail]))])
    assert engine.prefill_compiles == before


def test_paged_cache_donation(model_and_params):
    """Zero-copy stepping holds for the paged pool too: pre-step pool
    buffers are consumed by the donated jits, and donation stays invisible
    in the tokens."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, paged_cache=True, page_size=8)
    engine.submit(_reqs(cfg, [6], gen=3)[0])
    old_k, old_v = engine.cache["k"], engine.cache["v"]
    engine.step()  # admission round: donated prefill_slots consumes them
    assert old_k.is_deleted() and old_v.is_deleted()
    old_k, old_v = engine.cache["k"], engine.cache["v"]
    engine.step()  # decode step: donated decode consumes them
    assert old_k.is_deleted() and old_v.is_deleted()
    engine.run()

    lens = [5, 9, 13, 7, 11]
    a = _build(model_and_params, num_slots=2, paged_cache=True,
               page_size=8).run(_reqs(cfg, lens))
    b = _build(model_and_params, num_slots=2, paged_cache=True, page_size=8,
               donate_cache=False).run(_reqs(cfg, lens))
    for oa, ob in zip(a, b):
        assert oa.uid == ob.uid and oa.tokens == ob.tokens
