"""Suffix-prefill flash kernel + hit/cold round splitting.

Two layers of contract:

KERNEL (TestSuffixKernel): the Pallas table-reading kernel
(kernels/flash_suffix_prefill.py) must match the displaced jnp
gather-concat oracle (``ref.suffix_prefill_ref`` — bitwise the production
path prefix sharing shipped with) across page-table layouts: shared /
aliased pages between rows, CoW-split private copies, scattered physical
placement, mixed starts including 0 (cold rows) and mid-page values, and
every covering prefix-width bucket. Tolerances follow the flash_prefill
suite (reassociation: 2e-5 f32, 2e-2 bf16).

ENGINE: split admission must be INVISIBLE IN THE OUTPUT — a round mixing
cold and hit rows is token-identical to admitting the same requests
all-cold or all-hit, the fully-cached-prompt CoW corner included — while
cold rounds compile and dispatch ZERO suffix traces, and preemption-resume
re-admissions never inflate the external prefix hit rate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.kernels import ops, ref
from repro.launch.engine import Request, ServeEngine, bucket_pages

ARCH = "stablelm-1.6b"
PS = 4  # page size used throughout the engine tests


@pytest.fixture(scope="module")
def model_and_params():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _build(model_and_params, *, prefix=True, **kw):
    _, model, params = model_and_params
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_seq", 32)
    kw.setdefault("paged_cache", True)
    kw.setdefault("page_size", PS)
    if prefix:
        kw.setdefault("prefix_cache_pages", 16)
    return ServeEngine(model, params, prefix_cache=prefix, **kw)


def _assert_same_tokens(a, b):
    got = {o.uid: o.tokens for o in b}
    assert len(a) == len(b)
    for o in a:
        assert o.tokens == got[o.uid], f"uid {o.uid}: {o.tokens} != {got[o.uid]}"


# ------------------------------------------------------------------- ladder
def test_bucket_pages_ladder():
    assert [bucket_pages(p, 8) for p in (0, 1, 2, 3, 4, 5, 8)] == [
        1, 1, 2, 4, 4, 8, 8,
    ]
    assert bucket_pages(100, 8) == 8      # capped at the table width
    assert bucket_pages(0, 0) == 1        # degenerate table still covers


# ------------------------------------------------------------ kernel oracle
def _rand_case(key, *, n, s, hkv, g, hd, n_pool, t_w, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    return (
        jax.random.normal(ks[0], (n, s, hkv, g, hd), dtype),
        jax.random.normal(ks[1], (n, s, hkv, hd), dtype),
        jax.random.normal(ks[2], (n, s, hkv, hd), dtype),
        jax.random.normal(ks[3], (n_pool, PS, hkv, hd), dtype),
        jax.random.normal(ks[4], (n_pool, PS, hkv, hd), dtype),
    )


class TestSuffixKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("s,g,hd", [(8, 1, 32), (16, 2, 64), (32, 4, 32)])
    def test_sweep_vs_ref(self, dtype, s, g, hd):
        n, hkv, t_w, n_pool = 3, 2, 8, 24
        q, ksuf, vsuf, pk, pv = _rand_case(
            jax.random.PRNGKey(s * g + hd), n=n, s=s, hkv=hkv, g=g, hd=hd,
            n_pool=n_pool, t_w=t_w, dtype=dtype,
        )
        # scattered placement; row 2 is COLD (starts 0, table all-scratch)
        table = jnp.array([
            [5, 17, 3, 21, 9, 0, 0, 0],
            [5, 17, 11, 2, 0, 0, 0, 0],
            [0, 0, 0, 0, 0, 0, 0, 0],
        ], jnp.int32)
        starts = jnp.array([19, 16, 0], jnp.int32)  # mid-page, aligned, cold
        w = bucket_pages(-(-19 // PS), t_w)
        out = ops.suffix_prefill_attention(
            q, ksuf, vsuf, pk, pv, table, starts,
            prefix_width=w, use_kernel=True,
        )
        exp = ref.suffix_prefill_ref(q, ksuf, vsuf, pk, pv, table, starts)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=tol, atol=tol,
        )

    def test_aliased_and_cow_pages(self):
        """Rows SHARING physical pages (prefix hit) next to a row holding a
        CoW-split private copy of the same logical page — layout must be
        pure indirection, invisible in the output."""
        n, s, hkv, g, hd, t_w, n_pool = 4, 8, 2, 2, 32, 6, 16
        q, ksuf, vsuf, pk, pv = _rand_case(
            jax.random.PRNGKey(7), n=n, s=s, hkv=hkv, g=g, hd=hd,
            n_pool=n_pool, t_w=t_w,
        )
        # rows 0/1 alias pages (3, 8); row 2's last page CoW-split to 12;
        # row 3 aliases only the first shared page
        table = jnp.array([
            [3, 8, 0, 0, 0, 0],
            [3, 8, 5, 0, 0, 0],
            [3, 12, 0, 0, 0, 0],
            [3, 0, 0, 0, 0, 0],
        ], jnp.int32)
        starts = jnp.array([8, 12, 7, 4], jnp.int32)
        out = ops.suffix_prefill_attention(
            q, ksuf, vsuf, pk, pv, table, starts,
            prefix_width=bucket_pages(3, t_w), use_kernel=True,
        )
        exp = ref.suffix_prefill_ref(q, ksuf, vsuf, pk, pv, table, starts)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_all_cold_rows(self):
        """starts == 0 everywhere: the prefix phase is fully dead and the
        kernel must reduce to plain causal flash over the suffix."""
        n, s, hkv, g, hd, t_w, n_pool = 2, 16, 2, 2, 32, 4, 8
        q, ksuf, vsuf, pk, pv = _rand_case(
            jax.random.PRNGKey(3), n=n, s=s, hkv=hkv, g=g, hd=hd,
            n_pool=n_pool, t_w=t_w,
        )
        table = jnp.zeros((n, t_w), jnp.int32)
        starts = jnp.zeros((n,), jnp.int32)
        out = ops.suffix_prefill_attention(
            q, ksuf, vsuf, pk, pv, table, starts,
            prefix_width=1, use_kernel=True,
        )
        exp = ref.flash_prefill_ref(q, ksuf, vsuf, causal=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    @pytest.mark.parametrize("w", [2, 4, 8])
    def test_every_covering_width_bucket_agrees(self, w):
        """Any static width that covers max(starts) pages must produce the
        same output — dead pages past each row's live prefix contribute
        exactly-zero probability mass."""
        n, s, hkv, g, hd, t_w, n_pool = 2, 8, 1, 2, 64, 8, 20
        q, ksuf, vsuf, pk, pv = _rand_case(
            jax.random.PRNGKey(w), n=n, s=s, hkv=hkv, g=g, hd=hd,
            n_pool=n_pool, t_w=t_w,
        )
        table = jnp.array([
            [7, 2, 19, 4, 11, 0, 0, 0],
            [7, 2, 0, 0, 0, 0, 0, 0],
        ], jnp.int32)
        starts = jnp.array([6, 5], jnp.int32)   # 2 pages max
        out = ops.suffix_prefill_attention(
            q, ksuf, vsuf, pk, pv, table, starts,
            prefix_width=w, use_kernel=True,
        )
        exp = ref.suffix_prefill_ref(q, ksuf, vsuf, pk, pv, table, starts)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_bounded_ref_matches_full_ref(self):
        """The width-bounded oracle == the full-table oracle whenever the
        bound covers every live prefix (the engine's bucket contract)."""
        n, s, hkv, g, hd, t_w, n_pool = 3, 8, 2, 1, 32, 8, 16
        q, ksuf, vsuf, pk, pv = _rand_case(
            jax.random.PRNGKey(11), n=n, s=s, hkv=hkv, g=g, hd=hd,
            n_pool=n_pool, t_w=t_w,
        )
        table = jnp.arange(1, 1 + n * t_w, dtype=jnp.int32).reshape(n, t_w) % n_pool
        starts = jnp.array([5, 0, 8], jnp.int32)
        full = ref.suffix_prefill_ref(q, ksuf, vsuf, pk, pv, table, starts)
        bounded = ref.suffix_prefill_ref(
            q, ksuf, vsuf, pk, pv, table, starts, prefix_width=2
        )
        np.testing.assert_allclose(
            np.asarray(bounded), np.asarray(full), rtol=1e-6, atol=1e-6
        )

    @given(
        s0=st.integers(0, 31), s1=st.integers(0, 31),
        seed=st.integers(0, 50),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_arbitrary_starts(self, s0, s1, seed):
        """Kernel == oracle for arbitrary per-row starts (0, mid-page,
        page-aligned, full-table) at the bucketed covering width."""
        n, s, hkv, g, hd, t_w, n_pool = 2, 8, 1, 1, 32, 8, 34
        q, ksuf, vsuf, pk, pv = _rand_case(
            jax.random.PRNGKey(seed), n=n, s=s, hkv=hkv, g=g, hd=hd,
            n_pool=n_pool, t_w=t_w,
        )
        table = (
            1 + jax.random.permutation(
                jax.random.PRNGKey(seed + 1), n_pool - 1
            )[: n * t_w].reshape(n, t_w)
        ).astype(jnp.int32)
        starts = jnp.array([s0, s1], jnp.int32)
        w = bucket_pages(-(-max(s0, s1) // PS), t_w)
        out = ops.suffix_prefill_attention(
            q, ksuf, vsuf, pk, pv, table, starts,
            prefix_width=w, use_kernel=True,
        )
        exp = ref.suffix_prefill_ref(q, ksuf, vsuf, pk, pv, table, starts)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )


# --------------------------------------------------------- engine contract
def _shared_reqs(cfg, suffix_lens, *, prefix_tokens=16, gen=4, uid0=0,
                 seed=0):
    rng = np.random.default_rng(seed)
    common = rng.integers(1, cfg.vocab_size, prefix_tokens).astype(np.int32)
    reqs = []
    for j, sl in enumerate(suffix_lens):
        tail = rng.integers(1, cfg.vocab_size, sl).astype(np.int32)
        prompt = np.concatenate([common, tail]) if sl else common.copy()
        reqs.append(Request(uid=uid0 + j, prompt=prompt, max_new_tokens=gen))
    return reqs


def _cold_reqs(cfg, lens, *, gen=4, uid0=100, seed=9):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=uid0 + j,
                prompt=rng.integers(1, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=gen)
        for j, L in enumerate(lens)
    ]


def test_cold_rounds_trace_and_dispatch_zero_suffix(model_and_params):
    """A prefix-sharing engine serving cold-only traffic must never touch
    the suffix path: zero prefill_suffix compiles, zero suffix
    dispatches — cold rows pay exactly the non-sharing engine's cost."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params)
    engine.run(_cold_reqs(cfg, [5, 9, 13, 7], seed=1))
    engine.run(_cold_reqs(cfg, [6, 11], uid0=200, seed=2))
    assert engine.compiles["prefill_suffix"] == 0
    assert engine.pool_stats["suffix_dispatches"] == 0
    assert engine.pool_stats["cold_dispatches"] >= 2
    assert engine.pool_stats["prefix_cache_enabled"]


def test_mixed_round_splits_into_cold_and_hit_dispatch(model_and_params):
    cfg, _, _ = model_and_params
    engine = _build(model_and_params)
    engine.run(_shared_reqs(cfg, [4]))           # publish the prefix
    base_cold = engine.pool_stats["cold_dispatches"]
    # one admission round mixing 2 hits with 2 cold rows
    mixed = _shared_reqs(cfg, [3, 7], uid0=10) + _cold_reqs(cfg, [6, 9])
    engine.run(mixed)
    ps = engine.pool_stats
    assert ps["suffix_dispatches"] >= 1
    assert ps["cold_dispatches"] >= base_cold + 1
    assert engine.compiles["prefill_suffix"] >= 1


def test_mixed_round_token_identical_to_split_admission(model_and_params):
    """Satellite: a round mixing starts == 0 and starts > 0 rows emits
    exactly the tokens of all-cold + all-hit admission of the same
    requests, CoW fully-cached corner (suffix_start = len(feed)-1)
    included."""
    cfg, _, _ = model_and_params
    # uid 20 re-sends the EXACT published prompt → fully cached prompt,
    # suffix_start = len(feed) - 1, CoW split of the final page
    hit_rows = lambda: _shared_reqs(cfg, [0, 5], uid0=20)
    cold_rows = lambda: _cold_reqs(cfg, [7, 12])

    mixed_engine = _build(model_and_params)
    mixed_engine.run(_shared_reqs(cfg, [4]))
    mixed = mixed_engine.run(hit_rows() + cold_rows())
    assert mixed_engine.cow_copies > 0, "fully-cached corner must CoW"
    assert mixed_engine.pool_stats["suffix_dispatches"] >= 1

    split_engine = _build(model_and_params)
    split_engine.run(_shared_reqs(cfg, [4]))
    split = split_engine.run(hit_rows()) + split_engine.run(cold_rows())
    _assert_same_tokens(mixed, split)

    # and the non-sharing engine remains the outer oracle
    ref_engine = _build(model_and_params, prefix=False)
    ref_engine.run(_shared_reqs(cfg, [4]))
    ref_out = ref_engine.run(hit_rows() + cold_rows())
    _assert_same_tokens(mixed, ref_out)


@given(
    n_hit=st.integers(1, 3), n_cold=st.integers(1, 3),
    sl=st.integers(0, 11), cl=st.integers(1, 15),
)
@settings(max_examples=6, deadline=None)
def test_property_mixed_rounds_token_identical(
    model_and_params, n_hit, n_cold, sl, cl
):
    """Bucket-ladder edges included: widths and lengths land on and around
    the pow2 boundaries as hypothesis varies row counts and lengths."""
    cfg, _, _ = model_and_params
    hit_rows = lambda: _shared_reqs(
        cfg, [sl + j for j in range(n_hit)], uid0=10
    )
    cold_rows = lambda: _cold_reqs(cfg, [cl + j for j in range(n_cold)])

    mixed_engine = _build(model_and_params)
    mixed_engine.run(_shared_reqs(cfg, [4]))
    mixed = mixed_engine.run(hit_rows() + cold_rows())

    split_engine = _build(model_and_params)
    split_engine.run(_shared_reqs(cfg, [4]))
    split = split_engine.run(hit_rows()) + split_engine.run(cold_rows())
    _assert_same_tokens(mixed, split)


def test_suffix_kernel_engine_token_identity(model_and_params):
    """use_kernel=True routes hit rounds through the Pallas suffix kernel
    (plus paged decode); tokens must equal the jnp engine's bitwise."""
    cfg, _, _ = model_and_params
    outs = []
    for uk in (False, True):
        engine = _build(model_and_params, use_kernel=uk)
        engine.run(_shared_reqs(cfg, [4]))
        outs.append(engine.run(
            _shared_reqs(cfg, [0, 3, 7], uid0=10) + _cold_reqs(cfg, [6])
        ))
        if uk:
            assert engine.pool_stats["suffix_dispatches"] >= 1
    _assert_same_tokens(outs[0], outs[1])


def test_resume_hits_excluded_from_external_hit_rate(model_and_params):
    """Satellite: preemption-resume re-admissions (feed = prompt +
    generated) must not inflate prefix_hit_rate — the tight engine (with
    preemptions) reports the SAME external hit rate as a roomy engine
    serving identical traffic, with the resume savings tracked
    separately."""
    cfg, _, _ = model_and_params

    def traffic(engine):
        engine.run(_shared_reqs(cfg, [4], gen=2))        # publish prefix
        return engine.run(_shared_reqs(cfg, [2, 5], uid0=10, gen=10))

    roomy = _build(model_and_params, num_slots=2, max_seq=40)
    r_out = traffic(roomy)
    assert roomy.preemptions == 0

    # pool sized so decoding both hits past the prompt runs out of pages
    tight = _build(model_and_params, num_slots=2, max_seq=40, num_pages=10,
                   prefix_cache_pages=4)
    t_out = traffic(tight)
    assert tight.preemptions > 0, "pool must force preempt -> resume"
    assert tight.pool_stats["prefix_resume_hit_tokens"] > 0, (
        "resume re-admission must land in the resume counter"
    )
    _assert_same_tokens(t_out, r_out)
    assert tight.pool_stats["prefix_lookup_tokens"] == \
        roomy.pool_stats["prefix_lookup_tokens"]
    assert tight.pool_stats["prefix_hit_rate"] == pytest.approx(
        roomy.pool_stats["prefix_hit_rate"]
    )
