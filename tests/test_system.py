"""End-to-end behaviour tests for the cross-cloud federated training system:
the paper's headline claims, reproduced at smoke scale."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model


def train(aggregation, steps=60, beta=0.05, compression="none", seed=0,
          local_steps=2, lr=3e-3, arch="stablelm-1.6b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.1)
    mix = dirichlet_mixtures(jax.random.PRNGKey(42), 3, 4, beta=beta)
    fed = FederatedConfig(
        n_clouds=3, local_steps=local_steps, aggregation=aggregation,
        compression=compression, topk_ratio=0.05,
    )
    trainer = FederatedTrainer(model, fed, TrainConfig(steps=steps, lr=lr, warmup_steps=5))
    state = trainer.init_state(jax.random.PRNGKey(seed))
    step = jax.jit(trainer.train_step)
    losses, accs = [], []
    for i in range(steps):
        batch = federated_batch(
            corpus, jax.random.fold_in(jax.random.PRNGKey(seed + 1), i), mix, 4, 32
        )
        arrived = jnp.asarray([(i // local_steps) % 3 == j for j in range(3)])
        state, m = step(state, batch, arrived, jnp.full((3,), 0.5))
        losses.append(float(m["loss"]))
        accs.append(float(m["accuracy"]))
    return trainer, state, losses, accs


@pytest.mark.slow
def test_paper_claim_dynamic_beats_fedavg_on_noniid():
    """Table 3's qualitative claim at smoke scale: dynamic weighted
    aggregation converges at least as well as FedAvg under non-IID data."""
    _, _, l_fed, _ = train("fedavg", steps=80)
    _, _, l_dyn, _ = train("dynamic", steps=80)
    assert np.mean(l_dyn[-10:]) <= np.mean(l_fed[-10:]) + 0.05


def test_paper_claim_compression_cuts_comm_overhead():
    """Table 2's claim: compressed sync moves far fewer bytes."""
    t_none, s_none, l_none, _ = train("fedavg", steps=30)
    t_topk, s_topk, l_topk, _ = train("fedavg", steps=30, compression="topk")
    b_none = t_none.sync_bytes_per_cloud(s_none["global"]["params"])
    b_topk = t_topk.sync_bytes_per_cloud(s_topk["global"]["params"])
    assert b_topk < b_none / 10
    assert np.isfinite(l_topk[-1])


def test_all_aggregators_produce_finite_learning():
    for aggregation in ("fedavg", "dynamic", "gradient", "async"):
        _, _, losses, _ = train(aggregation, steps=20)
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0] + 0.1


def test_train_cli_runs(tmp_path):
    out = tmp_path / "r.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "stablelm-1.6b",
         "--steps", "6", "--aggregation", "gradient", "--json-out", str(out)],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": os.environ.get("HOME", "/tmp"),
             # containers with libtpu installed: without this pin the
             # subprocess probes the (absent) TPU via GCP metadata HTTP
             # retries for minutes before falling back to CPU
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert out.exists()


def test_serve_cli_runs():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "xlstm-125m",
         "--batch", "2", "--prompt-len", "8", "--gen", "4"],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": os.environ.get("HOME", "/tmp"),
             # containers with libtpu installed: without this pin the
             # subprocess probes the (absent) TPU via GCP metadata HTTP
             # retries for minutes before falling back to CPU
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    """The dry-run machinery end-to-end on an 8-device host mesh (fast).

    Patches the production mesh down to (2,2,2)/(2,2) inside the subprocess
    so the full lower/compile/roofline path runs in seconds."""
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import repro.launch.mesh as meshlib
meshlib.make_production_mesh = lambda multi_pod=False: (
    jax.make_mesh((2,2,2), ("pod","data","model")) if multi_pod
    else jax.make_mesh((2,2), ("data","model")))
import repro.launch.dryrun as dr
import repro.configs as C, dataclasses
# shrink the shape so the smoke config compiles in seconds
C.base.INPUT_SHAPES["train_4k"] = dataclasses.replace(
    C.base.INPUT_SHAPES["train_4k"], seq_len=64, global_batch=8)
import repro.configs.stablelm_1_6b as S
orig = S.smoke_config
def patched():
    return dataclasses.replace(orig(), name="stablelm-1.6b")
dr.get_config = lambda a: patched()
rec = dr.dryrun_pair("stablelm-1.6b", "train_4k", multi_pod=False)
assert rec["roofline"]["compute_s"] > 0
rec2 = dr.dryrun_pair("stablelm-1.6b", "train_4k", multi_pod=True)
assert rec2["roofline"]["dcn_link_bytes"] > 0, "no cross-pod traffic found"
print("DRYRUN_OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=580,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": os.environ.get("HOME", "/tmp"),
             # containers with libtpu installed: without this pin the
             # subprocess probes the (absent) TPU via GCP metadata HTTP
             # retries for minutes before falling back to CPU
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "DRYRUN_OK" in r.stdout
