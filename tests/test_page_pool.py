"""PagePool allocator unit tests (launch/engine.py).

The allocator is pure host-side bookkeeping, so these tests are exact: the
free list is a LIFO stack (most recently freed pages are reused first),
page 0 is reserved scratch and never handed out, allocation is
all-or-nothing, and accounting survives arbitrary interleavings of
retire/admit — "fragmentation" cannot strand capacity because pages carry
no adjacency: any free page serves any slot.
"""
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.launch.engine import PagePool


def test_fresh_pool_allocates_ascending():
    pool = PagePool(num_pages=6, page_size=4)
    assert pool.capacity == 5
    assert pool.alloc(3) == [1, 2, 3]
    assert pool.alloc(2) == [4, 5]
    assert pool.available == 0 and pool.in_use == 5


def test_scratch_page_never_allocated():
    pool = PagePool(num_pages=4, page_size=8)
    seen = set()
    for _ in range(3):
        pages = pool.alloc(1)
        seen.update(pages)
    assert pool.alloc(1) is None  # exhausted without ever touching page 0
    assert 0 not in seen
    assert seen == {1, 2, 3}


def test_lifo_reuse_order():
    """Freed pages come back most-recently-freed first — the documented
    free-list discipline (cache-warm reuse)."""
    pool = PagePool(num_pages=8, page_size=4)
    a = pool.alloc(3)          # [1, 2, 3]
    b = pool.alloc(2)          # [4, 5]
    pool.free(a)               # stack: ..., 6?, no — [7, 6] remain + 1, 2, 3
    pool.free(b)
    # LIFO: the last pages freed (b, pushed 4 then 5) pop first, reversed
    assert pool.alloc(2) == [5, 4]
    assert pool.alloc(3) == [3, 2, 1]
    # the never-allocated tail follows in ascending order
    assert pool.alloc(2) == [6, 7]


def test_alloc_is_all_or_nothing():
    pool = PagePool(num_pages=4, page_size=4)
    assert pool.alloc(2) == [1, 2]
    before = pool.available
    assert pool.alloc(2) is None       # only 1 page left
    assert pool.available == before    # no partial allocation leaked
    assert pool.alloc(1) == [3]


def test_double_free_rejected():
    pool = PagePool(num_pages=4, page_size=4)
    pages = pool.alloc(2)
    pool.free(pages)
    with pytest.raises(ValueError, match="free"):
        pool.free(pages[:1])
    with pytest.raises(ValueError, match="free"):
        pool.free([3])  # never allocated


def test_peak_tracking():
    pool = PagePool(num_pages=6, page_size=4)
    a = pool.alloc(4)
    assert pool.peak_in_use == 4
    pool.free(a)
    pool.alloc(2)
    assert pool.peak_in_use == 4  # peak is monotone
    assert pool.in_use == 2


def test_fragmentation_interleaved_retire_admit():
    """Interleaved multi-slot alloc/free ("fragmentation"): pages freed by
    one slot are immediately reusable by any other, accounting stays exact,
    and the full capacity remains reachable in one allocation afterwards."""
    pool = PagePool(num_pages=11, page_size=4)   # 10 allocatable
    slots = {i: pool.alloc(2) for i in range(5)}  # pool exhausted
    assert pool.available == 0
    pool.free(slots.pop(1))  # retire slots 1 and 3 — holes between live
    pool.free(slots.pop(3))
    got = pool.alloc(4)      # a new slot spans both "holes"
    assert sorted(got) == [3, 4, 7, 8]  # exactly the retired slots' pages
    pool.free(got)
    for pages in slots.values():
        pool.free(pages)
    assert pool.available == pool.capacity and pool.in_use == 0
    # no stranded capacity: one allocation can take everything back
    assert len(pool.alloc(10)) == 10


@given(
    ops=st.lists(st.integers(0, 5), min_size=1, max_size=40),
    num_pages=st.integers(3, 17),
)
@settings(max_examples=25, deadline=None)
def test_property_accounting_invariants(ops, num_pages):
    """Random alloc/free interleavings: in_use + available == capacity,
    allocation succeeds iff enough pages are free, page 0 never appears,
    and no page is ever held twice."""
    pool = PagePool(num_pages=num_pages, page_size=4)
    held = []
    for op in ops:
        if op == 0 and held:
            pool.free(held.pop())
        else:
            n = (op % 3) + 1
            pages = pool.alloc(n)
            if n <= pool.capacity - sum(len(h) for h in held):
                assert pages is not None
            if pages is None:
                continue
            assert 0 not in pages
            held.append(pages)
        flat = [p for h in held for p in h]
        assert len(flat) == len(set(flat))
        assert pool.in_use == len(flat)
        assert pool.in_use + pool.available == pool.capacity
    for h in held:
        pool.free(h)
    assert pool.available == pool.capacity


# ------------------------------------------------------------- refcounting
def test_share_increments_free_decrements():
    """A shared page survives frees until its LAST reference drops —
    alloc rc=1, each share +1, each free -1, rc==0 returns it."""
    pool = PagePool(num_pages=4, page_size=4)
    (p,) = pool.alloc(1)
    assert pool.refcount(p) == 1
    assert pool.share(p) == 2
    assert pool.share(p) == 3
    pool.free([p])
    pool.free([p])
    assert pool.refcount(p) == 1
    assert pool.in_use == 1  # still live: one owner left
    pool.free([p])
    assert pool.refcount(p) == 0
    assert pool.in_use == 0 and pool.available == pool.capacity


def test_share_of_free_page_rejected():
    """rc-underflow guard: a page on the free list may be re-allocated at
    any time, so sharing it is a hard error, never a silent rc=1."""
    pool = PagePool(num_pages=4, page_size=4)
    with pytest.raises(ValueError, match="share"):
        pool.share(1)  # never allocated
    (p,) = pool.alloc(1)
    pool.free([p])
    with pytest.raises(ValueError, match="share"):
        pool.share(p)  # was live, now free again


def test_overfree_shared_page_rejected():
    """Double-free guard counts references: free may be called exactly
    refcount times, one more raises."""
    pool = PagePool(num_pages=4, page_size=4)
    (p,) = pool.alloc(1)
    pool.share(p)
    pool.free([p])
    pool.free([p])
    with pytest.raises(ValueError, match="free"):
        pool.free([p])


def test_lifo_reuse_preserved_for_rc0_pages():
    """Shared pages do NOT enter the free list at intermediate frees; only
    the rc==0 transition pushes, keeping LIFO order exact."""
    pool = PagePool(num_pages=6, page_size=4)
    a = pool.alloc(2)          # [1, 2]
    b = pool.alloc(1)          # [3]
    pool.share(a[0])           # page 1 rc=2
    pool.free(a)               # page 1 rc=1 (not pushed), page 2 freed
    pool.free(b)               # page 3 freed
    assert pool.alloc(2) == [3, 2]  # LIFO; page 1 still live
    assert pool.refcount(a[0]) == 1
    pool.free([a[0]])          # rc 0 now — becomes most recently freed
    assert pool.alloc(1) == [a[0]]


def test_live_refs_counts_shares():
    pool = PagePool(num_pages=8, page_size=4)
    pages = pool.alloc(3)
    pool.share(pages[0])
    pool.share(pages[0])
    pool.share(pages[2])
    assert pool.in_use == 3
    assert pool.live_refs == 6  # 3 + 2 extra + 1 extra


def test_cross_tier_demote_promote_refcounts_exact():
    """Refcount safety across the host-tier boundary: demoting an indexed
    page drops only the INDEX's ref (a live co-reader keeps the page
    resident — the tier gets a copy, never the page), and promotion
    materializes a FRESH rc=1 page rather than resurrecting the old id.
    No ref is leaked or double-freed end to end."""
    from repro.launch.prefix_cache import PrefixCache

    pool = PagePool(num_pages=8, page_size=2)
    host: dict[tuple, int] = {}  # fake tier: prefix tokens -> demoted page

    def demote(prefix, page):
        host[prefix] = page  # the engine copies bytes; the id suffices here

    def promote(prefix):
        if prefix not in host:
            return None
        pages = pool.alloc(1)
        if pages is None:
            return None
        host.pop(prefix)
        return pages[0]

    cache = PrefixCache(pool, max_pages=1, demote_fn=demote,
                        promote_fn=promote)
    toks = [7, 7]
    (p,) = pool.alloc(1)          # slot A writes the page…
    cache.insert(toks, [p])       # …and publishes it: index ref
    assert pool.refcount(p) == 2
    (hit,) = cache.match(toks)    # slot B maps the hit and takes its ref
    assert hit == p
    pool.share(p)
    assert pool.refcount(p) == 3
    (q,) = pool.alloc(1)          # a different prefix at max_pages=1:
    cache.insert([9, 9], [q])     # inserting evicts p's node → demote
    assert host == {(7, 7): p}
    # eviction dropped exactly the index's ref; both slots keep the page
    assert pool.refcount(p) == 2 and pool.in_use == 2
    pool.free([p])                # slot A retires
    pool.free([p])                # slot B retires — NOW the page dies
    assert pool.refcount(p) == 0
    # radix miss promotes the demoted copy into a fresh rc=1 page whose
    # ref belongs to the index (the tier entry is consumed)
    (promoted,) = cache.match(toks)
    assert (7, 7) not in host and cache.size == 1
    assert pool.refcount(promoted) == 1
    # adopting the promoted node at the cap evicted q's node (demote), so
    # q now lives only through its slot's ref — and q's content moved to
    # the tier in the same motion
    assert pool.refcount(q) == 1 and host == {(9, 9): q}
    pool.free([q])
    cache.clear()                 # drops the index's promoted-page ref
    assert pool.in_use == 0 and pool.live_refs == 0
    assert pool.available == pool.capacity


@given(
    ops=st.lists(st.integers(0, 9), min_size=1, max_size=60),
    num_pages=st.integers(3, 13),
)
@settings(max_examples=25, deadline=None)
def test_property_refcount_invariants(ops, num_pages):
    """Random admit/share/retire/evict sequences against a reference
    refcount map: every allocatable page is either free or live
    (capacity == available + pages-with-refs), the pool's counts match the
    model exactly, Σ live refs ≥ live pages, and page 0 never escapes."""
    pool = PagePool(num_pages=num_pages, page_size=4)
    refs: dict[int, int] = {}  # reference model: page -> expected rc
    for op in ops:
        live = sorted(refs)
        if op < 3 and live:      # retire: drop one ref from some page
            p = live[op % len(live)]
            pool.free([p])
            refs[p] -= 1
            if refs[p] == 0:
                del refs[p]
        elif op < 6 and live:    # share: one more view of some page
            p = live[op % len(live)]
            pool.share(p)
            refs[p] += 1
        else:                    # admit: allocate 1-2 fresh pages
            n = (op % 2) + 1
            pages = pool.alloc(n)
            if len(refs) + n <= pool.capacity:
                assert pages is not None
            if pages is None:
                continue
            for p in pages:
                assert p != 0 and p not in refs
                refs[p] = 1
        assert pool.in_use == len(refs)
        assert pool.available + pool.in_use == pool.capacity
        assert pool.live_refs == sum(refs.values())
        assert pool.live_refs >= pool.in_use
        for p, rc in refs.items():
            assert pool.refcount(p) == rc
    for p, rc in list(refs.items()):
        pool.free([p] * rc)
    assert pool.available == pool.capacity and pool.live_refs == 0
