"""int8 KV pages: quantize helpers, dequantizing kernels, engine behavior.

The oracle layering follows the house rules:

* The fp kernels running over the DEQUANTIZED pool are the BITWISE oracle
  for the int8 kernels — in-body dequant is ``q · scale`` cast to the
  query dtype, exactly what ``ref.dequant_pool_ref`` materializes, so the
  int8 kernel must equal the fp kernel fed that materialized pool bit for
  bit (same chunking, same online-softmax association).
* The jnp dequant refs (``paged_table_decode_int8_ref``,
  ``suffix_prefill_int8_ref``) are the NUMERIC oracle (flash reassociates;
  allclose at the suite's usual tolerances).
* The fp engine is the TOLERANCE oracle for the int8 engine: quantized KV
  legitimately moves logits, so the engine pin is a greedy-token agreement
  floor on a fixed trace plus exact self-consistency (int8 preemption/
  resume must be bitwise-identical to an uncontended int8 run).

``int8_encode``/``int8_roundtrip`` pad-and-slice (arbitrary row counts)
is property-tested through ``tests/_hypothesis_compat``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.kernels import ops, ref
from repro.kernels.flash_suffix_prefill import suffix_prefill
from repro.kernels.paged_decode import paged_decode
from repro.kernels.quantize import (
    BLOCK,
    int8_encode,
    int8_roundtrip,
    kv_dequant,
    kv_quant,
)
from repro.launch.engine import Request, ServeEngine, make_requests

ARCH = "stablelm-1.6b"
P, G = 8, 6


# ------------------------------------------------------------ quantize math
def test_kv_quant_roundtrip_error_bound():
    """Symmetric per-vector int8: reconstruction error ≤ scale/2 per
    element (round-to-nearest over a 254-step grid)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 7, 64), jnp.float32)
    q, s = kv_quant(x)
    assert q.dtype == jnp.int8 and s.shape == (5, 7)
    err = np.abs(np.asarray(kv_dequant(q, s)) - np.asarray(x))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-7
    assert (err <= bound).all()


def test_kv_quant_matches_encode_ref_rows():
    """kv_quant over (nb, 256) rows IS the wire encoder's row math."""
    x = jax.random.normal(jax.random.PRNGKey(1), (11, BLOCK), jnp.float32)
    q, s = kv_quant(x)
    qr, sr = ref.int8_encode_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr)[:, 0])


@given(nb=st.integers(1, 40))
@settings(max_examples=15, deadline=None)
def test_property_encode_pad_and_slice(nb):
    """Arbitrary row counts (page-shaped callers): the padded kernel's
    sliced output matches the per-row reference — q bitwise, scales to
    1-ulp (the suite's idiom for the interpret pipeline's division) — and
    padding rows never leak into real rows."""
    x = jax.random.normal(jax.random.PRNGKey(nb), (nb, BLOCK), jnp.float32)
    q, s = int8_encode(x, interpret=True)
    assert q.shape == (nb, BLOCK) and s.shape == (nb, 1)
    qr, sr = ref.int8_encode_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@given(nb=st.integers(1, 40))
@settings(max_examples=15, deadline=None)
def test_property_roundtrip_pad_and_slice(nb):
    x = jax.random.normal(
        jax.random.PRNGKey(100 + nb), (nb, BLOCK), jnp.float32
    )
    out = int8_roundtrip(x, interpret=True)
    assert out.shape == (nb, BLOCK)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.int8_roundtrip_ref(x)),
        rtol=1e-6, atol=1e-9,
    )


# ------------------------------------------------------- int8 decode kernel
def _int8_pool_case(key, *, n, cap, page, hkv=2, g=4, hd=64,
                    dtype=jnp.float32):
    """Random queries + a quantized scattered page pool with its fp
    mirror: (q, pos→caller, int8 pools + scales, dequantized pools,
    table)."""
    t_w = cap // page
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (n, hkv, g, hd), dtype)
    n_pool = 1 + n * t_w
    pool_k = jax.random.normal(ks[1], (n_pool, page, hkv, hd), jnp.float32)
    pool_v = jax.random.normal(ks[2], (n_pool, page, hkv, hd), jnp.float32)
    qk, sk = kv_quant(pool_k)
    qv, sv = kv_quant(pool_v)
    perm = jax.random.permutation(ks[3], n * t_w)
    table = (1 + perm).reshape(n, t_w).astype(jnp.int32)
    deq_k = ref.dequant_pool_ref(qk, sk, dtype)
    deq_v = ref.dequant_pool_ref(qv, sv, dtype)
    return q, (qk, qv, sk, sv), (deq_k, deq_v), table


DECODE_CASES = [
    (256, [0, 10, 255, 300, 1000], 0),
    (256, [0, 10, 255, 300, 1000], 64),
    (512, [3, 511, 512, 700, 1537], 128),
]
PAGE = 64


class TestInt8Decode:
    @pytest.mark.parametrize("cap,poss,window", DECODE_CASES)
    def test_kernel_bitwise_matches_fp_kernel_on_dequant_pool(
        self, cap, poss, window
    ):
        """In-body dequant is invisible: the int8 table kernel == the fp
        table kernel over the materialized dequantized pool, bit for bit."""
        q, (qk, qv, sk, sv), (dk, dv), table = _int8_pool_case(
            jax.random.PRNGKey(cap + window), n=len(poss), cap=cap, page=PAGE
        )
        pos = jnp.asarray(poss, jnp.int32)
        out = paged_decode(
            q, qk, qv, pos, window, table=table, k_scale=sk, v_scale=sv
        )
        exp = paged_decode(q, dk, dv, pos, window, table=table)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    @pytest.mark.parametrize("cap,poss,window", DECODE_CASES)
    def test_kernel_close_to_int8_ref(self, cap, poss, window):
        q, (qk, qv, sk, sv), _, table = _int8_pool_case(
            jax.random.PRNGKey(3 * cap + window), n=len(poss), cap=cap,
            page=PAGE,
        )
        pos = jnp.asarray(poss, jnp.int32)
        out = paged_decode(
            q, qk, qv, pos, window, table=table, k_scale=sk, v_scale=sv
        )
        exp = ref.paged_table_decode_int8_ref(
            q, qk, qv, sk, sv, pos, table, window
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=3e-5, atol=3e-5
        )

    def test_int8_ref_bitwise_is_dequant_then_plain_ref(self):
        """The int8 oracle is definitionally dequant→gather→ring oracle —
        pinned so the oracle itself can't drift from the dequant scheme."""
        q, (qk, qv, sk, sv), (dk, dv), table = _int8_pool_case(
            jax.random.PRNGKey(17), n=3, cap=256, page=PAGE
        )
        pos = jnp.asarray([5, 100, 700], jnp.int32)
        a = ref.paged_table_decode_int8_ref(q, qk, qv, sk, sv, pos, table, 0)
        b = ref.paged_table_decode_ref(q, dk, dv, pos, table, 0)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bf16_queries_dequant_to_bf16(self):
        """The kernel dequantizes to the QUERY dtype (what the bf16 engine
        stores logically): bitwise vs. the fp kernel over a bf16-dequant
        pool."""
        q, (qk, qv, sk, sv), _, table = _int8_pool_case(
            jax.random.PRNGKey(23), n=2, cap=256, page=PAGE,
            dtype=jnp.bfloat16,
        )
        dk = ref.dequant_pool_ref(qk, sk, jnp.bfloat16)
        dv = ref.dequant_pool_ref(qv, sv, jnp.bfloat16)
        pos = jnp.asarray([30, 400], jnp.int32)
        out = paged_decode(
            q, qk, qv, pos, 64, table=table, k_scale=sk, v_scale=sv
        )
        exp = paged_decode(q, dk, dv, pos, 64, table=table)
        np.testing.assert_array_equal(
            np.asarray(out, np.float32), np.asarray(exp, np.float32)
        )

    def test_ops_routes_int8_table_mode(self):
        q, (qk, qv, sk, sv), (dk, dv), table = _int8_pool_case(
            jax.random.PRNGKey(31), n=2, cap=256, page=PAGE
        )
        pos = jnp.asarray([9, 300], jnp.int32)
        for use_kernel in (False, True):
            out = ops.swa_decode_attention(
                q, qk, qv, pos, 0, use_kernel=use_kernel, table=table,
                k_scale=sk, v_scale=sv,
            )
            exp = ops.swa_decode_attention(
                q, dk, dv, pos, 0, use_kernel=use_kernel, table=table
            )
            np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    def test_scales_require_table_mode(self):
        """Scales without a page table are a caller bug, not a silent
        fp read of int8 bytes."""
        q, (qk, qv, sk, sv), _, _ = _int8_pool_case(
            jax.random.PRNGKey(37), n=2, cap=256, page=PAGE
        )
        with pytest.raises(AssertionError):
            paged_decode(
                q, qk, qv, jnp.asarray([5, 6], jnp.int32), 0,
                k_scale=sk, v_scale=sv,
            )


# ------------------------------------------------- int8 suffix-prefill kernel
class TestInt8SuffixPrefill:
    def _case(self, key, dtype=jnp.float32):
        n, s, hkv, g, hd, page, t_w, n_pool = 3, 8, 2, 2, 32, 4, 8, 24
        ks = jax.random.split(key, 5)
        q = jax.random.normal(ks[0], (n, s, hkv, g, hd), dtype)
        ksuf = jax.random.normal(ks[1], (n, s, hkv, hd), dtype)
        vsuf = jax.random.normal(ks[2], (n, s, hkv, hd), dtype)
        pk = jax.random.normal(ks[3], (n_pool, page, hkv, hd), jnp.float32)
        pv = jax.random.normal(ks[4], (n_pool, page, hkv, hd), jnp.float32)
        qk, sk = kv_quant(pk)
        qv, sv = kv_quant(pv)
        # scattered placement, shared page 5 between rows 0/1, row 2 cold
        table = jnp.array([
            [5, 17, 3, 21, 9, 2, 7, 11],
            [5, 17, 13, 4, 0, 0, 0, 0],
            [0, 0, 0, 0, 0, 0, 0, 0],
        ], jnp.int32)
        starts = jnp.array([19, 16, 0], jnp.int32)
        return q, ksuf, vsuf, (qk, qv, sk, sv), table, starts

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_kernel_bitwise_matches_fp_kernel_on_dequant_pool(self, dtype):
        q, ksuf, vsuf, (qk, qv, sk, sv), table, starts = self._case(
            jax.random.PRNGKey(41), dtype
        )
        dk = ref.dequant_pool_ref(qk, sk, dtype)
        dv = ref.dequant_pool_ref(qv, sv, dtype)
        out = suffix_prefill(
            q, ksuf, vsuf, qk, qv, table, starts, prefix_width=5,
            pool_k_scale=sk, pool_v_scale=sv,
        )
        exp = suffix_prefill(
            q, ksuf, vsuf, dk, dv, table, starts, prefix_width=5
        )
        np.testing.assert_array_equal(
            np.asarray(out, np.float32), np.asarray(exp, np.float32)
        )

    def test_kernel_close_to_int8_ref(self):
        q, ksuf, vsuf, (qk, qv, sk, sv), table, starts = self._case(
            jax.random.PRNGKey(43)
        )
        out = suffix_prefill(
            q, ksuf, vsuf, qk, qv, table, starts, prefix_width=5,
            pool_k_scale=sk, pool_v_scale=sv,
        )
        exp = ref.suffix_prefill_int8_ref(
            q, ksuf, vsuf, qk, qv, sk, sv, table, starts
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_ops_routes_int8_suffix(self):
        q, ksuf, vsuf, (qk, qv, sk, sv), table, starts = self._case(
            jax.random.PRNGKey(47)
        )
        dk = ref.dequant_pool_ref(qk, sk, jnp.float32)
        dv = ref.dequant_pool_ref(qv, sv, jnp.float32)
        for use_kernel in (False, True):
            out = ops.suffix_prefill_attention(
                q, ksuf, vsuf, qk, qv, table, starts, prefix_width=5,
                pool_k_scale=sk, pool_v_scale=sv, use_kernel=use_kernel,
            )
            exp = ops.suffix_prefill_attention(
                q, ksuf, vsuf, dk, dv, table, starts, prefix_width=5,
                use_kernel=use_kernel,
            )
            np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


# ------------------------------------------------------------ engine layer
@pytest.fixture(scope="module")
def model_and_params():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _build(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_seq", P + G)
    return ServeEngine(model, params, **kw)


def _reqs(cfg, lens, *, gen=G, seed=0):
    base = make_requests(
        cfg, n_requests=len(lens), prompt_len=max(lens), gen_tokens=gen,
        seed=seed,
    )
    return [
        Request(uid=j, prompt=r.prompt[: lens[j]], max_new_tokens=gen)
        for j, r in enumerate(base)
    ]


def _assert_same_tokens(a, b):
    got = {o.uid: o.tokens for o in b}
    assert len(a) == len(b)
    for o in a:
        assert o.tokens == got[o.uid], f"uid {o.uid}: {o.tokens} != {got[o.uid]}"


def test_engine_rejects_int8_without_paged_cache(model_and_params):
    with pytest.raises(ValueError, match="paged"):
        _build(model_and_params, kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        _build(model_and_params, paged_cache=True, kv_dtype="int4")


def test_int8_pool_layout_and_stats(model_and_params):
    cfg, _, _ = model_and_params
    eng = _build(
        model_and_params, paged_cache=True, page_size=4, kv_dtype="int8"
    )
    assert eng.cache["k"].dtype == jnp.int8
    assert eng.cache["ks"].dtype == jnp.float32
    # one scale per (layer, page, token slot, kv head)
    assert eng.cache["ks"].shape == eng.cache["k"].shape[:-1]
    assert eng.pool_stats["kv_dtype"] == "int8"
    fp = _build(model_and_params, paged_cache=True, page_size=4)
    assert fp.pool_stats["kv_dtype"] == "fp"
    assert "ks" not in fp.cache


def test_int8_engine_token_agreement_vs_fp(model_and_params):
    """Tolerance pin: quantized KV may move a logit across a tie, but on
    the fixed smoke trace greedy outputs must agree on a large majority of
    requests (exact agreement is seed-stable; the floor leaves room for
    tie-flips only)."""
    cfg, _, _ = model_and_params
    lens = [4, 8, 3, 7, 6]
    fp = _build(model_and_params, paged_cache=True, page_size=4)
    i8 = _build(
        model_and_params, paged_cache=True, page_size=4, kv_dtype="int8"
    )
    ref_outs = {o.uid: o.tokens for o in fp.run(_reqs(cfg, lens))}
    outs = i8.run(_reqs(cfg, lens))
    agree = sum(o.tokens == ref_outs[o.uid] for o in outs) / len(outs)
    assert agree >= 0.6, f"int8 engine agreed on only {agree:.0%} of requests"
    for o in outs:  # every request still ran to its full budget
        assert len(o.tokens) == G


def test_int8_preemption_resume_bitwise_self_consistent(model_and_params):
    """Within int8, memory pressure must stay invisible: a preempting
    tight pool emits the SAME tokens as an uncontended int8 run — the
    resume path re-prefills into freshly quantized pages deterministically
    (masked requantization keeps scales bit-stable)."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7]
    ample = _build(
        model_and_params, paged_cache=True, page_size=4, kv_dtype="int8"
    )
    ref_outs = ample.run(_reqs(cfg, lens))
    tight = _build(
        model_and_params, paged_cache=True, page_size=4, kv_dtype="int8",
        num_pages=6,
    )
    outs = tight.run(_reqs(cfg, lens))
    assert tight.preemptions > 0, "tight pool must preempt"
    _assert_same_tokens(outs, ref_outs)
    assert tight.pool.in_use == 0


def test_int8_prefix_sharing_token_identical_to_int8_cold(model_and_params):
    """Prefix sharing over int8 pages: aliasing quantized pages is pure
    placement, so warm == cold within the int8 engine, bitwise."""
    cfg, _, _ = model_and_params
    shared = _reqs(cfg, [P, P], gen=4)
    shared[1] = Request(uid=1, prompt=shared[0].prompt, max_new_tokens=4)

    def run(prefix):
        eng = _build(
            model_and_params, paged_cache=True, page_size=4,
            kv_dtype="int8", num_slots=1, prefix_cache=prefix,
        )
        outs = eng.run([Request(uid=r.uid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)
                        for r in shared])
        return eng, outs

    warm_eng, warm = run(True)
    _, cold = run(False)
    assert warm_eng.pool_stats["prefix_hit_rate"] > 0
    _assert_same_tokens(warm, cold)


def test_paged_cache_specs_int8_shapes(model_and_params):
    """Dry-run specs mirror the quantized pool: int8 payload + fp32 scale
    planes at 1/head_dim the page bytes."""
    from repro.launch.specs import paged_cache_specs

    cfg, model, _ = model_and_params
    specs = paged_cache_specs(
        model, num_slots=3, num_pages=9, page_size=4, table_width=8,
        kv_dtype="int8",
    )
    assert specs["k"].dtype == jnp.int8
    assert specs["ks"].dtype == jnp.float32
    assert specs["ks"].shape == specs["k"].shape[:-1]
    assert specs["vs"].shape == specs["v"].shape[:-1]
