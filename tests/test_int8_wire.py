"""int8-on-the-wire cross-pod aggregation (beyond-paper): must match the
dense weighted average within int8 quantization error, and the compiled HLO
must carry the payload as s8. Runs in a subprocess with 8 virtual devices."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.aggregation import int8_wire_weighted_average, weighted_average

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
key = jax.random.PRNGKey(0)
tree = {
    "w": jax.random.normal(key, (2, 256, 256), jnp.float32),     # (clouds, d1, d2)
    "b": jax.random.normal(jax.random.fold_in(key, 1), (2, 16), jnp.float32),
    "s": jnp.asarray([1.5, -0.5], jnp.float32),                  # per-cloud scalar
}
specs = {"w": P("data", "model"), "b": P("model"), "s": P()}
weights = jnp.asarray([0.3, 0.7], jnp.float32)

placed = {
    k: jax.device_put(v, NamedSharding(mesh, P("pod", *specs[k])))
    for k, v in tree.items()
}
with mesh:
    fn = jax.jit(lambda t, w: int8_wire_weighted_average(
        t, w, pod_axis="pod", mesh=mesh, shard_specs=specs))
    out = fn(placed, weights)
    ref = weighted_average(tree, weights)
    hlo = fn.lower(placed, weights).compile().as_text()

for k in tree:
    a, r = np.asarray(out[k]), np.asarray(ref[k])
    scale = np.max(np.abs(r)) + 1e-9
    err = np.max(np.abs(a - r)) / scale
    # int8 row-wise quantization: relative error bounded by ~1/127 per cloud
    assert err < 0.03, (k, err)
assert " s8[" in hlo, "payload must cross the wire as int8"
print("INT8_WIRE_OK")
"""


def test_int8_wire_matches_dense_average():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": os.environ.get("HOME", "/tmp"),
             # pin CPU: containers with libtpu installed otherwise probe
             # the (absent) TPU via GCP metadata HTTP retries for minutes
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "INT8_WIRE_OK" in r.stdout
