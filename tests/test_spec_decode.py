"""Speculative decoding tests (launch/spec_decode.py + engine spec rounds).

The contract: speculation is a LATENCY optimization that must be invisible
in the tokens. Greedy requests emit the bitwise stream of the non-
speculative engine — the displaced per-token decode path stays as the
oracle — across draft quality (same-params ≈ full acceptance, foreign
params ≈ rejection storm), prefix caching, and int8 pools. Sampled
requests draw EXACTLY from the target distribution (the Leviathan
rejection-sampling guarantee, checked empirically) on deterministic
request-keyed streams. Rejection rollback may never leak or double-free a
pool page. Migration rides along: ``export_inflight`` now carries KV page
content, so a layout-compatible importer swaps migrated requests in
instead of recomputing."""
import dataclasses

import jax
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.launch.engine import Request, ServeEngine, make_requests
from repro.launch.sampling import (
    SamplingParams,
    filter_logits,
    speculative_acceptance,
)

ARCH = "stablelm-1.6b"
P, G = 16, 10


@pytest.fixture(scope="module")
def target():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _draft(arch=ARCH, seed=0):
    from repro.models import build_model

    dcfg = get_smoke_config(arch)
    dm = build_model(dcfg)
    return dm, dm.init(jax.random.PRNGKey(seed))


def _build(target, *, draft=None, spec_tokens=0, **kw):
    _, model, params = target
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_seq", P + G)
    kw.setdefault("paged_cache", True)
    kw.setdefault("page_size", 4)
    dm, dp = draft if draft is not None else (None, None)
    return ServeEngine(
        model, params, draft_model=dm, draft_params=dp,
        spec_tokens=spec_tokens, **kw,
    )


def _reqs(cfg, n=4, *, gen=G, seed=0, shared_prefix=False):
    reqs = make_requests(
        cfg, n_requests=n, prompt_len=P, gen_tokens=gen, seed=seed
    )
    if shared_prefix:
        head = reqs[0].prompt[: P - 2]
        for r in reqs:
            r.prompt = np.concatenate([head, r.prompt[P - 2:]])
    return reqs


def _tokens(outs):
    return {o.uid: o.tokens for o in outs}


# -------------------------------------------------------- greedy identity
@pytest.mark.parametrize("kv_dtype", ["fp", "int8"])
@pytest.mark.parametrize("prefix_cache", [False, True])
@pytest.mark.parametrize("draft_seed", [0, 7])
def test_greedy_bitwise_identity(target, kv_dtype, prefix_cache, draft_seed):
    """Spec engine == plain engine, token for token, whatever the draft
    agrees on: seed 0 shares the target's params (≈100%% acceptance, the
    full-accept + bonus-token path), seed 7 is a foreign model (≈0%%
    acceptance, every round rolls back). Prefix sharing and int8 pools
    must compose — the verify dispatch is the same suffix-prefill trace
    admission uses."""
    cfg = target[0]
    kw = dict(kv_dtype=kv_dtype, prefix_cache=prefix_cache)
    reqs = lambda: _reqs(cfg, 4, shared_prefix=prefix_cache)
    base = _build(target, **kw).run(reqs())
    spec = _build(
        target, draft=_draft(seed=draft_seed), spec_tokens=3, **kw
    ).run(reqs())
    assert _tokens(spec) == _tokens(base)


def test_greedy_identity_xlstm_draft(target):
    """A recurrent (snapshot-rollback) draft must hold the same identity
    as the ring draft — rollback restores the exact pre-round state."""
    cfg = target[0]
    base = _build(target).run(_reqs(cfg, 4))
    spec = _build(
        target, draft=_draft("xlstm-125m", seed=3), spec_tokens=3
    ).run(_reqs(cfg, 4))
    assert _tokens(spec) == _tokens(base)


def test_spec_uses_fewer_target_dispatches(target):
    """The point of the feature: a high-acceptance draft (same params as
    the target) must emit the trace in well under half the target decode
    dispatches the plain engine needs."""
    cfg = target[0]
    plain = _build(target)
    plain.run(_reqs(cfg, 4))
    spec = _build(target, draft=_draft(seed=0), spec_tokens=3)
    spec.run(_reqs(cfg, 4))
    assert spec.pool_stats["spec_accept_rate"] > 0.9
    assert plain.steps >= 1.5 * spec.steps, (plain.steps, spec.steps)


def test_spec_counters(target):
    cfg = target[0]
    eng = _build(target, draft=_draft(seed=7), spec_tokens=3)
    eng.run(_reqs(cfg, 2))
    ps = eng.pool_stats
    assert ps["spec_enabled"] and ps["spec_tokens"] == 3
    assert ps["spec_rounds"] == eng.steps > 0
    # admission prefill emits each request's FIRST token; spec rounds own
    # the rest
    assert ps["spec_emitted"] == 2 * (G - 1)
    assert 0.0 <= ps["spec_accept_rate"] <= 1.0
    assert ps["spec_dispatches_per_token"] <= 1.0
    assert {"spec_verify", "draft_propose", "draft_prefill"} <= set(
        eng.compiles
    )


# ---------------------------------------------------------------- sampling
def test_sampled_deterministic_and_mixed(target):
    """Sampled spec runs are reproducible from request seeds, and greedy
    requests sharing the engine with sampled ones keep bitwise identity
    (their rows never touch the acceptance sampler)."""
    cfg = target[0]
    sp = SamplingParams(temperature=0.9, top_k=12, top_p=0.95)

    def mixed():
        reqs = _reqs(cfg, 4)
        for r in reqs[::2]:
            r.sampling = dataclasses.replace(sp, seed=11 + r.uid)
        return reqs

    a = _build(target, draft=_draft(seed=7), spec_tokens=3).run(mixed())
    b = _build(target, draft=_draft(seed=7), spec_tokens=3).run(mixed())
    assert _tokens(a) == _tokens(b)
    base = _tokens(_build(target).run(_reqs(cfg, 4)))
    for o in a:
        if o.uid % 2 == 1:  # greedy rows
            assert o.tokens == base[o.uid]


def test_acceptance_marginal_matches_target():
    """Leviathan exactness, empirically: whatever the draft proposes, the
    FIRST emitted token's marginal over many keys must match the filtered
    target distribution (accept mass + residual draw reconstruct p)."""
    v = 8
    key = jax.random.PRNGKey(42)
    tgt = jax.random.normal(key, (4, v)) * 2.0
    dq = jax.nn.log_softmax(jax.random.normal(jax.random.fold_in(key, 1), (3, v)))
    draws = 1500
    drafts = jax.vmap(
        lambda k: jax.random.categorical(k, dq, axis=-1)
    )(jax.random.split(jax.random.PRNGKey(9), draws)).astype(np.int32)
    firsts = np.zeros(v)
    for i in range(draws):
        _, emitted = speculative_acceptance(
            jax.random.fold_in(jax.random.PRNGKey(5), i), tgt, drafts[i],
            dq, 3, 1.0, 0, 1.0, v,
        )
        firsts[int(emitted[0])] += 1
    p = np.asarray(jax.nn.softmax(filter_logits(tgt[0], 1.0, 0, 1.0, v)))
    np.testing.assert_allclose(firsts / draws, p, atol=0.05)


@settings(max_examples=20, deadline=None)
@given(
    k_live=st.integers(0, 3),
    temp=st.floats(0.2, 2.0),
    top_k=st.sampled_from([0, 2, 5]),
    seed=st.integers(0, 10**6),
)
def test_acceptance_invariants(k_live, temp, top_k, seed):
    """Structural properties on arbitrary rounds: 1 <= n_emit <=
    k_live + 1, every pre-final emission IS its draft token (only accepted
    drafts are emitted as-is), and all emissions are valid vocab ids."""
    v = 16
    key = jax.random.PRNGKey(seed)
    tgt = jax.random.normal(key, (4, v))
    dq = jax.nn.log_softmax(
        jax.random.normal(jax.random.fold_in(key, 1), (3, v)) / temp
    )
    drafts = jax.random.categorical(
        jax.random.fold_in(key, 2), dq, axis=-1
    ).astype(np.int32)
    n_emit, emitted = speculative_acceptance(
        jax.random.fold_in(key, 3), tgt, drafts, dq, k_live, temp, top_k,
        1.0, v,
    )
    n_emit, emitted = int(n_emit), np.asarray(emitted)
    assert 1 <= n_emit <= k_live + 1
    assert all(0 <= t < v for t in emitted[:n_emit])
    np.testing.assert_array_equal(
        emitted[: n_emit - 1], np.asarray(drafts)[: n_emit - 1]
    )


# -------------------------------------------------------- page accounting
def test_rollback_never_leaks_pages(target):
    """A rejection storm (foreign draft + sampling) allocates and rolls
    back lookahead pages every round; when the trace drains, every page
    must be back in the pool (no prefix index pinning here) and no slot
    may hold stale page refs — leaks and double-frees both fail this."""
    cfg = target[0]
    sp = SamplingParams(temperature=1.2, top_k=0, top_p=1.0)
    eng = _build(
        target, draft=_draft(seed=7), spec_tokens=3, prefix_cache=False,
        num_slots=2, page_size=2,
    )
    reqs = _reqs(cfg, 5)
    for r in reqs:
        r.sampling = dataclasses.replace(sp, seed=3 + r.uid)
    eng.run(reqs)
    assert eng.pool.in_use == 0
    assert all(not p for p in eng._slot_pages)
    ps = eng.pool_stats
    # rejections actually happened, so rollback paths were exercised
    assert ps["spec_accepted"] < ps["spec_drafted"]
    assert ps["spec_accept_rate"] < 1.0


def test_tight_pool_shrinks_lookahead(target):
    """With the pool too small for full lookahead, rounds run shallower
    (down to plain 1-token verifies) instead of preempting or failing —
    output identity must survive the degradation."""
    cfg = target[0]
    kw = dict(num_slots=2, page_size=2, num_pages=2 * ((P + G) // 2) + 2)
    base = _build(target, **kw).run(_reqs(cfg, 3))
    spec = _build(target, draft=_draft(seed=7), spec_tokens=3, **kw)
    assert spec.pool.capacity * 2 < 2 * (P + G) + 2 * 3  # genuinely tight
    assert _tokens(spec.run(_reqs(cfg, 3))) == _tokens(base)


# ------------------------------------------------------------------ gating
def test_gating_errors(target):
    with pytest.raises(ValueError, match="spec_tokens must be >= 1"):
        _build(target, draft=_draft(), spec_tokens=0)
    with pytest.raises(ValueError, match="draft_model and draft_params"):
        _build(target, draft=(None, _draft()[1]), spec_tokens=2)
    with pytest.raises(ValueError, match="paged_cache"):
        _build(target, draft=_draft(), spec_tokens=2, paged_cache=False)
    with pytest.raises(ValueError, match="prefill"):
        _build(
            target, draft=_draft(), spec_tokens=2, prefill="interleaved"
        )


# --------------------------------------------- migration with page content
def test_export_carries_pages_and_import_swaps_in(target):
    """Satellite: ``export_inflight`` no longer strips the host tier —
    live slots gather their pages into the record and an importing engine
    with a matching pool layout adopts them, so the migrated request
    resumes by SWAP-IN (one scatter), not recompute. Token streams must
    merge identically either way."""
    cfg = target[0]
    kw = dict(num_slots=2, host_pages=64, swap=True)
    base = _tokens(_build(target, **kw).run(_reqs(cfg, 3)))

    src = _build(target, **kw)
    for r in _reqs(cfg, 3):
        src.submit(r)
    for _ in range(4):  # leave requests mid-decode
        src.step()
    items = src.export_inflight()
    assert src.pool.in_use == 0
    carried = [
        res for _, res in items
        if res is not None and res.host_arrays is not None
    ]
    assert carried, "live mid-decode slots must carry their KV pages"

    dst = _build(target, **kw)
    dst.import_inflight(items)
    # adoption happened: resumes now point at DST's own host tier
    assert any(
        res.host_key == ("swap", uid)
        for uid, res in ((r.uid, res) for (r, res) in items if res)
        if res.generated
    )
    outs = dst.run()
    assert _tokens(outs) == base
    assert dst.pool_stats["swapped_in_pages"] > 0


def test_import_layout_mismatch_falls_back_to_recompute(target):
    """An int8 importer cannot adopt fp pages (plane sets differ): the
    record's arrays are dropped and the request resumes through the
    recompute path — it must still complete."""
    cfg = target[0]
    src = _build(target, num_slots=2, host_pages=64, swap=True)
    for r in _reqs(cfg, 2):
        src.submit(r)
    for _ in range(3):
        src.step()
    items = src.export_inflight()
    dst = _build(
        target, num_slots=2, host_pages=64, swap=True, kv_dtype="int8"
    )
    dst.import_inflight(items)
    for _, res in items:
        if res is not None:
            assert res.host_key is None and res.host_arrays is None
    outs = dst.run()
    assert len(outs) == 2 and all(len(o.tokens) == G for o in outs)
    assert dst.pool_stats["swapped_in_pages"] == 0


# ------------------------------------- int8 demote dtype pin (satellite)
def test_int8_prefix_demote_preserves_pool_dtypes(target):
    """Pin: demoting a prefix page from an int8 pool stores the int8
    planes AND their fp32 scale planes — a host tier silently holding fp
    pages would scatter garbage back on promotion."""
    cfg = target[0]
    eng = _build(
        target, kv_dtype="int8", prefix_cache=True, host_pages=4,
        prefix_cache_pages=2, page_size=4,
    )
    # DISTINCT prompts: each retirement publishes its own chunk chain, so
    # the 2-page index must LRU-evict across chains (a shared prefix would
    # pin the whole index on the protected insert path and never demote)
    eng.run(_reqs(cfg, 6))
    assert eng.host_demoted_pages > 0, "trace must demote at least one page"
    assert set(eng._kv_names) == {"k", "v", "ks", "vs"}
    for entry in eng.host._entries.values():
        for name, arr in entry["arrays"].items():
            assert arr.dtype == np.dtype(eng.cache[name].dtype), name
