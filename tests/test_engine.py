"""Continuous-batching engine tests (launch/engine.py).

The load-bearing property: iteration-level scheduling over a slot pool must
be *invisible* in the output — every request's greedy tokens are pinned
token-for-token against the sequential single-batch oracle
(``launch/serve.py::serve_batch``), under staggered arrivals, slot reuse,
sliding windows, both prefill modes, and the Pallas decode kernel.
Attention rows are independent, so identical per-row math is exact even in
bf16 — the tests assert equality, not closeness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.engine import Request, ServeEngine, make_requests
from repro.launch.serve import serve_batch

ARCH = "stablelm-1.6b"
P, G = 8, 6  # prompt / generated tokens per request


@pytest.fixture(scope="module")
def oracle():
    """Sequential lockstep serve over 5 requests — rows are the per-uid
    reference outputs (same seed/corpus as make_requests)."""
    return serve_batch(
        ARCH, batch=5, prompt_len=P, gen_tokens=G, seed=0, log_fn=lambda *_: None
    )


def _build(num_slots=2, window=0, use_kernel=False, prefill="chunked",
           max_seq=P + G, batch_prefill=True, time_fn=None, **kw):
    cfg = get_smoke_config(ARCH)
    model_params = getattr(_build, "_cache", None)
    if model_params is None:
        from repro.models import build_model

        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _build._cache = (model, params)
    else:
        model, params = model_params
    return ServeEngine(
        _build._cache[0], _build._cache[1], num_slots=num_slots,
        max_seq=max_seq, window=window, use_kernel=use_kernel, prefill=prefill,
        batch_prefill=batch_prefill, time_fn=time_fn, **kw,
    )


@pytest.mark.parametrize("prefill", ["chunked", "interleaved"])
def test_staggered_arrivals_match_oracle(oracle, prefill):
    """5 requests arriving at different times through 2 slots == oracle."""
    cfg = get_smoke_config(ARCH)
    engine = _build(num_slots=2, prefill=prefill)
    reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)
    for r, dt in zip(reqs, [0.0, 0.0, 0.1, 0.2, 0.5]):
        r.arrival_time = dt
    outs = engine.run(reqs)
    assert [o.uid for o in outs] == list(range(5))
    for o in outs:
        assert o.finish_reason == "length" and len(o.tokens) == G
        assert o.tokens == oracle["generated"][o.uid], (
            f"uid {o.uid} ({prefill}): engine {o.tokens} != "
            f"oracle {oracle['generated'][o.uid]}"
        )


def test_freed_slot_is_reused_and_backfilled(oracle):
    """More requests than slots: a queued request must take over a retired
    request's slot (no new allocation) and still match the oracle."""
    cfg = get_smoke_config(ARCH)
    engine = _build(num_slots=2)
    reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)
    outs = engine.run(reqs)
    slots_used = [o.slot for o in outs]
    assert set(slots_used) <= {0, 1}, "engine must stay inside the slot pool"
    reused = [s for s in {0, 1} if slots_used.count(s) >= 2]
    assert reused, f"5 requests over 2 slots must recycle a slot: {slots_used}"
    # recycled slots still produce oracle-identical output
    for o in outs:
        assert o.tokens == oracle["generated"][o.uid]
    # cache was never reallocated: pool width is still num_slots
    assert engine.cache["k"].shape[1] == 2


def test_heterogeneous_lengths_retire_and_backfill():
    """Requests with different max_new_tokens retire at different steps;
    each output is pinned against its own single-request oracle run."""
    cfg = get_smoke_config(ARCH)
    engine = _build(num_slots=2, max_seq=P + 9)
    base = make_requests(cfg, n_requests=3, prompt_len=P, gen_tokens=G, seed=0)
    lens = [3, 9, 5]
    reqs = [
        Request(uid=r.uid, prompt=r.prompt, max_new_tokens=lens[r.uid])
        for r in base
    ]
    outs = engine.run(reqs)
    assert [len(o.tokens) for o in outs] == lens
    full = serve_batch(
        ARCH, batch=3, prompt_len=P, gen_tokens=9, seed=0, log_fn=lambda *_: None
    )
    for o in outs:
        assert o.tokens == full["generated"][o.uid][: lens[o.uid]]


@pytest.mark.parametrize("prefill", ["chunked", "interleaved"])
def test_sliding_window_matches_non_engine_path(prefill):
    """window > 0: ring cache shrinks to the window; engine output must be
    identical to the sequential serve path with the same window. The chunked
    variant wraps the ring during prefill (prompt > window) — the regression
    that exposed the seed's fill_cache roll-direction bug."""
    w = 6  # smaller than the prompt → the ring actually wraps
    ref = serve_batch(
        ARCH, batch=4, prompt_len=P, gen_tokens=G, window=w, seed=0,
        log_fn=lambda *_: None,
    )
    cfg = get_smoke_config(ARCH)
    engine = _build(num_slots=2, window=w, prefill=prefill)
    reqs = make_requests(cfg, n_requests=4, prompt_len=P, gen_tokens=G, seed=0)
    outs = engine.run(reqs)
    for o in outs:
        assert o.tokens == ref["generated"][o.uid], f"uid {o.uid} (window={w})"
    # the window cache really is window-sized
    assert engine.cache["k"].shape[2] == w


def test_fill_cache_wraparound_matches_sequential_writes(rng):
    """Regression: fill_cache with S > capacity must leave the ring in the
    exact state S sequential one-token writes would (slot = pos % cap). The
    seed rolled the surviving tail the wrong direction."""
    from repro.models import attention as attn

    cfg = get_smoke_config(ARCH)
    cap, s = 6, 8
    hd = cfg.resolved_head_dim
    k = jax.random.normal(rng, (1, s, cfg.n_kv_heads, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(rng, 1), k.shape, jnp.float32)
    empty = {
        "k": jnp.zeros((1, cap, cfg.n_kv_heads, hd), jnp.float32),
        "v": jnp.zeros((1, cap, cfg.n_kv_heads, hd), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }
    bulk = attn.fill_cache(empty, k, v)
    seq = empty
    for i in range(s):
        seq = attn.fill_cache(seq, k[:, i : i + 1], v[:, i : i + 1], start=i)
    np.testing.assert_array_equal(np.asarray(bulk["k"]), np.asarray(seq["k"]))
    np.testing.assert_array_equal(np.asarray(bulk["v"]), np.asarray(seq["v"]))
    assert int(bulk["pos"]) == int(seq["pos"]) == s


def test_decode_kernel_path_matches_oracle(oracle):
    """--use-kernel threads the Pallas flash-decode kernel (interpret mode
    on CPU) through the engine's per-slot cache."""
    cfg = get_smoke_config(ARCH)
    engine = _build(num_slots=2, use_kernel=True)
    reqs = make_requests(cfg, n_requests=3, prompt_len=P, gen_tokens=G, seed=0)
    outs = engine.run(reqs)
    kernel_ref = serve_batch(
        ARCH, batch=3, prompt_len=P, gen_tokens=G, use_kernel=True, seed=0,
        log_fn=lambda *_: None,
    )
    for o in outs:
        assert o.tokens == kernel_ref["generated"][o.uid]


def test_eos_retires_early():
    """A request whose greedy continuation hits eos_id stops there."""
    cfg = get_smoke_config(ARCH)
    full = serve_batch(
        ARCH, batch=2, prompt_len=P, gen_tokens=G, seed=0, log_fn=lambda *_: None
    )
    # pick the 3rd generated token of uid 0 as the "EOS" id
    eos = full["generated"][0][2]
    engine = _build(num_slots=2)
    engine.eos_id = eos
    reqs = make_requests(cfg, n_requests=2, prompt_len=P, gen_tokens=G, seed=0)
    outs = engine.run(reqs)
    o0 = outs[0]
    assert o0.finish_reason == "eos"
    assert o0.tokens == full["generated"][0][:3]  # ends at the EOS token
    # the other request keeps its slot running to full length unless it
    # happens to emit the same id
    o1 = outs[1]
    if eos in full["generated"][1]:
        cut = full["generated"][1].index(eos) + 1
        assert o1.tokens == full["generated"][1][:cut]
    else:
        assert len(o1.tokens) == G


def test_admission_respects_capacity_guard():
    engine = _build(num_slots=1, max_seq=P + G)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        engine.submit(
            Request(uid=0, prompt=np.zeros(P, np.int32), max_new_tokens=G + 1)
        )


def test_slot_cache_specs_shapes():
    """The dry-run spec helper mirrors the engine's per-slot cache layout
    without allocating."""
    from repro.launch.specs import slot_cache_specs
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    specs = slot_cache_specs(model, num_slots=3, max_seq=16, window=0)
    assert specs["pos"].shape == (3,)
    assert specs["k"].shape == (
        cfg.n_layers, 3, 16, cfg.n_kv_heads, cfg.resolved_head_dim
    )
    win = slot_cache_specs(model, num_slots=3, max_seq=16, window=4)
    assert win["k"].shape[2] == 4
    ssm = build_model(get_smoke_config("xlstm-125m"))
    with pytest.raises(ValueError, match="no slot-cache API"):
        slot_cache_specs(ssm, num_slots=2, max_seq=8)


def test_request_timing_fields_monotone():
    cfg = get_smoke_config(ARCH)
    engine = _build(num_slots=2)
    reqs = make_requests(cfg, n_requests=3, prompt_len=P, gen_tokens=G, seed=0)
    outs = engine.run(reqs)
    for o in outs:
        assert o.arrival_time <= o.admit_time <= o.first_token_time <= o.finish_time
        assert o.latency >= 0 and o.ttft >= 0


# ------------------------------------------------- batched multi-slot prefill
def test_burst_one_prefill_dispatch_per_admission_round(oracle):
    """4 simultaneous arrivals through 4 slots: ONE batched prefill_slots
    forward (not 4 per-request dispatches), token-identical to the oracle."""
    cfg = get_smoke_config(ARCH)
    engine = _build(num_slots=4)
    # slice a 5-row draw: rows 0..3 are the oracle fixture's rows 0..3
    reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)[:4]
    outs = engine.run(reqs)
    assert engine.prefill_dispatches == 1, (
        f"burst of 4 must cost one dispatch, got {engine.prefill_dispatches}"
    )
    for o in outs:
        assert o.tokens == oracle["generated"][o.uid]


def test_batched_prefill_matches_per_request_prefill(oracle):
    """batch_prefill on/off is invisible in the greedy output; only the
    dispatch count changes (5 requests / 2 slots: 3 rounds vs 5)."""
    cfg = get_smoke_config(ARCH)
    outs, dispatches = {}, {}
    for batched in (True, False):
        engine = _build(num_slots=2, batch_prefill=batched)
        reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)
        outs[batched] = engine.run(reqs)
        dispatches[batched] = engine.prefill_dispatches
    assert dispatches[False] == 5
    assert dispatches[True] < dispatches[False]
    for a, b in zip(outs[True], outs[False]):
        assert a.uid == b.uid and a.tokens == b.tokens
        assert a.tokens == oracle["generated"][a.uid]


def test_batched_prefill_mixed_prompt_lengths():
    """A round with heterogeneous prompt lengths (padded batch) produces the
    same tokens as per-request prefill of the same requests."""
    cfg = get_smoke_config(ARCH)
    base = make_requests(cfg, n_requests=3, prompt_len=P, gen_tokens=G, seed=0)
    lens = [3, P, 5]

    def reqs():
        return [
            Request(uid=r.uid, prompt=r.prompt[: lens[r.uid]], max_new_tokens=G)
            for r in base
        ]

    engine = _build(num_slots=3, batch_prefill=True)
    ref = _build(num_slots=3, batch_prefill=False)
    a = engine.run(reqs())
    b = ref.run(reqs())
    assert engine.prefill_dispatches == 1 and ref.prefill_dispatches == 3
    for oa, ob in zip(a, b):
        assert oa.tokens == ob.tokens, f"uid {oa.uid}"


@pytest.mark.parametrize("prefill", ["chunked", "interleaved"])
def test_prompt_plus_gen_equals_max_seq_completes(oracle, prefill):
    """Boundary: prompt_len + max_new_tokens == max_seq must be admitted and
    finish full-length with oracle-identical tokens — the full-attention
    ring's last row is written but never wrapped onto a live row."""
    cfg = get_smoke_config(ARCH)
    engine = _build(num_slots=2, max_seq=P + G, prefill=prefill)
    reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)
    outs = engine.run(reqs)
    for o in outs:
        assert o.finish_reason == "length" and len(o.tokens) == G
        assert o.tokens == oracle["generated"][o.uid]
    # a dedicated slot's write head stops exactly at max_seq - 1: the last
    # token was generated without a write to (nonexistent) row max_seq.
    # (In the pooled run above, a retired slot's pos keeps drifting while
    # other slots decode — dead rows, validity-masked on reuse.)
    solo = _build(num_slots=1, max_seq=P + G, prefill=prefill)
    souts = solo.run(
        make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)[:1]
    )
    assert souts[0].tokens == oracle["generated"][0]
    assert int(solo.cache["pos"][0]) == P + G - 1


def test_first_token_time_stamps(oracle):
    """first_token_time marks the first GENERATED token: at admission for
    chunked prefill (step 0), after prompt_len teacher-forced decode steps
    for interleaved — never on a teacher-forced prompt step. Measured on a
    step-indexed clock (time_fn counts executed decode steps)."""
    cfg = get_smoke_config(ARCH)
    for prefill, expect in (("chunked", 0.0), ("interleaved", float(P))):
        holder = {}
        engine = _build(
            num_slots=1, prefill=prefill,
            time_fn=lambda: float(holder["e"].steps) if "e" in holder else 0.0,
        )
        holder["e"] = engine
        # row 0 of the 5-row draw == the oracle fixture's row 0
        reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)[:1]
        out = engine.run(reqs)[0]
        assert out.tokens == oracle["generated"][0]
        assert out.first_token_time == expect, (
            f"{prefill}: first token stamped at step {out.first_token_time}, "
            f"expected {expect}"
        )


def test_watchdog_retires_stuck_slot(oracle):
    """Per-request wall-clock watchdog (``max_wall_s``): a slot older than
    the budget retires with a structured ``timeout`` result carrying its
    partial tokens, and the queue behind it keeps flowing. Step-indexed
    clock: one time unit per executed decode step."""
    cfg = get_smoke_config(ARCH)
    holder = {}
    engine = _build(
        num_slots=1, max_wall_s=3.0,
        time_fn=lambda: float(holder["e"].steps) if "e" in holder else 0.0,
    )
    holder["e"] = engine
    reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)[:2]
    outs = engine.run(reqs)
    assert len(outs) == 2 and not engine.has_work
    assert [o.finish_reason for o in outs] == ["timeout", "timeout"], (
        "a 6-token request cannot beat a 3-step budget"
    )
    assert engine.timeouts == 2
    for o in outs:
        assert 0 < len(o.tokens) < G
        # the partial stream is a PREFIX of the fault-free output
        assert o.tokens == oracle["generated"][o.uid][: len(o.tokens)]


def test_watchdog_ample_budget_never_fires(oracle):
    """A budget the trace fits inside is invisible: identical tokens, zero
    timeouts — the watchdog is pure insurance."""
    cfg = get_smoke_config(ARCH)
    holder = {}
    engine = _build(
        num_slots=2, max_wall_s=100.0,
        time_fn=lambda: float(holder["e"].steps) if "e" in holder else 0.0,
    )
    holder["e"] = engine
    reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)
    outs = engine.run(reqs)
    assert engine.timeouts == 0
    for o in outs:
        assert o.tokens == oracle["generated"][o.uid]
        assert o.finish_reason != "timeout"


def test_deadline_shed_structured(oracle):
    """``deadline_s``: a request still QUEUED past its deadline is shed
    with a structured ``deadline_exceeded`` error instead of wedging the
    queue; an already-decoding request is never shed. Step-indexed
    clock."""
    cfg = get_smoke_config(ARCH)
    holder = {}
    engine = _build(
        num_slots=1,
        time_fn=lambda: float(holder["e"].steps) if "e" in holder else 0.0,
    )
    holder["e"] = engine
    reqs = make_requests(cfg, n_requests=5, prompt_len=P, gen_tokens=G, seed=0)[:3]
    # uid0 occupies the only slot for ~G steps; uid1's deadline expires
    # while it waits; uid2 (no deadline) must still be served
    reqs[0].deadline_s = 100.0   # admitted immediately — decoding exempt
    reqs[1].deadline_s = 2.0
    outs = engine.run(reqs)
    assert [o.uid for o in outs] == [0, 2]
    assert engine.shed_requests == 1
    assert [e.uid for e in engine.shed] == [1]
    assert engine.shed[0].reason == "deadline_exceeded"
    for o in outs:
        assert o.tokens == oracle["generated"][o.uid]
