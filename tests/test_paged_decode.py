"""Paged length-aware decode kernel (kernels/paged_decode.py) validation.

The contract has two layers, both pinned here:

* BITWISE: the paged kernel skips only pages whose every slot is invalid
  under the ring mask, and a fully-masked chunk contributes exactly zero to
  the online-softmax state — so paged output == unpaged ``swa_decode``
  output bit for bit, across no-wrap / exact-fit / wrap / multi-wrap,
  sliding-window and full attention, scalar and per-slot positions. The jnp
  paged oracle (``ref.paged_decode_ref``) is likewise bitwise equal to the
  plain oracle (``ref.swa_decode_ref``) — its extra live-span mask is a
  subset of the slots the ring mask already kills.
* NUMERIC: paged kernel vs. the jnp oracle within flash-attention
  tolerance (online softmax reassociates the reduction).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.paged_decode import paged_decode
from repro.kernels.swa_decode import swa_decode

# (cap, positions, window) covering every ring regime in one batch:
# no-wrap (pos+1 < cap), exact-fit (pos+1 == cap), wrap (cap <= pos < 2cap),
# multi-wrap (pos >= 2cap), and the first token (pos == 0). Caps <= 512 are
# SINGLE-page (auto page == cap): they pin the degenerate grid. The
# cap-1024 entries split into 2 auto pages, so rows with pos < 512 really
# take the skip path (index-map clamp + pl.when gate + the pages >= 1
# clip at pos == 0) — without them no bitwise pin would ever execute a
# skipped page.
CASES = [
    (256, [0, 10, 255, 300, 1000], 0),     # full attention
    (256, [0, 10, 255, 300, 1000], 64),    # sliding window < cap
    (512, [3, 511, 512, 700, 1537], 128),  # window, incl. exact-fit + wraps
    (128, [0, 64, 127, 128, 900], 128),    # window == cap (engine layout)
    (1024, [0, 10, 511, 512, 1023, 1024, 2500], 0),   # multi-page skipping
    (1024, [0, 10, 511, 512, 1023, 1024, 2500], 256),  # … with a window
]


def _rand(key, cap, n, hkv=2, g=4, hd=64, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (n, hkv, g, hd), dtype)
    kc = jax.random.normal(ks[1], (n, cap, hkv, hd), dtype)
    vc = jax.random.normal(ks[2], (n, cap, hkv, hd), dtype)
    return q, kc, vc


class TestPagedBitwise:
    @pytest.mark.parametrize("cap,poss,window", CASES)
    def test_kernel_bitwise_matches_unpaged_kernel(self, cap, poss, window):
        """Page skipping must be invisible: same bits as full-ring streaming."""
        q, kc, vc = _rand(jax.random.PRNGKey(cap + window), cap, len(poss))
        pos = jnp.asarray(poss, jnp.int32)
        paged = paged_decode(q, kc, vc, pos, window)
        unpaged = swa_decode(q, kc, vc, pos, window)
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(unpaged))

    @pytest.mark.parametrize("cap,poss,window", CASES)
    def test_ref_bitwise_matches_plain_ref(self, cap, poss, window):
        """The jnp paged oracle's live-span mask changes nothing."""
        q, kc, vc = _rand(jax.random.PRNGKey(7 * cap + window), cap, len(poss))
        pos = jnp.asarray(poss, jnp.int32)
        a = ref.paged_decode_ref(q, kc, vc, pos, window)
        b = ref.swa_decode_ref(q, kc, vc, pos, window)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_scalar_pos_broadcasts(self):
        """Lockstep batches (scalar pos) take the same paged path."""
        cap = 128
        q, kc, vc = _rand(jax.random.PRNGKey(3), cap, 3)
        for pos in (0, 40, 127, 128, 500):
            paged = paged_decode(q, kc, vc, jnp.asarray(pos), 0)
            unpaged = swa_decode(q, kc, vc, jnp.asarray(pos), 0)
            np.testing.assert_array_equal(np.asarray(paged), np.asarray(unpaged))


class TestPagedVsOracle:
    @pytest.mark.parametrize("cap,poss,window", CASES)
    def test_kernel_close_to_ref(self, cap, poss, window):
        q, kc, vc = _rand(jax.random.PRNGKey(13 * cap + window), cap, len(poss))
        pos = jnp.asarray(poss, jnp.int32)
        out = paged_decode(q, kc, vc, pos, window)
        expected = ref.swa_decode_ref(q, kc, vc, pos, window)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expected), rtol=3e-5, atol=3e-5
        )

    def test_bf16(self):
        cap = 128
        q, kc, vc = _rand(jax.random.PRNGKey(9), cap, 2, dtype=jnp.bfloat16)
        pos = jnp.asarray([17, 400], jnp.int32)
        out = paged_decode(q, kc, vc, pos, 64)
        unpaged = swa_decode(q, kc, vc, pos, 64)
        np.testing.assert_array_equal(
            np.asarray(out, np.float32), np.asarray(unpaged, np.float32)
        )

    def test_explicit_page_size(self):
        """A non-default page size partitions differently but values match —
        chunk boundaries never change which slots are valid. page=64 over a
        256-ring is 4 pages, so the row at pos=30 skips three of them."""
        cap = 256
        q, kc, vc = _rand(jax.random.PRNGKey(21), cap, 2)
        pos = jnp.asarray([30, 700], jnp.int32)
        a = paged_decode(q, kc, vc, pos, 0, page=64)
        b = paged_decode(q, kc, vc, pos, 0, page=256)
        ora = ref.swa_decode_ref(q, kc, vc, pos, 0)
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(ora), rtol=3e-5, atol=3e-5
        )
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5
        )

    def test_skipped_pages_bitwise_per_depth(self):
        """Direct pin on the skip machinery: at cap 1024 (2 auto pages), a
        batch whose rows live in 1 vs 2 pages must equal, bit for bit, the
        unpaged kernel AND solo single-row runs of themselves (page counts
        of OTHER rows can't leak across rows)."""
        cap = 1024
        q, kc, vc = _rand(jax.random.PRNGKey(33), cap, 4)
        pos = jnp.asarray([7, 500, 600, 1500], jnp.int32)  # 1,1,2,2 pages
        batched = paged_decode(q, kc, vc, pos, 0)
        unpaged = swa_decode(q, kc, vc, pos, 0)
        np.testing.assert_array_equal(np.asarray(batched), np.asarray(unpaged))
        for r in range(4):
            solo = paged_decode(
                q[r : r + 1], kc[r : r + 1], vc[r : r + 1], pos[r : r + 1], 0
            )
            np.testing.assert_array_equal(
                np.asarray(solo[0]), np.asarray(batched[r])
            )

    @given(pos=st.integers(0, 2000), window=st.sampled_from([0, 32, 128]))
    @settings(max_examples=20, deadline=None)
    def test_property_ring_positions(self, pos, window):
        """Paged kernel == unpaged kernel for arbitrary ring positions."""
        key = jax.random.PRNGKey(pos + 31 * window)
        q = jax.random.normal(key, (1, 1, 2, 64))
        kc = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 1, 64))
        vc = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 1, 64))
        paged = paged_decode(q, kc, vc, jnp.asarray(pos), window)
        unpaged = swa_decode(q, kc, vc, jnp.asarray(pos), window)
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(unpaged))


class TestOpsRouting:
    def test_paged_flag_routes_kernel_and_ref(self):
        cap = 128
        q, kc, vc = _rand(jax.random.PRNGKey(5), cap, 2)
        pos = jnp.asarray([9, 300], jnp.int32)
        k_paged = ops.swa_decode_attention(
            q, kc, vc, pos, 0, use_kernel=True, paged=True
        )
        k_plain = ops.swa_decode_attention(q, kc, vc, pos, 0, use_kernel=True)
        r_paged = ops.swa_decode_attention(q, kc, vc, pos, 0, paged=True)
        r_plain = ops.swa_decode_attention(q, kc, vc, pos, 0)
        np.testing.assert_array_equal(np.asarray(k_paged), np.asarray(k_plain))
        np.testing.assert_array_equal(np.asarray(r_paged), np.asarray(r_plain))


# ---------------------------------------------------------- page-table mode
def _scatter_to_pool(kc, vc, page, key):
    """Re-lay a contiguous (B, C, Hkv, hd) cache as a shared page pool with
    a RANDOM page placement: pool (1 + B·C/page, page, Hkv, hd) whose page
    0 is scratch, plus the (B, T) table mapping each row's logical pages to
    their scattered physical homes."""
    b, cap, hkv, hd = kc.shape
    t_w = cap // page
    flat_k = kc.reshape(b * t_w, page, hkv, hd)
    flat_v = vc.reshape(b * t_w, page, hkv, hd)
    perm = jax.random.permutation(key, b * t_w)
    dest = 1 + perm
    pool_shape = (1 + b * t_w, page, hkv, hd)
    pool_k = jnp.zeros(pool_shape, kc.dtype).at[dest].set(flat_k)
    pool_v = jnp.zeros(pool_shape, kc.dtype).at[dest].set(flat_v)
    table = dest.reshape(b, t_w).astype(jnp.int32)
    return pool_k, pool_v, table


# every CASES cap splits into pages of 64 — small enough that several
# logical pages exist (real skipping + indirection) at every cap
TABLE_PAGE = 64


class TestTableMode:
    @pytest.mark.parametrize("cap,poss,window", CASES)
    def test_table_kernel_bitwise_matches_contiguous_kernel(
        self, cap, poss, window
    ):
        """Scattered physical placement must be invisible BIT FOR BIT
        against the contiguous paged kernel at the SAME page size (same
        chunk partitioning → same online-softmax association)."""
        q, kc, vc = _rand(jax.random.PRNGKey(3 * cap + window), cap, len(poss))
        pos = jnp.asarray(poss, jnp.int32)
        pool_k, pool_v, table = _scatter_to_pool(
            kc, vc, TABLE_PAGE, jax.random.PRNGKey(cap)
        )
        out = paged_decode(q, pool_k, pool_v, pos, window, table=table)
        expected = paged_decode(q, kc, vc, pos, window, page=TABLE_PAGE)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))

    @pytest.mark.parametrize("cap,poss,window", CASES)
    def test_table_ref_bitwise_matches_plain_ref(self, cap, poss, window):
        """The jnp table oracle (gather pages → plain ring oracle) equals
        the plain oracle on the contiguous original."""
        q, kc, vc = _rand(jax.random.PRNGKey(5 * cap + window), cap, len(poss))
        pos = jnp.asarray(poss, jnp.int32)
        pool_k, pool_v, table = _scatter_to_pool(
            kc, vc, TABLE_PAGE, jax.random.PRNGKey(cap + 1)
        )
        a = ref.paged_table_decode_ref(q, pool_k, pool_v, pos, table, window)
        b = ref.swa_decode_ref(q, kc, vc, pos, window)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("cap,poss,window", CASES)
    def test_table_kernel_close_to_oracle(self, cap, poss, window):
        q, kc, vc = _rand(jax.random.PRNGKey(11 * cap + window), cap, len(poss))
        pos = jnp.asarray(poss, jnp.int32)
        pool_k, pool_v, table = _scatter_to_pool(
            kc, vc, TABLE_PAGE, jax.random.PRNGKey(cap + 2)
        )
        out = paged_decode(q, pool_k, pool_v, pos, window, table=table)
        expected = ref.paged_table_decode_ref(
            q, pool_k, pool_v, pos, table, window
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expected), rtol=3e-5, atol=3e-5
        )

    def test_unallocated_tail_entries_never_read(self):
        """Table entries beyond a row's live span may point ANYWHERE (the
        engine leaves them at scratch page 0): the index-map clamp + the
        live-page gate mean they must not change a single bit."""
        cap, page = 256, 64
        q, kc, vc = _rand(jax.random.PRNGKey(41), cap, 3)
        pos = jnp.asarray([10, 100, 150], jnp.int32)  # 1, 2, 3 live pages
        pool_k, pool_v, table = _scatter_to_pool(
            kc, vc, page, jax.random.PRNGKey(42)
        )
        live_pages = np.asarray((np.minimum(np.asarray(pos) + 1, cap) + page - 1) // page)
        wild = np.array(table)
        for r, lp in enumerate(live_pages):
            wild[r, lp:] = 0  # scratch — what the engine actually does
        a = paged_decode(q, pool_k, pool_v, pos, 0, table=table)
        b = paged_decode(q, pool_k, pool_v, pos, 0, table=jnp.asarray(wild))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rows_share_pool_without_leaking(self):
        """Two rows with interleaved physical pages: each row's solo run
        equals its batched row — page placement of OTHER rows can't leak."""
        cap, page = 512, 64
        q, kc, vc = _rand(jax.random.PRNGKey(51), cap, 2)
        pos = jnp.asarray([200, 700], jnp.int32)
        pool_k, pool_v, table = _scatter_to_pool(
            kc, vc, page, jax.random.PRNGKey(52)
        )
        batched = paged_decode(q, pool_k, pool_v, pos, 0, table=table)
        for r in range(2):
            solo = paged_decode(
                q[r : r + 1], pool_k, pool_v, pos[r : r + 1], 0,
                table=table[r : r + 1],
            )
            np.testing.assert_array_equal(
                np.asarray(solo[0]), np.asarray(batched[r])
            )

    def test_rows_aliasing_shared_prefix_pages_bitwise(self):
        """PREFIX SHARING at the kernel layer: two rows whose tables alias
        the SAME physical pages for a common prefix must read bit-for-bit
        what they read from private duplicated copies — aliasing is pure
        placement, and table mode already tolerates arbitrary placement,
        so no kernel change is needed (this pins that claim)."""
        cap, page, shared_pages = 256, 64, 2
        q, kc, vc = _rand(jax.random.PRNGKey(71), cap, 2)
        # duplicate the shared-prefix CONTENT into both rows' caches
        kc = kc.at[1, : shared_pages * page].set(kc[0, : shared_pages * page])
        vc = vc.at[1, : shared_pages * page].set(vc[0, : shared_pages * page])
        pos = jnp.asarray([150, 230], jnp.int32)  # both past the prefix
        pool_k, pool_v, table = _scatter_to_pool(
            kc, vc, page, jax.random.PRNGKey(72)
        )
        aliased = jnp.asarray(table).at[1, :shared_pages].set(
            table[0, :shared_pages]
        )  # row 1's prefix now points at row 0's physical pages
        for tab in (table, aliased):
            out = paged_decode(q, pool_k, pool_v, pos, 0, table=tab)
            ref_out = ref.paged_table_decode_ref(
                q, pool_k, pool_v, pos, tab, 0
            )
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref_out), rtol=3e-5, atol=3e-5
            )
        a = paged_decode(q, pool_k, pool_v, pos, 0, table=table)
        b = paged_decode(q, pool_k, pool_v, pos, 0, table=aliased)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ra = ref.paged_table_decode_ref(q, pool_k, pool_v, pos, table, 0)
        rb = ref.paged_table_decode_ref(q, pool_k, pool_v, pos, aliased, 0)
        np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))

    def test_ops_routes_table_mode(self):
        cap, page = 128, 64
        q, kc, vc = _rand(jax.random.PRNGKey(61), cap, 2)
        pos = jnp.asarray([9, 300], jnp.int32)
        pool_k, pool_v, table = _scatter_to_pool(
            kc, vc, page, jax.random.PRNGKey(62)
        )
        k_out = ops.swa_decode_attention(
            q, pool_k, pool_v, pos, 0, use_kernel=True, table=table
        )
        r_out = ops.swa_decode_attention(q, pool_k, pool_v, pos, 0, table=table)
        plain = ref.swa_decode_ref(q, kc, vc, pos, 0)
        np.testing.assert_array_equal(np.asarray(r_out), np.asarray(plain))
        np.testing.assert_allclose(
            np.asarray(k_out), np.asarray(plain), rtol=3e-5, atol=3e-5
        )

    @given(pos=st.integers(0, 2000), window=st.sampled_from([0, 32, 128]))
    @settings(max_examples=15, deadline=None)
    def test_property_table_ring_positions(self, pos, window):
        """Arbitrary ring positions: table kernel == contiguous paged
        kernel at the same page size, scattered placement and all."""
        key = jax.random.PRNGKey(pos + 131 * window)
        cap, page = 256, 64
        q = jax.random.normal(key, (1, 1, 2, 64))
        kc = jax.random.normal(jax.random.fold_in(key, 1), (1, cap, 1, 64))
        vc = jax.random.normal(jax.random.fold_in(key, 2), (1, cap, 1, 64))
        pool_k, pool_v, table = _scatter_to_pool(
            kc, vc, page, jax.random.fold_in(key, 3)
        )
        a = paged_decode(
            q, pool_k, pool_v, jnp.asarray(pos), window, table=table
        )
        b = paged_decode(q, kc, vc, jnp.asarray(pos), window, page=page)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
