"""Partitioning (§3.1) and async scheduler tests."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.partition import Partitioner
from repro.core.scheduler import (
    CloudSpec,
    events_to_round_masks,
    simulate_async_schedule,
    sync_round_time,
)


class TestPartitioner:
    def test_fixed_equal_shares(self):
        p = Partitioner(strategy="fixed", n_clouds=4)
        state = p.init()
        sizes = p.quantize(state, 64)
        np.testing.assert_array_equal(sizes, [16, 16, 16, 16])

    def test_sizes_sum_to_global_batch(self):
        p = Partitioner(strategy="dynamic", n_clouds=3)
        state = p.init([1.0, 2.0, 3.0])
        for gb in (12, 48, 96, 256):
            assert p.quantize(state, gb).sum() == gb

    @given(
        thr=st.lists(st.floats(0.2, 5.0), min_size=2, max_size=6),
        gb=st.sampled_from([32, 64, 128, 256]),
    )
    @settings(max_examples=30, deadline=None)
    def test_quantize_invariants(self, thr, gb):
        p = Partitioner(strategy="weighted", n_clouds=len(thr))
        state = p.init(thr)
        sizes = p.quantize(state, gb)
        assert sizes.sum() == gb
        assert (sizes >= 1).all()

    def test_dynamic_converges_to_throughput_ratio(self):
        """The §3.1 monitor-adjust cycle: shares → true throughput shares."""
        true_thr = np.asarray([1.0, 2.0, 4.0])
        p = Partitioner(strategy="dynamic", n_clouds=3, ema=0.3)
        state = p.init()
        for _ in range(40):
            sizes = p.quantize(state, 112)
            times = sizes / true_thr  # observed step time per cloud
            state = p.observe(state, sizes, times)
        target = true_thr / true_thr.sum()
        np.testing.assert_allclose(state.shares, target, atol=0.06)

    def test_dynamic_beats_fixed_on_heterogeneous(self):
        true_thr = np.asarray([1.0, 1.0, 5.0])
        fixed = Partitioner(strategy="fixed", n_clouds=3)
        dyn = Partitioner(strategy="dynamic", n_clouds=3)
        fs, ds = fixed.init(), dyn.init()
        for _ in range(30):
            sizes = dyn.quantize(ds, 70)
            ds = dyn.observe(ds, sizes, sizes / true_thr)
        t_fixed = Partitioner.round_time(fixed.quantize(fs, 70), true_thr)
        t_dyn = Partitioner.round_time(dyn.quantize(ds, 70), true_thr)
        assert t_dyn < t_fixed
        assert Partitioner.utilization(dyn.quantize(ds, 70), true_thr) > \
            Partitioner.utilization(fixed.quantize(fs, 70), true_thr)

    def test_granularity_quantizes(self):
        p = Partitioner(strategy="fixed", n_clouds=3, granule=8)
        sizes = p.quantize(p.init(), 96)
        assert (sizes % 8 == 0).all() and sizes.sum() == 96


class TestScheduler:
    def test_fast_cloud_arrives_more_often(self):
        clouds = [CloudSpec("slow", 1.0), CloudSpec("fast", 4.0)]
        events = simulate_async_schedule(clouds, local_steps=4, n_rounds=50)
        fast = sum(1 for e in events if e.cloud == 1)
        assert fast > 30  # ~4/5 of arrivals

    def test_staleness_nonnegative_and_alpha_discounted(self):
        clouds = [CloudSpec("a", 1.0), CloudSpec("b", 0.2)]
        events = simulate_async_schedule(clouds, 4, 40, base_alpha=0.5)
        for e in events:
            assert e.staleness >= 0
            assert e.alpha == pytest.approx(0.5 / (1 + e.staleness))
        # the slow cloud accumulates staleness
        assert max(e.staleness for e in events if e.cloud == 1) >= 3

    def test_event_times_monotone(self):
        clouds = [CloudSpec(f"c{i}", 1.0 + i) for i in range(3)]
        events = simulate_async_schedule(clouds, 2, 30)
        times = [e.time for e in events]
        assert all(t2 >= t1 for t1, t2 in zip(times, times[1:]))

    def test_round_masks(self):
        clouds = [CloudSpec("a", 1.0), CloudSpec("b", 2.0)]
        events = simulate_async_schedule(clouds, 2, 10)
        arrived, alphas = events_to_round_masks(events, 2, 10)
        assert arrived.shape == (10, 2)
        assert (arrived.sum(axis=1) == 1).all()  # one arrival per round
        assert (alphas[arrived] > 0).all()

    def test_sync_round_time_dominated_by_straggler(self):
        clouds = [CloudSpec("fast", 10.0), CloudSpec("slow", 0.5)]
        t = sync_round_time(clouds, local_steps=4, step_time=1.0, sync_bytes=0)
        assert t == pytest.approx(4 / 0.5 + clouds[1].link_latency_s)
