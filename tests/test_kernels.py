"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.dp_clip import clip_noise, sq_norm
from repro.kernels.quantize import int8_encode, int8_roundtrip
from repro.kernels.swa_decode import swa_decode
from repro.kernels.topk_compress import topk_sparsify


class TestTopKKernel:
    @pytest.mark.parametrize("rows", [8, 32, 128])
    @pytest.mark.parametrize("k", [1, 3, 13, 26, 64])
    def test_sweep_vs_ref(self, rows, k):
        x = jax.random.normal(jax.random.PRNGKey(rows * k), (rows, 256))
        out = topk_sparsify(x, k)
        expected = ref.topk_sparsify_ref(x, k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-6)

    def test_all_zero_block(self):
        x = jnp.zeros((8, 256))
        np.testing.assert_array_equal(np.asarray(topk_sparsify(x, 3)), 0.0)

    def test_leaf_wrapper_kernel_vs_ref(self, rng):
        x = jax.random.normal(rng, (1000, 7), jnp.bfloat16)
        a = ops.topk_sparsify_leaf(x, 0.05, use_kernel=True)
        b = ops.topk_sparsify_leaf(x, 0.05, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-3, atol=1e-3
        )


class TestQuantizeKernel:
    @pytest.mark.parametrize("rows", [8, 64])
    @pytest.mark.parametrize("scale", [1e-4, 1.0, 1e4])
    def test_roundtrip_sweep(self, rows, scale):
        x = jax.random.normal(jax.random.PRNGKey(rows), (rows, 256)) * scale
        out = int8_roundtrip(x)
        expected = ref.int8_roundtrip_ref(x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expected), rtol=1e-6, atol=1e-9 * scale
        )

    def test_encode_matches_ref(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (16, 256))
        qa, sa = int8_encode(x)
        qb, sb = ref.int8_encode_ref(x)
        np.testing.assert_array_equal(np.asarray(qa), np.asarray(qb))
        np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), rtol=1e-6)
        assert qa.dtype == jnp.int8


class TestDpClipKernel:
    @pytest.mark.parametrize("rows", [8, 48])
    def test_sq_norm_sweep(self, rows):
        x = jax.random.normal(jax.random.PRNGKey(rows), (rows, 256))
        np.testing.assert_allclose(
            float(sq_norm(x)), float(ref.sq_norm_ref(x)), rtol=1e-5
        )

    def test_clip_noise_fused(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
        noise = jax.random.normal(jax.random.PRNGKey(2), (8, 256))
        out = clip_noise(x, jnp.float32(0.3), noise, 0.7)
        expected = ref.clip_noise_ref(x, jnp.float32(0.3), noise, 0.7)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expected), rtol=1e-6, atol=1e-6
        )

    def test_dp_transmit_end_to_end(self, rng):
        tree = {"w": jax.random.normal(rng, (100, 30)) * 10}
        a = ops.dp_transmit(tree, rng, clip_norm=1.0, stddev=0.0, use_kernel=True)
        b = ops.dp_transmit(tree, rng, clip_norm=1.0, stddev=0.0, use_kernel=False)
        np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]), rtol=1e-4)
        from repro.utils.tree import tree_norm
        assert float(tree_norm(a)) <= 1.0 + 1e-4


class TestSwaDecodeKernel:
    @pytest.mark.parametrize("hd", [64, 128])
    @pytest.mark.parametrize("g", [1, 4])
    @pytest.mark.parametrize("cap,pos,window", [
        (256, 10, 0),        # partially filled, full attention
        (256, 255, 0),       # exactly full
        (256, 1000, 0),      # wrapped ring, full attention over cap
        (512, 700, 128),     # wrapped ring + sliding window
        (128, 0, 64),        # first token
    ])
    def test_sweep_vs_ref(self, hd, g, cap, pos, window):
        key = jax.random.PRNGKey(cap + pos + hd + g)
        b, hkv = 2, 2
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, hkv, g, hd))
        kc = jax.random.normal(ks[1], (b, cap, hkv, hd))
        vc = jax.random.normal(ks[2], (b, cap, hkv, hd))
        out = swa_decode(q, kc, vc, jnp.asarray(pos), window)
        expected = ref.swa_decode_ref(q, kc, vc, jnp.asarray(pos), window)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expected), rtol=3e-5, atol=3e-5
        )

    def test_bf16(self):
        b, hkv, g, hd, cap = 1, 2, 2, 64, 128
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (b, hkv, g, hd), jnp.bfloat16)
        kc = jax.random.normal(jax.random.fold_in(key, 1), (b, cap, hkv, hd), jnp.bfloat16)
        vc = jax.random.normal(jax.random.fold_in(key, 2), (b, cap, hkv, hd), jnp.bfloat16)
        out = swa_decode(q, kc, vc, jnp.asarray(60), 32)
        expected = ref.swa_decode_ref(q, kc, vc, jnp.asarray(60), 32)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expected, np.float32),
            rtol=2e-2, atol=2e-2,
        )

    @given(pos=st.integers(0, 2000), window=st.sampled_from([0, 32, 128]))
    @settings(max_examples=20, deadline=None)
    def test_property_ring_positions(self, pos, window):
        """Kernel == oracle for arbitrary ring positions."""
        key = jax.random.PRNGKey(pos)
        q = jax.random.normal(key, (1, 1, 2, 64))
        kc = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 1, 64))
        vc = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 1, 64))
        out = swa_decode(q, kc, vc, jnp.asarray(pos), window)
        expected = ref.swa_decode_ref(q, kc, vc, jnp.asarray(pos), window)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expected), rtol=3e-5, atol=3e-5
        )


# ------------------------------------------------------------ flash prefill
class TestFlashPrefill:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("s,t,g,hd", [
        (64, 64, 1, 32), (64, 64, 4, 32), (128, 128, 2, 64),
    ])
    def test_causal_matches_ref(self, rng, dtype, s, t, g, hd):
        from repro.kernels.flash_prefill import flash_prefill
        from repro.kernels.ref import flash_prefill_ref
        b, hkv = 2, 2
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hkv, g, hd), dtype)
        k = jax.random.normal(ks[1], (b, t, hkv, hd), dtype)
        v = jax.random.normal(ks[2], (b, t, hkv, hd), dtype)
        out = flash_prefill(q, k, v, causal=True, interpret=True)
        ref = flash_prefill_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol,
        )

    @pytest.mark.parametrize("window", [16, 48])
    def test_sliding_window_matches_ref(self, rng, window):
        from repro.kernels.flash_prefill import flash_prefill
        from repro.kernels.ref import flash_prefill_ref
        b, s, hkv, g, hd = 1, 128, 2, 2, 32
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hkv, g, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
        out = flash_prefill(q, k, v, causal=True, window=window, interpret=True)
        ref = flash_prefill_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
        )

    def test_non_causal_cross_attention_shape(self, rng):
        """Encoder/cross-attention: kv length != q length, no mask."""
        from repro.kernels.flash_prefill import flash_prefill
        from repro.kernels.ref import flash_prefill_ref
        b, s, t, hkv, g, hd = 1, 64, 128, 2, 2, 32
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hkv, g, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, t, hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, t, hkv, hd), jnp.float32)
        out = flash_prefill(q, k, v, causal=False, interpret=True)
        ref = flash_prefill_ref(q, k, v, causal=False)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
        )

    def test_matches_attend_full_oracle(self, rng):
        """The kernel's oracle agrees with the model's attend_full path."""
        from repro.kernels.ref import flash_prefill_ref
        from repro.configs import get_smoke_config
        from repro.models import attention as attn
        cfg = get_smoke_config("stablelm-1.6b")
        params = attn.init_attention(rng, cfg)
        b, s = 2, 32
        hd = cfg.resolved_head_dim
        x = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        ref_out = attn.attend_full(params, x, pos, cfg, causal=True, q_chunk=s)
        # rebuild q/k/v exactly as attend_full does
        q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, hd)
        k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        from repro.models.layers import apply_rope
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        g = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, s, cfg.n_kv_heads, g, hd)
        out = flash_prefill_ref(qg, k, v, causal=True)
        out = out.reshape(b, s, -1) @ params["wo"]
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref_out, np.float32),
            rtol=5e-3, atol=5e-3,
        )
