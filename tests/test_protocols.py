"""Protocol cost-model tests (§3.2 gRPC/QUIC comparison)."""
import pytest

from repro.core.protocols import GRPC, QUIC, TCP, Link, sync_wall_time


class TestProtocols:
    def test_quic_wins_on_lossy_links(self):
        """The paper's claim: QUIC handles high-latency lossy WANs better."""
        lossy = Link(latency_s=0.05, bandwidth=1e9, loss_rate=1e-3)
        b = 500e6
        assert QUIC.transfer_time(b, lossy) < GRPC.transfer_time(b, lossy)
        assert QUIC.transfer_time(b, lossy) < TCP.transfer_time(b, lossy)

    def test_multiplexing_helps_grpc_and_quic(self):
        link = Link()
        b = 1e9
        for proto in (GRPC, QUIC):
            t1 = proto.transfer_time(b, link, n_streams=1)
            t8 = proto.transfer_time(b, link, n_streams=8)
            assert t8 < t1
        # plain TCP has no multiplexing gain
        assert TCP.transfer_time(b, link, 8) == pytest.approx(
            TCP.transfer_time(b, link, 1)
        )

    def test_transfer_time_monotone_in_bytes(self):
        link = Link()
        times = [GRPC.transfer_time(b, link) for b in (1e6, 1e7, 1e8, 1e9)]
        assert all(t2 > t1 for t1, t2 in zip(times, times[1:]))

    def test_bandwidth_bound_dominates_large_transfers(self):
        link = Link(bandwidth=1e9, loss_rate=0.0)
        b = 10e9
        t = QUIC.transfer_time(b, link, n_streams=8)
        wire_floor = b / (link.bandwidth * 0.98)
        assert t == pytest.approx(wire_floor + link.latency_s, rel=0.1)

    def test_handshake_amortization(self):
        link = Link()
        fresh = GRPC.transfer_time(1e6, link, reuse_conn=False)
        reused = GRPC.transfer_time(1e6, link, reuse_conn=True)
        assert fresh - reused == pytest.approx(2.5 * 2 * link.latency_s)

    def test_ring_beats_star_for_many_clouds(self):
        """Ring all-reduce moves 2(n−1)/n·B per link vs 2·B up+down."""
        link = Link(loss_rate=0.0)
        star = sync_wall_time(4e9, 8, QUIC, link, topology="star")
        ring = sync_wall_time(4e9, 8, QUIC, link, topology="ring")
        assert ring < star
