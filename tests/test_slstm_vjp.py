"""The sLSTM custom VJP (weight grads hoisted out of the backward scan) must
match plain autodiff through the naive cell-by-cell scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import xlstm


def _naive_scan(rec, xz, xi, xf, xo):
    """Reference: plain lax.scan over slstm_cell (differentiated by jax AD)."""
    b, s, d = xz.shape
    p = dict(rec, conv_w=None)
    zero = jnp.zeros((b, d), jnp.float32)
    state = {"c": zero, "n": zero, "h": zero, "m": jnp.full((b, d), -1e30, jnp.float32)}

    def step(carry, xs):
        new = xlstm.slstm_cell(rec, *xs, carry)
        return new, new["h"]

    _, hs = jax.lax.scan(
        step, state,
        (xz.swapaxes(0, 1), xi.swapaxes(0, 1), xf.swapaxes(0, 1), xo.swapaxes(0, 1)),
    )
    return hs.swapaxes(0, 1)


@pytest.mark.parametrize("seed", [0, 1])
def test_slstm_custom_vjp_matches_autodiff(seed):
    key = jax.random.PRNGKey(seed)
    b, s, d, h = 2, 10, 16, 4
    ks = jax.random.split(key, 9)
    rec = {
        "r_z": jax.random.normal(ks[0], (h, d // h, d // h), jnp.float32) * 0.3,
        "r_i": jax.random.normal(ks[1], (h, d // h, d // h), jnp.float32) * 0.3,
        "r_f": jax.random.normal(ks[2], (h, d // h, d // h), jnp.float32) * 0.3,
        "r_o": jax.random.normal(ks[3], (h, d // h, d // h), jnp.float32) * 0.3,
        "b_z": jnp.zeros((d,), jnp.float32),
        "b_i": jnp.zeros((d,), jnp.float32),
        "b_f": jnp.full((d,), 1.0, jnp.float32),
        "b_o": jnp.zeros((d,), jnp.float32),
    }
    xs = [jax.random.normal(ks[4 + i], (b, s, d), jnp.float32) for i in range(4)]
    w = jax.random.normal(ks[8], (b, s, d), jnp.float32)  # random cotangent mix

    def loss_custom(rec, xs):
        return jnp.sum(xlstm.slstm_scan_train(rec, *xs) * w)

    def loss_naive(rec, xs):
        return jnp.sum(_naive_scan(rec, *xs) * w)

    l1, g1 = jax.value_and_grad(loss_custom, argnums=(0, 1))(rec, tuple(xs))
    l2, g2 = jax.value_and_grad(loss_naive, argnums=(0, 1))(rec, tuple(xs))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b_ in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-5)


def test_slstm_custom_vjp_bf16_path():
    """bf16 inputs (the model's storage dtype) run and give finite grads."""
    cfg = get_smoke_config("xlstm-125m")
    b, s, d, h = 2, 8, 16, 4
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 5)
    rec = {
        "r_z": jax.random.normal(ks[0], (h, d // h, d // h), jnp.bfloat16) * 0.3,
        "r_i": jax.random.normal(ks[1], (h, d // h, d // h), jnp.bfloat16) * 0.3,
        "r_f": jax.random.normal(ks[2], (h, d // h, d // h), jnp.bfloat16) * 0.3,
        "r_o": jax.random.normal(ks[3], (h, d // h, d // h), jnp.bfloat16) * 0.3,
        "b_z": jnp.zeros((d,), jnp.float32),
        "b_i": jnp.zeros((d,), jnp.float32),
        "b_f": jnp.full((d,), 1.0, jnp.float32),
        "b_o": jnp.zeros((d,), jnp.float32),
    }
    xs = [jax.random.normal(ks[4], (b, s, d), jnp.bfloat16) for _ in range(4)]
    g = jax.grad(lambda r: jnp.sum(xlstm.slstm_scan_train(r, *xs)))(rec)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
