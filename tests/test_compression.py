"""Compression channel tests (§3.2): semantics, wire accounting, error
feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.compression import (
    BLOCK,
    Compressor,
    int8_roundtrip,
    topk_block_sparsify,
)


class TestTopK:
    def test_keeps_largest(self, rng):
        x = jax.random.normal(rng, (1024,))
        out = np.asarray(topk_block_sparsify(x, ratio=0.05))
        xb = np.asarray(x).reshape(-1, BLOCK)
        ob = out.reshape(-1, BLOCK)
        k = int(round(0.05 * BLOCK))
        for row in range(xb.shape[0]):
            kept = np.nonzero(ob[row])[0]
            assert len(kept) == k  # continuous values: no ties
            thr = np.sort(np.abs(xb[row]))[-k]
            assert (np.abs(xb[row][kept]) >= thr - 1e-7).all()
            # kept values unmodified
            np.testing.assert_allclose(ob[row][kept], xb[row][kept], rtol=1e-6)

    def test_shape_and_dtype_preserved(self, rng):
        for shape in [(7,), (33, 5), (2, 3, 129)]:
            x = jax.random.normal(rng, shape, jnp.float32)
            out = topk_block_sparsify(x, 0.1)
            assert out.shape == shape and out.dtype == x.dtype

    @given(ratio=st.floats(0.01, 1.0))
    @settings(max_examples=20, deadline=None)
    def test_energy_decreases(self, ratio):
        x = jax.random.normal(jax.random.PRNGKey(1), (512,))
        out = topk_block_sparsify(x, ratio)
        assert float(jnp.sum(out**2)) <= float(jnp.sum(x**2)) + 1e-5


class TestInt8:
    def test_roundtrip_error_bound(self, rng):
        x = jax.random.normal(rng, (2048,)) * 10
        out = int8_roundtrip(x)
        # error per block ≤ scale/2 = max|x|/254
        xb = np.asarray(x).reshape(-1, BLOCK)
        ob = np.asarray(out).reshape(-1, BLOCK)
        for row in range(xb.shape[0]):
            bound = np.abs(xb[row]).max() / 254 + 1e-6
            assert np.abs(xb[row] - ob[row]).max() <= bound

    def test_zeros_stay_zero(self):
        x = jnp.zeros((512,))
        np.testing.assert_array_equal(np.asarray(int8_roundtrip(x)), 0.0)


class TestCompressor:
    def test_bytes_accounting_monotone(self, rng):
        tree = {"a": jnp.zeros((1000, 64), jnp.bfloat16), "b": jnp.zeros((3000,), jnp.float32)}
        raw = Compressor("none").bytes_per_sync(tree)
        topk = Compressor("topk", topk_ratio=0.01).bytes_per_sync(tree)
        int8 = Compressor("int8").bytes_per_sync(tree)
        assert topk < int8 < raw
        # int8-on-topk pays once kept values dominate per-block overhead
        topk10 = Compressor("topk", topk_ratio=0.10).bytes_per_sync(tree)
        both10 = Compressor("topk+int8", topk_ratio=0.10).bytes_per_sync(tree)
        assert both10 < topk10
        assert Compressor("topk", topk_ratio=0.01).compression_ratio(tree) > 20

    def test_unknown_method_raises(self):
        with pytest.raises(ValueError):
            Compressor("gzip")

    def test_roundtrip_composition(self, rng):
        tree = {"w": jax.random.normal(rng, (600,))}
        c = Compressor("topk+int8", topk_ratio=0.1)
        out = c.roundtrip(tree)["w"]
        # sparsity preserved through int8 stage
        assert float(jnp.mean(out == 0)) > 0.8

    def test_error_feedback_preserves_signal(self, rng):
        """Accumulated (transmitted + residual) == original sum over rounds —
        the EF invariant that makes top-k unbiased in the long run."""
        c = Compressor("topk", topk_ratio=0.05)
        residual = jnp.zeros((512,))
        total_sent = jnp.zeros((512,))
        total_true = jnp.zeros((512,))
        for i in range(30):
            g = jax.random.normal(jax.random.fold_in(rng, i), (512,))
            total_true = total_true + g
            carried = g + residual
            sent = c.roundtrip_leaf(carried)
            residual = carried - sent
            total_sent = total_sent + sent
        # residual bounded; sent+residual == true exactly
        np.testing.assert_allclose(
            np.asarray(total_sent + residual), np.asarray(total_true), rtol=1e-4, atol=1e-4
        )
