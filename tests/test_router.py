"""Fault-tolerant multi-replica router tests (launch/router.py).

The contract extends the engine suite's invariance theme one level up:
WHERE a request runs — which replica, before or after a migration — must
be invisible in its output. A fault-free single engine is the oracle; the
router under injected kill/stall/slow faults must emit bitwise identical
token streams (greedy and sampled), complete every submitted request, and
report what happened through ``router_stats`` instead of raising. Routing
policy (prefix affinity, occupancy balance, backpressure) and the SLO
machinery (deadline shed, best-fit rejection) are pinned alongside.
"""
import jax
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.launch.engine import (
    AdmissionError,
    Request,
    ServeEngine,
    make_requests,
)
from repro.launch.router import (
    FaultPlan,
    ReplicaFault,
    ServeRouter,
    parse_fault_spec,
)
from repro.launch.sampling import SamplingParams

ARCH = "stablelm-1.6b"
P, G = 8, 6  # default prompt / generated tokens (ring cap 14)
PS = 4       # page size used throughout


@pytest.fixture(scope="module")
def model_and_params():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


ENGINE_KW = dict(paged_cache=True, page_size=PS, prefix_cache=True, seed=0)


def _router(model_and_params, **kw):
    _, model, params = model_and_params
    for k, v in ENGINE_KW.items():
        kw.setdefault(k, v)
    kw.setdefault("replicas", 2)
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_seq", P + G)
    return ServeRouter(model, params, **kw)


def _engine(model_and_params, **kw):
    _, model, params = model_and_params
    for k, v in ENGINE_KW.items():
        kw.setdefault(k, v)
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_seq", P + G)
    return ServeEngine(model, params, **kw)


def _reqs(cfg, lens, *, gen=G, uid0=0, seed=0, sampled=False):
    base = make_requests(
        cfg, n_requests=len(lens), prompt_len=max(lens), gen_tokens=gen,
        seed=seed,
    )
    reqs = [
        Request(uid=uid0 + j, prompt=r.prompt[: lens[j]], max_new_tokens=gen)
        for j, r in enumerate(base)
    ]
    if sampled:
        for r in reqs:
            r.sampling = SamplingParams(
                temperature=0.9, top_p=0.95, seed=100 + r.uid
            )
    return reqs


def _assert_same_tokens(a, b):
    ref = {o.uid: o.tokens for o in b}
    assert len(a) == len(b)
    for o in a:
        assert o.tokens == ref[o.uid], (
            f"uid {o.uid}: {o.tokens} != {ref[o.uid]}"
        )


@pytest.fixture(scope="module")
def fault_free(model_and_params):
    """Single fault-free engine outputs for the shared 5-request trace —
    the oracle every failover scenario is pinned against."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7, P, 6]
    out = {}
    out["greedy"] = _engine(model_and_params).run(_reqs(cfg, lens))
    out["sampled"] = _engine(model_and_params).run(
        _reqs(cfg, lens, sampled=True)
    )
    out["lens"] = lens
    return out


# ------------------------------------------------------- failover identity
def test_kill_mid_decode_token_identical_greedy(model_and_params, fault_free):
    """The acceptance pin: kill 1 of 2 replicas mid-decode; every in-flight
    request completes on the survivor with BITWISE identical greedy
    tokens."""
    cfg, _, _ = model_and_params
    r = _router(model_and_params, fault_plan=FaultPlan(kill={0: 3}))
    outs = r.run(_reqs(cfg, fault_free["lens"]))
    _assert_same_tokens(outs, fault_free["greedy"])
    rs = r.router_stats
    assert rs["healthy"] == [False, True]
    assert "killed" in rs["fail_reasons"][0]
    assert rs["migrations"] == 1 and rs["migrated_requests"] > 0, (
        "kill at step 3 must catch requests in flight"
    )
    assert not r.shed_errors


def test_kill_mid_decode_token_identical_sampled(
    model_and_params, fault_free
):
    """Same failover, sampled decoding: the per-request PRNG stream rides
    the resume record, so migration neither replays nor skips a draw."""
    cfg, _, _ = model_and_params
    r = _router(model_and_params, fault_plan=FaultPlan(kill={0: 3}))
    outs = r.run(_reqs(cfg, fault_free["lens"], sampled=True))
    _assert_same_tokens(outs, fault_free["sampled"])
    assert r.router_stats["migrated_requests"] > 0


def test_stall_detected_by_progress_tracking(model_and_params, fault_free):
    """A stalled replica raises nothing — the router must notice frozen
    observable state within ``stall_patience`` rounds and migrate."""
    cfg, _, _ = model_and_params
    r = _router(
        model_and_params,
        fault_plan=FaultPlan(stall={1: 2}),
        stall_patience=3,
    )
    outs = r.run(_reqs(cfg, fault_free["lens"]))
    _assert_same_tokens(outs, fault_free["greedy"])
    rs = r.router_stats
    assert rs["healthy"] == [True, False]
    assert "stalled" in rs["fail_reasons"][1]
    assert rs["migrated_requests"] > 0


def test_slow_replica_survives(model_and_params, fault_free):
    """A straggler is not a failure: a slowed replica keeps its work and
    its health; only its pace changes."""
    cfg, _, _ = model_and_params
    r = _router(
        model_and_params, fault_plan=FaultPlan(slow={1: (1, 0.001)})
    )
    outs = r.run(_reqs(cfg, fault_free["lens"]))
    _assert_same_tokens(outs, fault_free["greedy"])
    rs = r.router_stats
    assert rs["healthy"] == [True, True]
    assert rs["migrations"] == 0


def test_kill_with_queued_requests_migrates_queue(model_and_params):
    """More requests than the dead replica's slots: the waiting queue
    (not just live slots) migrates, in order, and everything completes."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7, 6, P, 5, 7, P]
    ref = _engine(model_and_params, num_slots=4).run(_reqs(cfg, lens))
    r = _router(
        model_and_params, num_slots=2, fault_plan=FaultPlan(kill={0: 2})
    )
    outs = r.run(_reqs(cfg, lens))
    _assert_same_tokens(outs, ref)
    assert len(outs) == len(lens) and not r.shed_errors


# ------------------------------------------------------------ routing policy
def test_prefix_affinity_routes_to_warm_replica(model_and_params):
    """A prompt whose chunk-chain is indexed on one replica routes THERE,
    not to the emptier one — predicted hits beat occupancy balance."""
    cfg, _, _ = model_and_params
    r = _router(model_and_params)
    warm = _reqs(cfg, [P])           # lands on replica 0 (balance tie)
    r.run(warm)
    assert r.replica_requests == [1, 0]
    # probe reports predicted hit TOKENS (full pages × page size)
    assert r.engines[0].prefix_probe(warm[0].prompt) == (P // PS) * PS
    hit = _reqs(cfg, [P], uid0=1)    # identical prompt → replica 0 again
    r.run(hit)
    assert r.replica_requests == [2, 0]
    assert r.router_stats["affinity_routed"] == 1


def test_migrated_prefix_hit_request_token_identical(model_and_params):
    """The migrate-of-prefix-hit pin: a request riding replica 0's warm
    prefix index is mid-decode when replica 0 dies; it must finish on
    replica 1 (whose index never saw the prefix) token-identically."""
    cfg, _, _ = model_and_params
    warm = _reqs(cfg, [P])
    # uid1 re-sends the warm PROMPT verbatim (same tokens → full-page
    # chunk-chain hit on whichever replica served uid0); uid2/3 differ
    burst = [
        Request(uid=1, prompt=warm[0].prompt.copy(), max_new_tokens=G),
        *_reqs(cfg, [7, 6], uid0=2),
    ]
    base = _engine(model_and_params)
    ref = base.run([Request(
        uid=r.uid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
    ) for r in warm]) + base.run([Request(
        uid=r.uid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
    ) for r in burst])
    r = _router(model_and_params)
    outs = r.run(warm)
    # arm the kill two steps into the burst — phase 1 already consumed
    # replica 0 steps, so the plan is anchored to its live counter
    r.fault_plan = FaultPlan(kill={0: r.router_stats["replica_steps"][0] + 2})
    outs += [o for o in r.run(burst) if o.uid != warm[0].uid]
    _assert_same_tokens(outs, ref)
    rs = r.router_stats
    assert rs["healthy"] == [False, True]
    assert rs["affinity_routed"] >= 1 and rs["migrated_requests"] > 0


def test_occupancy_balance_spreads_load(model_and_params):
    """Distinct prompts (no affinity anywhere): admissions spread across
    replicas by occupancy instead of piling onto one."""
    cfg, _, _ = model_and_params
    r = _router(model_and_params, prefix_cache=False)
    r.run(_reqs(cfg, [P, 7, 6, 5]))
    assert all(n > 0 for n in r.replica_requests), r.replica_requests
    assert r.router_stats["balance_routed"] == 4


def test_backpressure_bounded_retry_then_completion(model_and_params):
    """Every replica saturated (slots full + queue at cap): the router
    holds requests with bounded retries — nothing errors, nothing drops,
    tokens stay identical."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7, 6, P, 5]
    ref = _engine(model_and_params, num_slots=4).run(_reqs(cfg, lens))
    r = _router(model_and_params, num_slots=1, max_queue=1, max_retries=4)
    outs = r.run(_reqs(cfg, lens))
    _assert_same_tokens(outs, ref)
    assert r.retries > 0, "six requests over two 1-slot replicas with a "\
        "1-deep queue cap must exercise backpressure"
    assert not r.shed_errors


# --------------------------------------------------------------- SLO / sheds
def test_deadline_shed_under_saturation(model_and_params):
    """Saturated replicas + an expiring deadline: the queued request is
    shed with a structured ``deadline_exceeded`` error; survivors finish
    token-identically. Virtual step-indexed clock — one tick per router
    round."""
    cfg, _, _ = model_and_params
    lens = [P, P, 6]
    ref = _engine(model_and_params).run(_reqs(cfg, lens))
    clock = {"t": 0.0}
    r = _router(
        model_and_params, num_slots=1, time_fn=lambda: clock["t"]
    )
    reqs = _reqs(cfg, lens)
    doomed = Request(
        uid=99, prompt=reqs[0].prompt.copy(), max_new_tokens=G,
        deadline_s=2.0,
    )
    for q in [*reqs, doomed]:
        r.submit(q)
    while r.has_work:
        r.step()
        clock["t"] += 1.0
    shed = r.shed_errors
    assert [e.uid for e in shed] == [99]
    assert shed[0].reason == "deadline_exceeded"
    assert r.router_stats["shed_requests"] == 1
    _assert_same_tokens(r.finished, ref)


def test_exceeds_pool_checks_every_replica_best_fit(model_and_params):
    """Heterogeneous replicas: a request only the BIG replica can hold is
    accepted (and served there); one exceeding both is rejected with the
    best-fit shortfall, not the first pool's."""
    _, model, params = model_and_params
    small = ServeEngine(model, params, num_slots=1, max_seq=10)
    big = ServeEngine(model, params, num_slots=1, max_seq=P + G)
    r = ServeRouter(engines=[small, big])
    cfg, _, _ = model_and_params
    fits_big = _reqs(cfg, [P])       # needs 14: small is 4 short
    outs = r.run(fits_big)
    assert len(outs) == 1 and r.replica_requests == [0, 1]
    with pytest.raises(AdmissionError) as ei:
        r.submit(Request(
            uid=7, prompt=fits_big[0].prompt.copy(), max_new_tokens=12,
        ))                           # needs 20: best fit is big, short 6
    assert ei.value.reason == "exceeds_pool"
    assert "replica 1" in str(ei.value) and "6 tokens" in str(ei.value)


def test_all_capable_replicas_dead_sheds_structured(model_and_params):
    """When the only replicas with capacity for a queued request have all
    died, the request is shed with ``no_healthy_replica`` — the healthy
    remainder's work is not torn down by an exception."""
    _, model, params = model_and_params
    cfg, _, _ = model_and_params
    small = ServeEngine(model, params, num_slots=1, max_seq=10)
    big = ServeEngine(model, params, num_slots=1, max_seq=P + G)
    r = ServeRouter(engines=[big, small], fault_plan=FaultPlan(kill={0: 1}))
    fits_small = _reqs(cfg, [4], gen=4)              # either replica
    only_big = _reqs(cfg, [P], uid0=1)               # replica 0 only
    outs = r.run(fits_small + only_big)
    assert [o.uid for o in outs] == [0]
    assert [e.uid for e in r.shed_errors] == [1]
    assert r.shed_errors[0].reason == "no_healthy_replica"


# ----------------------------------------------------------- chaos property
@given(chaos=st.integers(min_value=0, max_value=10**6))
@settings(max_examples=4, deadline=None)
def test_property_random_faults_token_identical(
    model_and_params, fault_free, chaos
):
    """Chaos pin: a random kill/stall fault on a random replica at a
    random early step — interleaved with the standard submission burst —
    never changes a single output token versus the fault-free engine, and
    never drops a request."""
    import random

    cfg, _, _ = model_and_params
    rng = random.Random(chaos)
    kind = rng.choice(["kill", "stall"])
    rid = rng.randrange(2)
    step = rng.randrange(1, 7)
    plan = (
        FaultPlan(kill={rid: step}) if kind == "kill"
        else FaultPlan(stall={rid: step})
    )
    r = _router(model_and_params, fault_plan=plan)
    outs = r.run(_reqs(cfg, fault_free["lens"]))
    assert not r.shed_errors, f"{kind}@{rid}:{step} shed requests"
    _assert_same_tokens(outs, fault_free["greedy"])
    # the replica's step counter only advances while it holds work, so
    # counter > fault step ⟺ the fault engaged — and an engaged fault
    # must have been detected (a drained replica has nothing to stall)
    engaged = r.router_stats["replica_steps"][rid] > step
    assert r.router_stats["healthy"][rid] is (not engaged), (
        f"{kind}@{rid}:{step} engaged={engaged} but health disagrees"
    )


# ----------------------------------------------------------------- plumbing
def test_parse_fault_spec_grammar():
    plan = parse_fault_spec(["kill:1@8", "stall:0@4", "slow:1@2@0.05"])
    assert plan.kill == {1: 8}
    assert plan.stall == {0: 4}
    assert plan.slow == {1: (2, 0.05)}
    # precedence on a shared replica: kill > stall > slow
    assert plan.action(1, 7) == ("slow", 0.05)
    assert plan.action(1, 8) == ("kill", 0.0)
    assert plan.action(0, 3) is None
    for bad in ["boom:1@2", "kill:x@2", "slow:1@2", "kill:1"]:
        with pytest.raises(ValueError):
            parse_fault_spec([bad])


def test_router_stats_shape(model_and_params):
    cfg, _, _ = model_and_params
    r = _router(model_and_params)
    r.run(_reqs(cfg, [P, 6]))
    rs = r.router_stats
    assert rs["replicas"] == 2
    assert len(rs["occupancy"]) == len(rs["queued"]) == 2
    assert rs["migrations"] == 0 and rs["shed_requests"] == 0
    assert rs["affinity_routed"] + rs["balance_routed"] == 2
