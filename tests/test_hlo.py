"""HLO cost-model parser tests (synthetic modules)."""
import numpy as np
import pytest

from repro.utils import hlo

SYNTH = """
HloModule test, is_scheduled=true

%body (arg.1: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg.1 = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%arg.1), index=0
  %x = f32[128,256] get-tuple-element(%arg.1), index=1
  %w = f32[256,256] constant({...})
  %d = f32[128,256] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256] all-reduce(%d), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ip, %ar)
}

%cond (arg.2: (s32[], f32[128,256])) -> pred[] {
  %arg.2 = (s32[], f32[128,256]) parameter(0)
  %i2 = s32[] get-tuple-element(%arg.2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main.1 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,256]) tuple(%zero, %p0)
  %w2 = (s32[], f32[128,256]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %res = f32[128,256] get-tuple-element(%w2), index=1
  %cp = f32[128,256] collective-permute(%res), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  ROOT %out = f32[128,256] copy(%cp)
}
"""


class TestParser:
    def test_trip_count_multiplies_flops(self):
        cost = hlo.analyze(SYNTH)
        # dot: 2*128*256*256 flops, ×10 trips
        expected = 2 * 128 * 256 * 256 * 10
        assert cost.flops == pytest.approx(expected)

    def test_collectives_counted_with_trips(self):
        cost = hlo.analyze(SYNTH)
        kinds = cost.by_kind()
        assert "all-reduce" in kinds and "collective-permute" in kinds
        ar = [c for c in cost.collectives if c.kind == "all-reduce"][0]
        assert ar.count == 10
        assert ar.group_size == 4 and ar.num_groups == 2
        # ring all-reduce: 2*(3/4)*bytes, ×10
        assert ar.link_bytes_per_device == pytest.approx(
            2 * 0.75 * 128 * 256 * 4 * 10
        )

    def test_cross_pod_classification(self):
        cost = hlo.analyze(SYNTH, pod_size=4)
        ar = [c for c in cost.collectives if c.kind == "all-reduce"][0]
        assert not ar.cross_pod  # groups {0-3},{4-7} stay within pods of 4
        cost2 = hlo.analyze(SYNTH, pod_size=2)
        ar2 = [c for c in cost2.collectives if c.kind == "all-reduce"][0]
        assert ar2.cross_pod

    def test_iota_replica_groups(self):
        groups = hlo._parse_iota_groups("[4,2]<=[8]")
        assert groups == [[0, 1], [2, 3], [4, 5], [6, 7]]
        groups = hlo._parse_iota_groups("[2,4]<=[4,2]T(1,0)")
        assert groups == [[0, 2, 4, 6], [1, 3, 5, 7]]

    def test_hbm_bytes_include_loop_body(self):
        cost = hlo.analyze(SYNTH)
        # dot reads x(128KB)+w(256KB), writes 128KB; all-reduce r/w 128KB each;
        # add small. ×10 trips ≥ 10×(dot ops)
        assert cost.hbm_bytes > 10 * (128 * 256 * 4 * 2 + 256 * 256 * 4)

    def test_real_module_smoke(self):
        """Parser handles a real compiled module (saved during development)."""
        import os
        path = "/tmp/hlo_stablelm.txt"
        if not os.path.exists(path):
            pytest.skip("no saved module")
        cost = hlo.analyze(open(path).read())
        assert cost.flops > 1e13
        assert cost.hbm_bytes > 1e12
        assert cost.n_collectives() > 10
