"""Optimizer tests: AdamW semantics, schedules, outer optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig, TrainConfig
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm, lr_schedule
from repro.optim.outer import outer_init, outer_update
from repro.utils.tree import tree_map, tree_norm


class TestAdamW:
    def test_first_step_is_lr_sized(self):
        cfg = TrainConfig(lr=0.1, warmup_steps=0, steps=10, weight_decay=0.0, grad_clip=0)
        params = {"w": jnp.ones((4, 4))}
        grads = {"w": jnp.full((4, 4), 0.5)}
        state = adamw_init(params)
        new, _ = adamw_update(cfg, grads, state, params, lr=0.1)
        # bias-corrected first step ≈ lr·sign(g)
        np.testing.assert_allclose(np.asarray(new["w"]), 1.0 - 0.1, rtol=1e-3)

    def test_weight_decay_only_on_matrices(self):
        cfg = TrainConfig(lr=0.1, weight_decay=0.5, grad_clip=0)
        params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        grads = tree_map(jnp.zeros_like, params)
        new, _ = adamw_update(cfg, grads, adamw_init(params), params, lr=0.1)
        assert float(new["w"][0, 0]) < 1.0     # decayed
        assert float(new["b"][0]) == 1.0       # biases not decayed

    def test_count_increments(self):
        cfg = TrainConfig()
        params = {"w": jnp.ones(3)}
        state = adamw_init(params)
        _, state = adamw_update(cfg, {"w": jnp.ones(3)}, state, params)
        assert int(state["count"]) == 1

    def test_grad_clip(self):
        grads = {"w": jnp.full((100,), 10.0)}
        clipped, norm = clip_by_global_norm(grads, 1.0)
        assert float(norm) == pytest.approx(100.0)
        assert float(tree_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


class TestSchedule:
    def test_warmup_then_decay(self):
        cfg = TrainConfig(lr=1.0, warmup_steps=10, steps=110)
        lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in (1, 5, 10, 60, 110)]
        assert lrs[0] < lrs[1] < lrs[2]          # warmup rising
        assert lrs[2] > lrs[3] > lrs[4]          # cosine falling
        assert lrs[4] >= 0.1 * 0.99              # floor at 10%


class TestOuter:
    def test_none_returns_aggregate(self):
        fed = FederatedConfig(outer_optimizer="none")
        g = {"w": jnp.zeros(3)}
        a = {"w": jnp.ones(3)}
        out, _ = outer_update(fed, g, a, {})
        np.testing.assert_array_equal(np.asarray(out["w"]), 1.0)

    def test_sgd_lr_scales_step(self):
        fed = FederatedConfig(outer_optimizer="sgd", outer_lr=0.5)
        g = {"w": jnp.zeros(3)}
        a = {"w": jnp.ones(3)}
        out, _ = outer_update(fed, g, a, outer_init(fed, g))
        np.testing.assert_allclose(np.asarray(out["w"]), 0.5)

    def test_nesterov_accumulates(self):
        fed = FederatedConfig(outer_optimizer="nesterov", outer_lr=1.0, outer_momentum=0.9)
        g = {"w": jnp.zeros(3)}
        state = outer_init(fed, g)
        a = {"w": jnp.ones(3)}
        out1, state = outer_update(fed, g, a, state)
        # second identical pseudo-gradient: momentum amplifies the step
        out2, state = outer_update(fed, g, a, state)
        assert float(out2["w"][0]) > float(out1["w"][0])
