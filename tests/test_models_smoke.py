"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family runs one forward + one train step on CPU, asserting output
shapes and no NaNs; plus decode-vs-forward consistency where exact."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.models.common import padded_vocab
from repro.optim.adamw import adamw_init, adamw_update
from repro.utils.tree import tree_count_params


def make_batch(cfg, key, b=2, s=32):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.vision_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.arch_type == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_reduced_config_limits(self, arch):
        cfg = get_smoke_config(arch)
        assert cfg.n_layers <= 3
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4

    def test_forward_shapes_and_finite(self, arch, rng):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(rng)
        assert tree_count_params(params) > 0
        b, s = 2, 32
        batch = make_batch(cfg, jax.random.fold_in(rng, 0), b, s)
        logits = model.forward(params, batch)
        exp_s = s + (cfg.vision_seq if cfg.arch_type == "vlm" else 0)
        assert logits.shape == (b, exp_s, padded_vocab(cfg.vocab_size))
        assert np.isfinite(np.asarray(logits[..., : cfg.vocab_size], np.float32)).all()

    def test_train_step_no_nans(self, arch, rng):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(rng)
        opt = adamw_init(params)
        tcfg = TrainConfig(lr=1e-3, steps=10, warmup_steps=1)
        batch = make_batch(cfg, jax.random.fold_in(rng, 3))

        @jax.jit
        def step(params, opt, batch):
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch
            )
            params, opt = adamw_update(tcfg, grads, opt, params)
            return params, opt, loss

        p1, o1, loss1 = step(params, opt, batch)
        p2, _, loss2 = step(p1, o1, batch)
        assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
        assert float(loss2) < float(loss1)  # same batch: loss must drop
        for leaf in jax.tree_util.tree_leaves(p2):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()

    def test_decode_runs_and_finite(self, arch, rng):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(rng)
        batch = make_batch(cfg, jax.random.fold_in(rng, 4), b=2, s=8)
        cache = model.init_cache(params, batch, max_seq=8)
        dec = jax.jit(lambda p, c, t: model.decode(p, c, t))
        for i in range(4):
            cache, logits = dec(params, cache, batch["tokens"][:, i : i + 1])
            assert logits.shape == (2, padded_vocab(cfg.vocab_size))
            assert np.isfinite(np.asarray(logits[:, : cfg.vocab_size], np.float32)).all()


EXACT_DECODE_ARCHS = [
    a for a in ARCH_IDS
    if a not in ("pixtral-12b",)  # vlm decode-from-scratch omits image prefix
]


@pytest.mark.parametrize("arch", EXACT_DECODE_ARCHS)
def test_decode_matches_teacher_forcing(arch, rng):
    """Feeding tokens one-by-one through the cached decode path reproduces
    the full-sequence forward logits (capacity drops disabled for MoE)."""
    cfg = get_smoke_config(arch)
    if cfg.arch_type == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(rng)
    b, s = 2, 12
    batch = make_batch(cfg, jax.random.fold_in(rng, 5), b, s)
    cache = model.init_cache(params, batch, max_seq=s)
    dec = jax.jit(lambda p, c, t: model.decode(p, c, t))
    outs = []
    for i in range(s):
        cache, lg = dec(params, cache, batch["tokens"][:, i : i + 1])
        outs.append(lg)
    a = np.asarray(jnp.stack(outs, 1), np.float32)[..., : cfg.vocab_size]
    fwd = np.asarray(model.forward(params, batch), np.float32)[..., : cfg.vocab_size]
    tol = 0.02 if cfg.arch_type == "audio" else 5e-3
    err = np.max(np.abs(a - fwd)) / (np.max(np.abs(fwd)) + 1e-9)
    assert err < tol, f"decode/forward mismatch rel err {err}"


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "recurrentgemma-2b", "xlstm-125m"])
def test_long_context_ring_decode(arch, rng):
    """Sliding-window / recurrent decode keeps state bounded: decode 3× the
    cache capacity worth of tokens without shape growth or NaNs."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(rng)
    window = 8
    batch = {"tokens": jnp.zeros((1, 1), jnp.int32)}
    cache = model.init_cache(params, batch, max_seq=24, window=window)
    sizes_before = [x.shape for x in jax.tree_util.tree_leaves(cache)]
    dec = jax.jit(lambda p, c, t: model.decode(p, c, t, window=window))
    tok = jnp.zeros((1, 1), jnp.int32)
    for i in range(24):
        cache, logits = dec(params, cache, tok)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
        assert np.isfinite(np.asarray(logits[:, : cfg.vocab_size], np.float32)).all()
    sizes_after = [x.shape for x in jax.tree_util.tree_leaves(cache)]
    assert sizes_before == sizes_after


def test_swa_decode_matches_full_for_short_seq(rng):
    """With seq < window, sliding-window decode == full decode."""
    cfg = get_smoke_config("mistral-nemo-12b")
    model = build_model(cfg)
    params = model.init(rng)
    toks = jax.random.randint(rng, (1, 6), 0, cfg.vocab_size)
    batch = {"tokens": toks}

    def run(window, cap):
        cache = model.init_cache(params, batch, max_seq=cap, window=window)
        outs = []
        c = cache
        for i in range(6):
            c, lg = model.decode(params, c, toks[:, i : i + 1], window=window)
            outs.append(lg)
        return np.asarray(jnp.stack(outs, 1), np.float32)

    full = run(0, 6)
    swa = run(16, 16)
    np.testing.assert_allclose(swa, full, rtol=1e-2, atol=1e-2)
