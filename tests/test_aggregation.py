"""Unit + property tests for the paper's aggregation formulas (§3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import aggregation as agg


def _stacked(key, n_clouds, shapes=((4, 8), (16,), (2, 3, 5))):
    keys = jax.random.split(key, len(shapes))
    return {
        f"w{i}": jax.random.normal(k, (n_clouds,) + s)
        for i, (k, s) in enumerate(zip(keys, shapes))
    }


class TestFedAvg:
    def test_formula1_weighted_by_sample_counts(self, rng):
        """w = Σ n_i/n · w_i exactly."""
        stacked = _stacked(rng, 3)
        counts = jnp.asarray([100.0, 300.0, 600.0])
        w = agg.fedavg_weights(counts)
        np.testing.assert_allclose(np.asarray(w), [0.1, 0.3, 0.6], rtol=1e-6)
        out = agg.weighted_average(stacked, w)
        for k in stacked:
            expected = (
                0.1 * stacked[k][0] + 0.3 * stacked[k][1] + 0.6 * stacked[k][2]
            )
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(expected), rtol=1e-5)

    def test_identical_clouds_fixed_point(self, rng):
        """Aggregating identical replicas returns the replica."""
        single = {k: v[0] for k, v in _stacked(rng, 1).items()}
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (4,) + x.shape), single
        )
        out = agg.weighted_average(stacked, agg.fedavg_weights(jnp.ones(4)))
        for k in single:
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(single[k]), rtol=1e-6
            )

    @given(counts=st.lists(st.integers(1, 10_000), min_size=2, max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_weights_simplex(self, counts):
        w = np.asarray(agg.fedavg_weights(jnp.asarray(counts, jnp.float32)))
        assert abs(w.sum() - 1.0) < 1e-5
        assert (w >= 0).all()


class TestDynamicWeights:
    def test_formula2_softmax_of_neg_loss(self):
        losses = jnp.asarray([1.0, 2.0, 3.0])
        w = np.asarray(agg.dynamic_weights(losses))
        expected = np.exp(-np.asarray([1.0, 2.0, 3.0]))
        expected /= expected.sum()
        np.testing.assert_allclose(w, expected, rtol=1e-6)

    def test_lower_loss_gets_higher_weight(self):
        w = np.asarray(agg.dynamic_weights(jnp.asarray([0.5, 1.5, 2.5])))
        assert w[0] > w[1] > w[2]

    @given(
        losses=st.lists(
            st.floats(0.0, 20.0, allow_nan=False), min_size=2, max_size=8
        ),
        temp=st.floats(0.1, 10.0),
    )
    @settings(max_examples=50, deadline=None)
    def test_simplex_and_monotonicity(self, losses, temp):
        w = np.asarray(agg.dynamic_weights(jnp.asarray(losses, jnp.float32), temp))
        assert abs(w.sum() - 1.0) < 1e-4
        order = np.argsort(losses)
        # weights are non-increasing in loss
        assert (np.diff(w[order]) <= 1e-6).all()


class TestGradientAggregation:
    def test_formula3_matches_manual_sgd(self, rng):
        """w_{t+1} = w_t − η Σ (n_i/n) ∇w_i."""
        grads = _stacked(rng, 3)
        counts = jnp.asarray([1.0, 2.0, 1.0])
        w = agg.fedavg_weights(counts)
        agg_grad = agg.gradient_aggregate(None, grads, w)
        for k in grads:
            manual = (grads[k][0] + 2 * grads[k][1] + grads[k][2]) / 4.0
            np.testing.assert_allclose(np.asarray(agg_grad[k]), np.asarray(manual), rtol=1e-5)


class TestAsyncUpdate:
    def test_formula4_single_cloud(self, rng):
        g = {k: v[0] for k, v in _stacked(rng, 1).items()}
        ci = {k: v + 1.0 for k, v in g.items()}
        out = agg.async_update(g, ci, 0.25)
        for k in g:
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(g[k] + 0.25), rtol=1e-5, atol=1e-5
            )

    def test_alpha_zero_is_identity(self, rng):
        g = {k: v[0] for k, v in _stacked(rng, 1).items()}
        ci = {k: v * 2.0 for k, v in g.items()}
        out = agg.async_update(g, ci, 0.0)
        for k in g:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(g[k]))

    def test_masked_matches_sequential_for_disjoint(self, rng):
        """One arrival per round == formula 4 applied sequentially."""
        stacked = _stacked(rng, 3)
        g = {k: jnp.zeros(v.shape[1:]) for k, v in stacked.items()}
        alphas = jnp.asarray([0.5, 0.3, 0.2])
        out = dict(g)
        for i in range(3):
            arrived = jnp.zeros(3, bool).at[i].set(True)
            out = agg.masked_async_update(out, stacked, alphas, arrived)
        seq = dict(g)
        for i in range(3):
            ci = {k: v[i] for k, v in stacked.items()}
            seq = agg.async_update(seq, ci, alphas[i])
        for k in g:
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(seq[k]), rtol=1e-4, atol=1e-5
            )

    @given(alpha=st.floats(0.0, 1.0))
    @settings(max_examples=30, deadline=None)
    def test_convex_combination_bounds(self, alpha):
        """Result stays between global and cloud params elementwise."""
        g = {"w": jnp.asarray([0.0, 1.0, -2.0])}
        c = {"w": jnp.asarray([1.0, -1.0, 4.0])}
        out = np.asarray(agg.async_update(g, c, alpha)["w"])
        lo = np.minimum(np.asarray(g["w"]), np.asarray(c["w"]))
        hi = np.maximum(np.asarray(g["w"]), np.asarray(c["w"]))
        assert (out >= lo - 1e-6).all() and (out <= hi + 1e-6).all()
