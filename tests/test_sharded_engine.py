"""Mesh-sharded serving tests (``ServeEngine(mesh=...)``).

Contract: tensor-parallel serving must be INVISIBLE in the output. The
single-device paged engine is the oracle — a mesh-sharded engine (attention
heads + KV-pool kv-head slices split over the ``model`` axis through
shard_map, page tables host-side and shard-invariant) must emit BITWISE
token-identical streams on every trace: greedy and sampled, cold admission
and prefix-cache suffix rounds, watermark preemption + resume, jnp and
Pallas-kernel attention. Identity is bitwise by construction (the per-shard
head slices all-gather back to the exact full pre-wo activation; see
``models/sharding.use_tensor_axis``), so these pins are exact, not
tolerance-based.

Device budget: the plain tier-1 run has ONE CPU device — multi-shard
in-process tests skip, and the subprocess probe (2 virtual devices via
XLA_FLAGS, the test_int8_wire idiom) keeps real sharding exercised on every
run. The sharded CI job re-runs this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` where the 1/2/4-mesh
matrix runs in-process."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.launch.engine import (
    Request,
    ServeEngine,
    bucket_length,
    bucket_width,
    make_requests,
)
from repro.launch.mesh import make_serve_mesh
from repro.launch.sampling import SamplingParams
from repro.models.model import localize_config

ARCH = "stablelm-1.6b"
P, G = 8, 6
NDEV = len(jax.devices())

needs = lambda n: pytest.mark.skipif(
    NDEV < n, reason=f"needs {n} devices (run under XLA_FLAGS="
    f"--xla_force_host_platform_device_count={n})"
)


@pytest.fixture(scope="module")
def model_and_params():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _build(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_seq", P + G)
    kw.setdefault("paged_cache", True)
    kw.setdefault("page_size", 4)
    return ServeEngine(model, params, **kw)


def _reqs(cfg, lens, *, gen=G, uid0=0, seed=0, sampling=None):
    base = make_requests(
        cfg, n_requests=len(lens), prompt_len=max(lens), gen_tokens=gen,
        seed=seed,
    )
    return [
        Request(uid=uid0 + j, prompt=r.prompt[: lens[j]],
                max_new_tokens=gen, sampling=sampling)
        for j, r in enumerate(base)
    ]


def _same(a, b):
    ref = {o.uid: o.tokens for o in b}
    assert len(a) == len(b)
    for o in a:
        assert o.tokens == ref[o.uid], (o.uid, o.tokens, ref[o.uid])


# ------------------------------------------------------------ fixed probes
def test_mesh1_identity_and_stats(model_and_params):
    """A 1-device mesh exercises the full shard_map plumbing on any
    machine: same tokens as mesh=None, shard-aware pool_stats."""
    cfg, _, _ = model_and_params
    lens = [3, P, 5, 7]
    base = _build(model_and_params).run(_reqs(cfg, lens))
    eng = _build(model_and_params, mesh=make_serve_mesh(1))
    _same(eng.run(_reqs(cfg, lens)), base)
    ps = eng.pool_stats
    assert ps["shards"] == 1 and ps["mesh_axes"] == {"model": 1}
    assert len(ps["occupancy"]) == 1


def test_unsharded_pool_stats_fields(model_and_params):
    """mesh=None reports the degenerate shard fields (older consumers of
    pool_stats keep working; new ones need no mesh special-case)."""
    eng = _build(model_and_params)
    ps = eng.pool_stats
    assert ps["shards"] == 1 and ps["mesh_axes"] is None
    assert ps["occupancy"] == [0.0]


@needs(2)
@pytest.mark.parametrize("shards", [2, pytest.param(4, marks=needs(4))])
def test_sharded_greedy_identity(model_and_params, shards):
    """Fixed greedy probe: 2- and 4-shard engines emit bitwise the
    single-device paged engine's streams (mixed lengths, slot reuse)."""
    cfg, _, _ = model_and_params
    lens = [3, P, 5, 7, 2, 6]
    base = _build(model_and_params).run(_reqs(cfg, lens))
    eng = _build(model_and_params, mesh=make_serve_mesh(shards))
    _same(eng.run(_reqs(cfg, lens)), base)
    assert eng.pool_stats["shards"] == shards
    assert len(set(eng.pool_stats["occupancy"])) == 1  # shard-invariant


@needs(2)
def test_sharded_sampled_identity(model_and_params):
    """Sampled streams: identical logits bits + identical per-uid PRNG
    streams ⇒ identical draws under sharding."""
    cfg, _, _ = model_and_params
    sp = SamplingParams(temperature=0.9, top_k=37, top_p=0.95, seed=11)
    lens = [4, P, 6, 3]
    base = _build(model_and_params).run(_reqs(cfg, lens, sampling=sp))
    sharded = _build(model_and_params, mesh=make_serve_mesh(2))
    _same(sharded.run(_reqs(cfg, lens, sampling=sp)), base)


@needs(2)
def test_sharded_kernel_paths(model_and_params):
    """Pallas paths under shard_map: paged-decode kernel + suffix-prefill
    kernel run per shard on the local kv-head slice, same tokens."""
    cfg, _, _ = model_and_params
    kw = dict(use_kernel=True, prefix_cache=True, num_slots=3)
    lens = [P, 6, P, 4]  # repeat lens so warm prefix pages get hit
    base = _build(model_and_params, **kw)
    ref = base.run(_reqs(cfg, lens))
    ref2 = base.run(_reqs(cfg, lens, uid0=10))  # warm round → suffix path
    sharded = _build(model_and_params, mesh=make_serve_mesh(2), **kw)
    _same(sharded.run(_reqs(cfg, lens)), ref)
    _same(sharded.run(_reqs(cfg, lens, uid0=10)), ref2)
    assert sharded.suffix_dispatches == base.suffix_dispatches > 0


@needs(2)
def test_sharded_preemption_resume(model_and_params):
    """Tight pool under sharding: watermark admission + youngest-slot OOM
    preemption and token-exact resume fire exactly as on one device, and
    the streams still match the ROOMY single-device engine."""
    cfg, _, _ = model_and_params
    tight = dict(num_slots=3, num_pages=10, watermark_pages=1)
    lens = [P, P, P]
    roomy = _build(model_and_params).run(_reqs(cfg, lens, gen=G + 2))
    base = _build(model_and_params, **tight)
    base_out = base.run(_reqs(cfg, lens, gen=G + 2))
    assert base.preemptions > 0  # the probe must actually preempt
    sharded = _build(model_and_params, mesh=make_serve_mesh(2), **tight)
    out = sharded.run(_reqs(cfg, lens, gen=G + 2))
    assert sharded.preemptions == base.preemptions
    _same(out, base_out)
    _same(out, roomy)


@needs(2)
def test_sharded_prefix_hit_rounds(model_and_params):
    """Prefix-cache admission under sharding: published pages are shared,
    warm rounds take the suffix dispatch, CoW splits fire — all on the
    shard-invariant page table — with bitwise-identical output."""
    cfg, _, _ = model_and_params
    kw = dict(prefix_cache=True, num_slots=3, num_pages=40)
    pre = np.arange(1, 13, dtype=np.int32)

    def trace(uid0=0):
        return [
            Request(uid=uid0 + u,
                    prompt=np.concatenate(
                        [pre, np.full(3 + u, 50 + u, np.int32)]),
                    max_new_tokens=G)
            for u in range(4)
        ]

    base = _build(model_and_params, **kw)
    ref = [base.run(trace()), base.run(trace(10))]
    sharded = _build(model_and_params, mesh=make_serve_mesh(2), **kw)
    got = [sharded.run(trace()), sharded.run(trace(10))]
    for g, r in zip(got, ref):
        _same(g, r)
    assert sharded.suffix_dispatches == base.suffix_dispatches > 0
    assert sharded.cow_copies == base.cow_copies
    assert sharded.pool_stats["prefix_hit_rate"] == \
        base.pool_stats["prefix_hit_rate"] > 0


# ------------------------------------------------------------ property pin
@given(
    lens=st.lists(st.integers(2, P), min_size=1, max_size=5),
    temperature=st.sampled_from([0.0, 0.8]),
)
@settings(max_examples=8, deadline=None)
def test_property_sharded_identity(model_and_params, lens, temperature):
    """Any shared-feasible trace, greedy or sampled: the 2-shard engine is
    bitwise the single-device engine."""
    if NDEV < 2:
        pytest.skip("needs 2 devices")
    cfg, _, _ = model_and_params
    sp = (None if temperature == 0.0 else
          SamplingParams(temperature=temperature, top_k=20, seed=3))
    base = _build(model_and_params).run(_reqs(cfg, lens, gen=3, sampling=sp))
    eng = _build(model_and_params, mesh=make_serve_mesh(2))
    _same(eng.run(_reqs(cfg, lens, gen=3, sampling=sp)), base)


# ---------------------------------------------------- compile-count gates
@needs(2)
def test_sharded_compile_gate(model_and_params):
    """The sharded engine stays within the SAME bucket-ladder compile bound
    as the single-device engine — shard_map adds a mesh, not shapes: page
    tables still ride the cache pytree and admission rounds still bucket."""
    cfg, _, _ = model_and_params
    engine = _build(model_and_params, num_slots=4, page_size=8,
                    mesh=make_serve_mesh(2))
    lens = [3, 5, 7, 9, 11, 13]
    shapes = [(w, l) for w in (1, 2, 3, 4) for l in lens][:21]
    assert len(shapes) >= 20
    uid = 0
    for w, l in shapes:
        engine.run(_reqs(cfg, [l] * w, uid0=uid))
        uid += w
    n_buckets = len(
        {(bucket_width(w, 4), bucket_length(l)) for w, l in shapes}
    )
    compiled = engine.compiles["prefill_slots"]
    assert compiled <= n_buckets, (
        f"sharded engine compiled prefill_slots {compiled} times over "
        f"{len(shapes)} round shapes; bucket ladder allows {n_buckets}"
    )
    assert engine.compiles["decode"] == 1
    before = engine.compiles["prefill_slots"]
    engine.run(_reqs(cfg, [4, 6, 12], uid0=uid))
    assert engine.compiles["prefill_slots"] == before


def test_warm_dedupe_persists_across_calls(model_and_params):
    """Satellite pin: ``warm`` keys traced shapes by the full (shape, mesh
    shards, prefix config) and keeps them across calls — a second warm with
    overlapping lens adds zero compiles and zero runs."""
    eng = _build(model_and_params, num_slots=4)
    eng.warm([5, 9])
    first = dict(eng.compiles)
    assert first["prefill_slots"] > 0
    steps = eng.steps
    eng.warm([5, 9, 6])  # 6 buckets with 9 → fully covered
    assert dict(eng.compiles) == first
    assert eng.steps == steps  # no warm runs actually executed


# ----------------------------------------------------------- construction
def test_mesh_validation(model_and_params):
    _, model, params = model_and_params
    from jax.sharding import Mesh

    bad = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="model"):
        ServeEngine(model, params, mesh=bad, paged_cache=True)
    # head divisibility is validated by the per-shard config split
    with pytest.raises(ValueError, match="divide"):
        localize_config(model.cfg, 3)  # 4 heads over 3 shards
    with pytest.raises(ValueError, match="device"):
        make_serve_mesh(NDEV + 1)


# ------------------------------------------------- subprocess (always on)
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
import numpy as np
from repro.configs import get_smoke_config
from repro.launch.engine import Request, ServeEngine, make_requests
from repro.launch.mesh import make_serve_mesh
from repro.launch.sampling import SamplingParams
from repro.models import build_model

cfg = get_smoke_config("stablelm-1.6b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
assert len(jax.devices()) == 2

def build(**kw):
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_seq", 14)
    kw.setdefault("paged_cache", True)
    kw.setdefault("page_size", 4)
    return ServeEngine(model, params, **kw)

def reqs(lens, gen=6, sampling=None):
    base = make_requests(cfg, n_requests=len(lens), prompt_len=max(lens),
                         gen_tokens=gen, seed=0)
    return [Request(uid=j, prompt=r.prompt[:lens[j]], max_new_tokens=gen,
                    sampling=sampling)
            for j, r in enumerate(base)]

lens = [3, 8, 5, 7]
base = {o.uid: o.tokens for o in build().run(reqs(lens))}
got = {o.uid: o.tokens
       for o in build(mesh=make_serve_mesh(2)).run(reqs(lens))}
assert got == base, (base, got)

# tight pool: preemption + resume under sharding
tight = dict(num_pages=10, watermark_pages=1)
b = build(**tight); bo = {o.uid: o.tokens for o in b.run(reqs([8, 8, 8]))}
s = build(mesh=make_serve_mesh(2), **tight)
so = {o.uid: o.tokens for o in s.run(reqs([8, 8, 8]))}
assert b.preemptions == s.preemptions > 0, (b.preemptions, s.preemptions)
assert so == bo

# sampled stream
sp = SamplingParams(temperature=0.8, top_k=25, seed=5)
bs = {o.uid: o.tokens for o in build().run(reqs(lens, sampling=sp))}
ss = {o.uid: o.tokens
      for o in build(mesh=make_serve_mesh(2)).run(reqs(lens, sampling=sp))}
assert ss == bs
print("SHARDED_ENGINE_OK")
"""


def test_sharded_engine_subprocess_two_devices():
    """Real 2-device sharding on every tier-1 run: the suite process holds
    one CPU device by design (conftest), so the multi-device identity probe
    runs in a subprocess with a forced 2-device host platform."""
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": os.environ.get("HOME", "/tmp"),
             # pin CPU: containers with libtpu installed otherwise probe
             # the (absent) TPU via GCP metadata HTTP retries for minutes
             "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED_ENGINE_OK" in r.stdout
