"""Tiered host↔device KV cache tests (launch/engine.py, host_pages>0).

The contract: the host tier is a pure PERFORMANCE layer. Swap-resume
restores the bitwise pages a preempted slot held, so every trace must be
token-identical to the recompute-resume engine (which stays the oracle) —
across greedy and sampled decoding, chunked and interleaved prefill, and
every degraded path: a tier too small for the victim, an entry dropped by
LRU mid-queue, a shed request, and an export to another engine. What the
tier buys is visible only in the counters: swap-resumes add ZERO prefill
tokens where recompute re-prefills prompt + generated per resume.

``HostTier`` itself is exact host-side bookkeeping (LRU over page-counted
entries), unit-tested first without a model.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.engine import (
    HostTier,
    Request,
    ServeEngine,
    make_requests,
)
from repro.launch.sampling import SamplingParams

ARCH = "stablelm-1.6b"
P, G = 8, 6  # default prompt / generated tokens (ring cap 14)


# --------------------------------------------------------- HostTier (unit)
class TestHostTier:
    def _arrays(self, n):
        return {"k": np.ones((2, n, 3), np.int8)}

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError, match="capacity"):
            HostTier(0)

    def test_put_get_pop_accounting(self):
        host = HostTier(4)
        assert host.put(("swap", 1), self._arrays(2), 2)
        assert host.pages == 2
        assert host.n_pages(("swap", 1)) == 2
        got = host.get(("swap", 1))
        assert got is not None and got["k"].shape[1] == 2
        assert host.get(("swap", 9)) is None
        popped = host.pop(("swap", 1))
        assert popped is not None and popped["k"].shape[1] == 2
        assert host.pages == 0 and host.n_pages(("swap", 1)) == 0
        assert host.pop(("swap", 1)) is None

    def test_lru_eviction_order_and_touch(self):
        host = HostTier(4)
        host.put(("swap", 1), self._arrays(2), 2)
        host.put(("swap", 2), self._arrays(2), 2)
        host.get(("swap", 1))  # touch: 2 becomes LRU
        assert host.put(("swap", 3), self._arrays(2), 2)
        assert host.evictions == 1
        assert host.n_pages(("swap", 2)) == 0  # the untouched entry went
        assert host.n_pages(("swap", 1)) == 2
        assert host.pages == 4

    def test_oversized_entry_refused_without_eviction(self):
        host = HostTier(4)
        host.put(("swap", 1), self._arrays(3), 3)
        assert not host.put(("swap", 2), self._arrays(5), 5)
        assert host.evictions == 0  # refusal must not churn the tier
        assert host.n_pages(("swap", 1)) == 3

    def test_reput_same_key_replaces(self):
        host = HostTier(4)
        host.put(("swap", 1), self._arrays(3), 3)
        host.put(("swap", 1), self._arrays(2), 2)
        assert host.pages == 2
        assert host.n_pages(("swap", 1)) == 2

    def test_clear(self):
        host = HostTier(4)
        host.put(("swap", 1), self._arrays(2), 2)
        host.clear()
        assert host.pages == 0 and host.get(("swap", 1)) is None


# ------------------------------------------------------------ engine layer
@pytest.fixture(scope="module")
def model_and_params():
    from repro.models import build_model

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _build(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_seq", P + G)
    kw.setdefault("paged_cache", True)
    kw.setdefault("page_size", 4)
    return ServeEngine(model, params, **kw)


def _reqs(cfg, lens, *, gen=G, uid0=0, seed=0):
    base = make_requests(
        cfg, n_requests=len(lens), prompt_len=max(lens), gen_tokens=gen,
        seed=seed,
    )
    return [
        Request(uid=uid0 + j, prompt=r.prompt[: lens[j]], max_new_tokens=gen)
        for j, r in enumerate(base)
    ]


def _assert_same_tokens(a, b):
    ref = {o.uid: o.tokens for o in b}
    assert len(a) == len(b)
    for o in a:
        assert o.tokens == ref[o.uid], (
            f"uid {o.uid}: {o.tokens} != {ref[o.uid]}"
        )


def test_host_pages_requires_paged_cache(model_and_params):
    with pytest.raises(ValueError, match="paged"):
        _build(model_and_params, paged_cache=False, host_pages=8)


def test_swap_resume_token_identical_and_prefill_free(model_and_params):
    """The load-bearing identity + perf claim in one trace: a tight pool
    preempts, the swap engine resumes via device scatter, and its output is
    bitwise the ample-pool run — while its prefill_tokens stay at the
    fault-free minimum (sum of prompts) where recompute-resume pays prompt
    + generated again per resume."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7]
    ample = _build(model_and_params)
    ref = ample.run(_reqs(cfg, lens))
    assert ample.preemptions == 0
    recompute = _build(model_and_params, num_pages=6)
    swap = _build(model_and_params, num_pages=6, host_pages=16)
    rc_outs = recompute.run(_reqs(cfg, lens))
    sw_outs = swap.run(_reqs(cfg, lens))
    assert recompute.preemptions > 0 and swap.preemptions > 0
    _assert_same_tokens(rc_outs, ref)
    _assert_same_tokens(sw_outs, ref)
    # swap-resume never re-prefills: every resumed page came back via
    # scatter, so prefill work equals the no-preemption minimum
    sw_stats, rc_stats = swap.pool_stats, recompute.pool_stats
    assert sw_stats["prefill_tokens"] == sum(lens)
    assert rc_stats["prefill_tokens"] > sum(lens)
    assert sw_stats["swapped_out_pages"] > 0
    assert sw_stats["swapped_in_pages"] == sw_stats["swapped_out_pages"]
    assert rc_stats["swapped_out_pages"] == 0
    # tier drained: every swapped entry was consumed by its resume
    assert swap.host.pages == 0
    assert sw_stats["swap_enabled"] and not rc_stats["swap_enabled"]
    assert sw_stats["host_capacity_pages"] == 16


def test_swap_resume_preserves_sampling_streams(model_and_params):
    """Swap-in must not replay or skip PRNG draws: sampled output under a
    swapping pool equals the ample-pool run stream-for-stream."""
    cfg, _, _ = model_and_params
    lens = [P, P, 6]

    def reqs():
        rs = _reqs(cfg, lens)
        for r in rs:
            r.sampling = SamplingParams(
                temperature=0.9, top_k=7, seed=100 + r.uid
            )
        return rs

    ref = _build(model_and_params).run(reqs())
    swap = _build(model_and_params, num_pages=6, host_pages=16)
    outs = swap.run(reqs())
    assert swap.preemptions > 0
    assert swap.swapped_in_pages > 0
    _assert_same_tokens(outs, ref)


def test_interleaved_swap_resume_token_identical(model_and_params):
    """Interleaved prefill preempts lazily-growing slots (possibly
    mid-prompt, pos < len(prompt)); the swap path must restore exactly the
    written prefix and teacher-force the rest through pending."""
    cfg, _, _ = model_and_params
    lens = [P, P, 5]
    ref = _build(model_and_params, prefill="interleaved").run(
        _reqs(cfg, lens)
    )
    swap = _build(
        model_and_params, prefill="interleaved", num_pages=6, host_pages=16
    )
    outs = swap.run(_reqs(cfg, lens))
    assert swap.preemptions > 0
    assert swap.swapped_in_pages > 0
    _assert_same_tokens(outs, ref)


def test_host_tier_too_small_falls_back_to_recompute(model_and_params):
    """A victim bigger than the whole tier refuses the put (no partial
    swap) and resumes through recompute — output unchanged."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7]
    ref = _build(model_and_params).run(_reqs(cfg, lens))
    swap = _build(model_and_params, num_pages=6, host_pages=1)
    outs = swap.run(_reqs(cfg, lens))
    assert swap.preemptions > 0
    assert swap.swapped_out_pages == 0  # every victim held >1 page
    assert swap.swapped_in_pages == 0
    _assert_same_tokens(outs, ref)


def test_dropped_host_entry_falls_back_to_recompute(model_and_params):
    """An entry the tier dropped while its request queued (here: forced
    with clear(), the LRU-eviction worst case) downgrades that resume to
    recompute mid-run — token identity must survive the mixed trace."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7]
    ref = _build(model_and_params).run(_reqs(cfg, lens))
    swap = _build(model_and_params, num_pages=6, host_pages=16)
    for r in _reqs(cfg, lens):
        swap.submit(r)
    outs = []
    while swap.has_work:
        outs.extend(swap.step())
        if swap.swapped_out_pages > 0 and swap.host.pages > 0:
            swap.host.clear()  # drop queued victims' entries
    assert swap.swapped_out_pages > 0
    # the cleared entries never swapped back in
    assert swap.swapped_in_pages < swap.swapped_out_pages
    _assert_same_tokens(sorted(outs, key=lambda o: o.uid), ref)


def test_shed_queued_victim_drops_host_entry(model_and_params):
    """A mid-prefill victim (no generated tokens — NOT mid-stream, so not
    shed-exempt) queued past its deadline is shed AND its host-tier entry
    is released with it; the survivor still matches the ample run."""
    cfg, _, _ = model_and_params
    lens = [14, 14]
    ref = _build(
        model_and_params, prefill="interleaved", max_seq=16, num_slots=2
    ).run(_reqs(cfg, lens, gen=2))
    swap = _build(
        model_and_params, prefill="interleaved", max_seq=16, num_slots=2,
        num_pages=6, host_pages=16,
    )
    for r in _reqs(cfg, lens, gen=2):
        swap.submit(r)
    victim_uid = None
    outs = []
    for _ in range(200):
        outs.extend(swap.step())
        if victim_uid is None:
            for uid, resume in swap._resume.items():
                if not resume.generated and resume.host_key is not None:
                    victim_uid = uid
                    for req in swap.waiting:
                        if req.uid == uid:
                            req.deadline_s = 1e-9
                    break
        if not swap.has_work:
            break
    assert victim_uid is not None, "no mid-prefill swap victim occurred"
    assert not swap.has_work
    assert swap.shed_requests == 1
    assert swap.shed[0].uid == victim_uid
    assert swap.shed[0].reason == "deadline_exceeded"
    # shedding released the tier entry along with the resume record
    assert swap.host.n_pages(("swap", victim_uid)) == 0
    assert swap.host.pages == 0
    assert victim_uid not in swap._resume
    survivors = {o.uid for o in outs}
    assert victim_uid not in survivors
    _assert_same_tokens(
        sorted(outs, key=lambda o: o.uid),
        [o for o in ref if o.uid in survivors],
    )


def test_export_inflight_strips_host_entries(model_and_params):
    """Migration: exported resume records carry no host_key (swapped pages
    live in the SOURCE engine's tier, which is drained), and the importing
    engine resumes through recompute token-identically."""
    cfg, _, _ = model_and_params
    lens = [P, P, 7]
    ref = _build(model_and_params).run(_reqs(cfg, lens))
    src = _build(model_and_params, num_pages=6, host_pages=16)
    for r in _reqs(cfg, lens):
        src.submit(r)
    while src.has_work and src.host.pages == 0:
        src.step()
    assert src.host.pages > 0, "no swapped-out victim queued at export time"
    items = src.export_inflight()
    assert src.host.pages == 0  # exported entries released, none leaked
    assert all(
        resume is None or resume.host_key is None for _, resume in items
    )
    assert not src.has_work
    dst = _build(model_and_params)  # no tier: only recompute can resume
    dst.import_inflight(items)
    outs = src.finished + dst.run()
    _assert_same_tokens(sorted(outs, key=lambda o: o.uid), ref)


def test_prefix_demote_promote_round_trip(model_and_params):
    """Cold prefix pages demoted under index pressure come BACK: a later
    radix miss promotes the host copy into a fresh pool page and serves the
    prompt as a prefix hit, token-identically to the original run."""
    cfg, _, _ = model_and_params
    engine = _build(
        model_and_params, max_seq=16, num_slots=1, num_pages=8,
        prefix_cache=True, prefix_cache_pages=2, host_pages=8,
    )
    req_a = _reqs(cfg, [8], gen=4, uid0=0, seed=0)
    req_b = _reqs(cfg, [8], gen=4, uid0=1, seed=7)
    assert list(req_a[0].prompt) != list(req_b[0].prompt)
    ref = engine.run(req_a)  # publishes A's 2 full prompt pages
    engine.run(req_b)        # tiny index: B's pages evict A's → demote
    assert engine.host_demoted_pages >= 2
    assert engine.host.pages > 0
    again = Request(uid=10, prompt=req_a[0].prompt, max_new_tokens=4)
    outs = engine.run([again])
    assert engine.host_promote_hits == 2  # both of A's pages came back
    assert engine.prefix_hit_pages >= 2
    assert outs[0].tokens == ref[0].tokens
