"""Shared fixtures. NOTE: no XLA_FLAGS device override here — tests run on
the real single CPU device; only launch/dryrun.py requests 512 host devices."""
import gc

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_state_between_modules():
    """Free compiled XLA executables after each test module.

    Engine-heavy modules each build hundreds of jitted executables
    (every ServeEngine wraps its own jit closures); reference cycles keep
    them alive past the test that made them, and with enough modules in
    one process the accumulated JIT code eventually segfaults XLA's CPU
    backend_compile (reproducible at the same compile across full-suite
    runs; any module alone is fine). Dropping the caches between modules
    bounds live compiled state to one module's worth. Cross-module jit
    reuse is almost nil — engines are per-test — so this costs little."""
    yield
    gc.collect()       # break engine cycles so cache entries are collectable
    jax.clear_caches()
    gc.collect()


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run slow tests (full smoke sweep, subprocess dry-runs)",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
