"""Shared fixtures. NOTE: no XLA_FLAGS device override here — tests run on
the real single CPU device; only launch/dryrun.py requests 512 host devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run slow tests (full smoke sweep, subprocess dry-runs)",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
