"""Pallas TPU kernel: flash-decode attention over a ring-buffer KV cache.

The long_500k serving shape decodes ONE token against a sliding-window ring
cache; this kernel is that hot path. Online-softmax accumulation over cache
chunks keeps VMEM at O(chunk · head_dim):

    grid = (B, Hkv, C/CK); the last grid axis is the streaming reduction —
    running (m, l, acc) live in VMEM scratch across grid steps (TPU grid
    iteration is sequential per core), the output block is written on the
    final chunk.

Ring-buffer masking is position arithmetic, not data movement: slot s holds
global position  pos − ((pos mod C) − s) mod C ; valid ⇔ within
[pos−window+1, pos]. GQA is handled by blocking all G = H/Hkv query heads of
one KV head into a single (G, hd) q tile — one MXU matmul per chunk."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0**30


def _swa_kernel(
    pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, ck: int, cap: int, window: int, scale: float,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-row position: pos_ref is (B, 1) in SMEM; grid axis 0 is the batch
    # row, so each program masks against its own slot's depth (continuous
    # batching runs every row at a different position).
    pos = pos_ref[pl.program_id(0), 0]
    q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (CK, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (G, CK)

    slots = j * ck + jax.lax.broadcasted_iota(jnp.int32, (1, ck), 1)
    slot_w = pos % cap
    gpos = pos - (slot_w - slots) % cap
    lo = jnp.maximum(pos - (window - 1), 0) if window > 0 else 0
    valid = (gpos >= lo) & (gpos <= pos)           # (1, CK)
    s = jnp.where(valid, s, NEG)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                          # (G, CK)
    alpha = jnp.exp(m_prev - m_new)                 # (G, 1)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (G, hd)
    acc_new = acc_prev * alpha + pv
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == (cap // ck) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def _chunk(cap: int) -> int:
    for ck in (512, 256, 128, 64):
        if cap % ck == 0 and cap >= ck:
            return ck
    return cap


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def swa_decode(
    q: jax.Array,          # (B, Hkv, G, hd)
    k_cache: jax.Array,    # (B, C, Hkv, hd)
    v_cache: jax.Array,    # (B, C, Hkv, hd)
    pos: jax.Array,        # () or (B,) i32 — tokens already cached per row
    window: int = 0,
    *,
    interpret: bool = True,
) -> jax.Array:
    b, hkv, g, hd = q.shape
    cap = k_cache.shape[1]
    ck = _chunk(cap)
    scale = hd**-0.5
    kernel = functools.partial(
        _swa_kernel, ck=ck, cap=cap, window=window, scale=scale
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        grid=(b, hkv, cap // ck),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, ck, 1, hd), lambda b_, h, j: (b_, j, h, 0)),
            pl.BlockSpec((1, ck, 1, hd), lambda b_, h, j: (b_, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, h, j: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(
        jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1), (b, 1)),
        q, k_cache, v_cache,
    )
