"""Pallas TPU kernels for the framework's compute hot spots.

Layout: one ``<name>.py`` per kernel (pl.pallas_call + BlockSpec),
``ops.py`` with the jit'd public wrappers (pytree plumbing + kernel/ref
dispatch), ``ref.py`` with the pure-jnp oracles every kernel is tested
against. Kernels target TPU; on this CPU container they are validated in
``interpret=True`` mode."""
from repro.kernels.ops import (
    dp_transmit,
    int8_encode_leaf,
    int8_roundtrip_leaf,
    swa_decode_attention,
    topk_sparsify_leaf,
    tree_sq_norm,
)

__all__ = [
    "dp_transmit",
    "int8_encode_leaf",
    "int8_roundtrip_leaf",
    "swa_decode_attention",
    "topk_sparsify_leaf",
    "tree_sq_norm",
]
