"""Pallas TPU kernel: block-local top-k gradient sparsification (§3.2).

TPU adaptation (DESIGN.md §2.4): no sort. The per-row k-th-largest magnitude
is found by k rounds of masked vector max — every operation is a VPU
reduce/select over a (ROWS, 256) VMEM tile, fully lane-parallel. Ties at the
threshold are kept (threshold semantics, matching ref.topk_sparsify_ref).

Tile shape (8, 256): 8 sublanes × 2 lane-groups of 128 — one fp32 VREG tile
pair per row-block, k ≤ 64 keeps the loop cheap next to the HBM round trip
(the op is memory-bound: 8 KiB in / 8 KiB out per tile)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
BLOCK = 256


def _topk_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)            # (ROWS, BLOCK)
    mag = jnp.abs(x)

    def body(_, carry):
        # per row: lower thr to the next distinct magnitude until the number
        # of elements ≥ thr reaches k (ties counted as a group, matching the
        # oracle's "k-th largest" threshold semantics)
        active, thr, cnt = carry
        cur = jnp.max(jnp.where(active, mag, -1.0), axis=1, keepdims=True)
        ties = jnp.sum((mag == cur).astype(jnp.int32), axis=1, keepdims=True)
        need = cnt < k
        thr = jnp.where(need, cur, thr)
        cnt = cnt + jnp.where(need, ties, 0)
        active = active & (mag < cur)
        return active, thr, cnt

    init = (
        jnp.ones(mag.shape, jnp.bool_),
        jnp.zeros((ROWS, 1), jnp.float32),
        jnp.zeros((ROWS, 1), jnp.int32),
    )
    _, thr, _ = jax.lax.fori_loop(0, k, body, init)
    o_ref[...] = jnp.where(mag >= thr, x, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_sparsify(x: jax.Array, k: int, *, interpret: bool = True) -> jax.Array:
    """x: (nb, 256) fp32 → same shape with sub-threshold entries zeroed.

    nb must be a multiple of 8 (pad upstream)."""
    nb, block = x.shape
    assert block == BLOCK, f"expected block {BLOCK}, got {block}"
    assert nb % ROWS == 0, f"rows {nb} not a multiple of {ROWS}"
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((nb, block), x.dtype),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
        interpret=interpret,
    )(x)
