"""Pallas TPU kernels: fused DP-SGD transmit transform (§3.1 security).

Two passes over each update tensor (viewed as (nb, 256) fp32 rows):

1. ``sq_norm`` — tiled Σx² reduction. All grid steps map to the same (1,1)
   output block; TPU grid iteration is sequential per core, so the kernel
   accumulates into the output block across steps (initializing at step 0).
   The host combines per-leaf partials into the global pytree norm.
2. ``clip_noise`` — out = x·scale + σ·noise, fusing the clip rescale and the
   Gaussian perturbation in one HBM round trip (noise is generated upstream
   with jax.random — counter-based RNG on TPU; keeping it outside makes the
   kernel deterministic and testable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
BLOCK = 256


def _sq_norm_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(x * x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sq_norm(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """(nb, 256) fp32 → () squared L2 norm."""
    nb, block = x.shape
    assert block == BLOCK and nb % ROWS == 0
    out = pl.pallas_call(
        _sq_norm_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        interpret=interpret,
    )(x)
    return out[0, 0]


def _clip_noise_kernel(x_ref, scale_ref, noise_ref, o_ref, *, stddev: float):
    x = x_ref[...].astype(jnp.float32)
    s = scale_ref[0, 0]
    o_ref[...] = (x * s + stddev * noise_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stddev", "interpret"))
def clip_noise(
    x: jax.Array, scale: jax.Array, noise: jax.Array, stddev: float,
    *, interpret: bool = True,
) -> jax.Array:
    """out = x·scale + stddev·noise. x/noise: (nb, 256); scale: () fp32."""
    nb, block = x.shape
    assert block == BLOCK and nb % ROWS == 0
    return pl.pallas_call(
        functools.partial(_clip_noise_kernel, stddev=stddev),
        out_shape=jax.ShapeDtypeStruct((nb, block), x.dtype),
        grid=(nb // ROWS,),
        in_specs=[
            pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
        interpret=interpret,
    )(x, scale.reshape(1, 1), noise)
