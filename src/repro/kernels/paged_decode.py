"""Pallas TPU kernel: length-aware paged flash-decode over ring-buffer KV,
in two flavors — per-row contiguous rings and a SHARED page-table pool.

``swa_decode`` streams EVERY cache chunk for every batch row, so a slot
holding 8 tokens in a 512-slot ring pays the same HBM traffic and MXU time
as a full slot. This kernel is its paged sibling for the continuous-batching
engine, where rows (slots) sit at wildly different depths: the ring is cut
into pages of ``page`` slots, the per-row number of LIVE pages

    live_pages[b] = ceil(min(pos[b] + 1, C) / page)

is scalar-prefetched (``pltpu.PrefetchScalarGridSpec``), and the grid is
(B, Hkv, C/page) where dead pages are skipped two ways:

* the k/v index map clamps the page index to ``live_pages[b] - 1``, so a
  dead page issues NO new DMA (it re-reads the already-resident last live
  page — the standard paged-attention trick);
* the kernel body runs under ``pl.when(j < live_pages[b])``, so the MXU
  work is skipped outright.

A page is dead exactly when every one of its slots fails the ring validity
mask, which happens iff the ring has not wrapped past it (slot index >
pos): skipping it is therefore BITWISE identical to the unpaged kernel —
a fully-masked chunk contributes exp(NEG − m) == 0.0 to the online-softmax
state (and a leading garbage chunk is annihilated exactly by
``alpha = exp(NEG − m_new) == 0.0`` at the first live chunk). Tests pin
paged == unpaged bitwise and both against the jnp oracle.

Note ``live_pages`` depends on ``pos`` only through ``min(pos + 1, C)``:
once a row's ring wraps, every page is live and the kernel degrades to
exactly ``swa_decode``. The win is the engine's common case — short or
freshly admitted slots far from wrap.

Page-table mode (``table`` passed): the KV cache is ONE shared pool of
physical pages, shape (P, page, Hkv, hd) with no batch dimension, and
``table`` is a (B, T) int32 map — row b's logical page j lives at pool
page ``table[b, j]``, so a slot's pages may sit ANYWHERE in the pool
(vLLM-PagedAttention layout). The table rows are scalar-prefetched along
with ``pos``/``live_pages`` and drive the k/v DMA index map directly:

    kv_block(b, h, j) = pool[table[b, min(j, live_pages[b]-1)]]

Everything else — the ring-position validity mask over LOGICAL slot
indices ``j·page + i`` with capacity C = T·page, the live-page gating, the
online-softmax state — is identical to ring mode, so the output is bitwise
equal to the contiguous paged kernel at the SAME page size run over the
gathered cache ``pool[table].reshape(B, C, Hkv, hd)`` (tests pin exactly
that; comparing against ``swa_decode`` instead is only allclose when the
page size differs from its auto chunk — online softmax reassociates).

int8 pool mode (``k_scale``/``v_scale`` passed with ``table``): the pool
pages are int8 with one f32 scale per page slot per kv-head, shape
(P, page, Hkv), riding the SAME scalar-prefetched table indirection as the
pages themselves. The body dequantizes each block to the fp pool dtype
(``kv_quant``'s row scheme inverted: ``q·s`` in f32, cast) before the
unchanged online-softmax math, so the int8 kernel is bitwise equal to the
fp kernel run over the jnp-dequantized pool — the pin the tests use; the
tolerance story vs. the fp ENGINE lives at engine level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.swa_decode import _chunk

NEG = -2.0**30


def _paged_kernel(
    *refs, page: int, cap: int, window: int, scale: float, deq=None,
):
    # refs = (pos_ref, pages_ref, [table_ref,] q_ref, k_ref, v_ref,
    #         [ks_ref, vs_ref,] o_ref, m_ref, l_ref, acc_ref) — the optional
    #         table_ref (page-table mode) is consumed by the kv index maps,
    #         not the body: the body masks LOGICAL slot indices, identical
    #         in both modes. With ``deq`` set (int8 pool mode) the k/v pool
    #         blocks are int8 and ks/vs carry one f32 scale per page slot
    #         per kv-head; dequant happens here, in-body, reproducing
    #         ``quantize.kv_dequant(..., dtype=deq)`` bitwise so the output
    #         equals the fp kernel run over the jnp-dequantized pool.
    pos_ref, pages_ref = refs[0], refs[1]
    if deq is not None:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs[-9:]
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs[-7:]
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_pages = cap // page

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < pages_ref[b])
    def _live_page():
        pos = pos_ref[b]
        q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
        k = k_ref[0, :, 0]                             # (page, hd)
        v = v_ref[0, :, 0]
        if deq is not None:
            k = (k.astype(jnp.float32) * ks_ref[0, :, 0][:, None]).astype(deq)
            v = (v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]).astype(deq)
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                      # (G, page)

        slots = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        slot_w = pos % cap
        gpos = pos - (slot_w - slots) % cap
        lo = jnp.maximum(pos - (window - 1), 0) if window > 0 else 0
        valid = (gpos >= lo) & (gpos <= pos)           # (1, page)
        s = jnp.where(valid, s, NEG)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                          # (G, page)
        alpha = jnp.exp(m_prev - m_new)                 # (G, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )                                               # (G, hd)
        acc_new = acc_prev * alpha + pv
        m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("window", "page", "interpret"))
def paged_decode(
    q: jax.Array,          # (B, Hkv, G, hd)
    k_cache: jax.Array,    # (B, C, Hkv, hd) — or (P, page, Hkv, hd) pool
    v_cache: jax.Array,    # same layout as k_cache
    pos: jax.Array,        # () or (B,) i32 — tokens already cached per row
    window: int = 0,
    *,
    page: int = 0,         # 0 = auto (largest of 512/256/128/64 dividing C)
    table: jax.Array | None = None,  # (B, T) i32 page table → pool mode
    k_scale: jax.Array | None = None,  # (P, page, Hkv) f32 — int8 pool mode
    v_scale: jax.Array | None = None,
    interpret: bool = True,
) -> jax.Array:
    b, hkv, g, hd = q.shape
    if table is not None:
        return _table_decode(
            q, k_cache, v_cache, pos, table, window=window,
            k_scale=k_scale, v_scale=v_scale, interpret=interpret,
        )
    assert k_scale is None and v_scale is None, (
        "int8 pool scales require page-table mode"
    )
    cap = k_cache.shape[1]
    pg = page or _chunk(cap)
    assert cap % pg == 0, f"cap {cap} not divisible by page {pg}"
    scale = hd**-0.5
    n_pages = cap // pg

    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    # pages holding at least one slot the ring head has reached
    live = jnp.minimum(pos_b + 1, cap)
    pages = jnp.clip((live + pg - 1) // pg, 1, n_pages)

    kernel = functools.partial(
        _paged_kernel, page=pg, cap=cap, window=window, scale=scale
    )

    def kv_map(b_, h, j, pos_ref, pages_ref):
        # dead pages re-read the last live page: no fresh DMA
        return (b_, jnp.minimum(j, pages_ref[b_] - 1), h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h, j, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, pg, 1, hd), kv_map),
            pl.BlockSpec((1, pg, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, h, j, *_: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(pos_b, pages, q, k_cache, v_cache)


def _table_decode(
    q: jax.Array,          # (B, Hkv, G, hd)
    k_pool: jax.Array,     # (P, page, Hkv, hd) shared physical page pool
    v_pool: jax.Array,     # (P, page, Hkv, hd)
    pos: jax.Array,        # () or (B,) i32
    table: jax.Array,      # (B, T) i32 — logical page j of row b lives at
    #                        pool page table[b, j]; entries past the row's
    #                        live span are never dereferenced (index map
    #                        clamps to the last live page first)
    *,
    window: int = 0,
    k_scale: jax.Array | None = None,  # (P, page, Hkv) f32 per-slot-per-head
    v_scale: jax.Array | None = None,  # scales → int8 pool mode (dequant
    #                        in-body to q.dtype, the fp pool dtype)
    interpret: bool = True,
) -> jax.Array:
    b, hkv, g, hd = q.shape
    p_total, pg = k_pool.shape[0], k_pool.shape[1]
    t_w = table.shape[1]
    cap = t_w * pg         # logical ring capacity per row
    scale = hd**-0.5
    quant = k_scale is not None
    assert quant == (v_scale is not None), "need both or neither scale pool"

    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    live = jnp.minimum(pos_b + 1, cap)
    pages = jnp.clip((live + pg - 1) // pg, 1, t_w)
    table = jnp.asarray(table, jnp.int32)

    kernel = functools.partial(
        _paged_kernel, page=pg, cap=cap, window=window, scale=scale,
        deq=q.dtype if quant else None,
    )

    def kv_map(b_, h, j, pos_ref, pages_ref, table_ref):
        # page-table indirection: logical page j of row b_ lives wherever
        # the slot's table row says; dead logical pages re-read the last
        # live one (clamp BEFORE the table lookup, so an unallocated table
        # entry — by convention 0, the reserved scratch page — is never
        # the target of a fresh DMA for a live computation)
        return (table_ref[b_, jnp.minimum(j, pages_ref[b_] - 1)], 0, h, 0)

    def scale_map(b_, h, j, pos_ref, pages_ref, table_ref):
        # scales ride the same table indirection as their pages
        return (table_ref[b_, jnp.minimum(j, pages_ref[b_] - 1)], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda b_, h, j, *_: (b_, h, 0, 0)),
        pl.BlockSpec((1, pg, 1, hd), kv_map),
        pl.BlockSpec((1, pg, 1, hd), kv_map),
    ]
    inputs = [q, k_pool, v_pool]
    if quant:
        in_specs += [
            pl.BlockSpec((1, pg, 1), scale_map),
            pl.BlockSpec((1, pg, 1), scale_map),
        ]
        inputs += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, t_w),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, h, j, *_: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(pos_b, pages, table, *inputs)
