"""Pallas TPU kernel: length-aware paged flash-decode over ring-buffer KV.

``swa_decode`` streams EVERY cache chunk for every batch row, so a slot
holding 8 tokens in a 512-slot ring pays the same HBM traffic and MXU time
as a full slot. This kernel is its paged sibling for the continuous-batching
engine, where rows (slots) sit at wildly different depths: the ring is cut
into pages of ``page`` slots, the per-row number of LIVE pages

    live_pages[b] = ceil(min(pos[b] + 1, C) / page)

is scalar-prefetched (``pltpu.PrefetchScalarGridSpec``), and the grid is
(B, Hkv, C/page) where dead pages are skipped two ways:

* the k/v index map clamps the page index to ``live_pages[b] - 1``, so a
  dead page issues NO new DMA (it re-reads the already-resident last live
  page — the standard paged-attention trick);
* the kernel body runs under ``pl.when(j < live_pages[b])``, so the MXU
  work is skipped outright.

A page is dead exactly when every one of its slots fails the ring validity
mask, which happens iff the ring has not wrapped past it (slot index >
pos): skipping it is therefore BITWISE identical to the unpaged kernel —
a fully-masked chunk contributes exp(NEG − m) == 0.0 to the online-softmax
state (and a leading garbage chunk is annihilated exactly by
``alpha = exp(NEG − m_new) == 0.0`` at the first live chunk). Tests pin
paged == unpaged bitwise and both against the jnp oracle.

Note ``live_pages`` depends on ``pos`` only through ``min(pos + 1, C)``:
once a row's ring wraps, every page is live and the kernel degrades to
exactly ``swa_decode``. The win is the engine's common case — short or
freshly admitted slots far from wrap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.swa_decode import _chunk

NEG = -2.0**30


def _paged_kernel(
    pos_ref, pages_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, page: int, cap: int, window: int, scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_pages = cap // page

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < pages_ref[b])
    def _live_page():
        pos = pos_ref[b]
        q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                      # (G, page)

        slots = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        slot_w = pos % cap
        gpos = pos - (slot_w - slots) % cap
        lo = jnp.maximum(pos - (window - 1), 0) if window > 0 else 0
        valid = (gpos >= lo) & (gpos <= pos)           # (1, page)
        s = jnp.where(valid, s, NEG)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                          # (G, page)
        alpha = jnp.exp(m_prev - m_new)                 # (G, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )                                               # (G, hd)
        acc_new = acc_prev * alpha + pv
        m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("window", "page", "interpret"))
def paged_decode(
    q: jax.Array,          # (B, Hkv, G, hd)
    k_cache: jax.Array,    # (B, C, Hkv, hd)
    v_cache: jax.Array,    # (B, C, Hkv, hd)
    pos: jax.Array,        # () or (B,) i32 — tokens already cached per row
    window: int = 0,
    *,
    page: int = 0,         # 0 = auto (largest of 512/256/128/64 dividing C)
    interpret: bool = True,
) -> jax.Array:
    b, hkv, g, hd = q.shape
    cap = k_cache.shape[1]
    pg = page or _chunk(cap)
    assert cap % pg == 0, f"cap {cap} not divisible by page {pg}"
    scale = hd**-0.5
    n_pages = cap // pg

    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    # pages holding at least one slot the ring head has reached
    live = jnp.minimum(pos_b + 1, cap)
    pages = jnp.clip((live + pg - 1) // pg, 1, n_pages)

    kernel = functools.partial(
        _paged_kernel, page=pg, cap=cap, window=window, scale=scale
    )

    def kv_map(b_, h, j, pos_ref, pages_ref):
        # dead pages re-read the last live page: no fresh DMA
        return (b_, jnp.minimum(j, pages_ref[b_] - 1), h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h, j, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, pg, 1, hd), kv_map),
            pl.BlockSpec((1, pg, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, h, j, *_: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(pos_b, pages, q, k_cache, v_cache)
