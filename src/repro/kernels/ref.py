"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(shape/dtype sweeps, assert_allclose). They are also the CPU fallbacks the
framework uses when kernels are disabled."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ topk_compress
def topk_sparsify_ref(x: jax.Array, k: int, block: int = 256) -> jax.Array:
    """Block-local magnitude top-k with threshold (tie-keeping) semantics.

    x: (nb, block) fp32 → same shape, entries below the per-row k-th largest
    magnitude zeroed."""
    mag = jnp.abs(x)
    kth = jax.lax.top_k(mag, k)[0][:, -1:]
    return jnp.where(mag >= kth, x, 0.0)


# ------------------------------------------------------------------ quantize
def int8_roundtrip_ref(x: jax.Array) -> jax.Array:
    """Per-row symmetric int8 quantize→dequantize. x: (nb, block) fp32."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale


def int8_encode_ref(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


# ------------------------------------------------------------------- dp_clip
def sq_norm_ref(x: jax.Array) -> jax.Array:
    """Σ x² over everything → () fp32."""
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def clip_noise_ref(
    x: jax.Array, scale: jax.Array, noise: jax.Array, stddev: float
) -> jax.Array:
    """out = x·scale + stddev·noise (the fused DP transmit transform)."""
    return x * scale + stddev * noise


# ---------------------------------------------------------------- swa_decode
def swa_decode_ref(
    q: jax.Array,       # (B, Hkv, G, hd)
    k: jax.Array,       # (B, C, Hkv, hd)   ring-buffer cache (rotated keys)
    v: jax.Array,       # (B, C, Hkv, hd)
    pos: jax.Array,     # () or (B,)  tokens already cached per row
    window: int,        # attention span (0 = all cached)
) -> jax.Array:
    """Single-token flash-decode over a ring-buffer KV cache (oracle).

    Slot s holds global position  pos - ((pos % C) - s) mod C ; valid slots
    are those within [max(pos-window+1, 0), pos]. ``pos`` may be scalar
    (lockstep batch) or (B,) (per-slot positions, continuous batching)."""
    b, c, hkv, hd = k.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))  # (B,)
    slot = pos % c
    slots = jnp.arange(c)
    gpos = pos[:, None] - (slot[:, None] - slots[None, :]) % c  # (B, C)
    lo = jnp.maximum(pos - (window - 1), 0) if window > 0 else jnp.zeros_like(pos)
    valid = (gpos >= lo[:, None]) & (gpos <= pos[:, None])

    scores = jnp.einsum(
        "bkgd,bskd->bkgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd**-0.5)
    scores = jnp.where(valid[:, None, None, :], scores, -2.0**30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_ref(
    q: jax.Array,       # (B, Hkv, G, hd)
    k: jax.Array,       # (B, C, Hkv, hd)   ring-buffer cache (rotated keys)
    v: jax.Array,       # (B, C, Hkv, hd)
    pos: jax.Array,     # () or (B,)  tokens already cached per row
    window: int,        # attention span (0 = all cached)
) -> jax.Array:
    """Length-aware paged decode oracle (kernels/paged_decode.py).

    Identical to ``swa_decode_ref`` with an explicit per-row live-span mask
    ``slot < min(pos + 1, C)`` intersected in. A slot beyond the live span
    is already invalid under the ring-position mask (its reconstructed
    global position is negative), so the intersection equals the original
    valid set and the output is BITWISE equal to ``swa_decode_ref`` — the
    paged kernel's page skipping must be invisible, and this oracle states
    that in jnp terms."""
    b, c, hkv, hd = k.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))  # (B,)
    slot = pos % c
    slots = jnp.arange(c)
    gpos = pos[:, None] - (slot[:, None] - slots[None, :]) % c  # (B, C)
    lo = jnp.maximum(pos - (window - 1), 0) if window > 0 else jnp.zeros_like(pos)
    live = jnp.minimum(pos + 1, c)                              # (B,)
    valid = (gpos >= lo[:, None]) & (gpos <= pos[:, None])
    valid &= slots[None, :] < live[:, None]                     # page mask

    scores = jnp.einsum(
        "bkgd,bskd->bkgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd**-0.5)
    scores = jnp.where(valid[:, None, None, :], scores, -2.0**30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gather_pages_ref(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Materialize per-row contiguous ring caches from a shared page pool.

    pool: (P, page, Hkv, hd) physical pages; table: (B, T) i32 page map →
    (B, T·page, Hkv, hd). This is the layout bridge between the page-table
    world and every contiguous-ring oracle: logical slot c of row b is
    ``pool[table[b, c // page], c % page]``."""
    b, t_w = table.shape
    page, hkv, hd = pool.shape[1:]
    return pool[table].reshape(b, t_w * page, hkv, hd)


def dequant_pool_ref(
    pool_q: jax.Array,   # (P, page, Hkv, hd) int8 pages
    scales: jax.Array,   # (P, page, Hkv) f32 per-slot-per-head scales
    dtype=jnp.float32,
) -> jax.Array:
    """Dequantize an int8 page pool to its fp equivalent (the value set the
    int8 kernels' in-body dequant reproduces bitwise): q·s in f32, cast.
    Identical math to ``quantize.kv_dequant`` — duplicated here so the
    oracle module stays self-contained."""
    return (pool_q.astype(jnp.float32) * scales[..., None]).astype(dtype)


def paged_table_decode_int8_ref(
    q: jax.Array,        # (B, Hkv, G, hd)
    k_pool: jax.Array,   # (P, page, Hkv, hd) int8
    v_pool: jax.Array,   # (P, page, Hkv, hd) int8
    k_scale: jax.Array,  # (P, page, Hkv) f32
    v_scale: jax.Array,  # (P, page, Hkv) f32
    pos: jax.Array,
    table: jax.Array,
    window: int,
) -> jax.Array:
    """int8 page-table decode oracle: dequantize the pool to the q dtype
    (what the kernel does in-body), then the plain gather + ring oracle."""
    return paged_table_decode_ref(
        q,
        dequant_pool_ref(k_pool, k_scale, q.dtype),
        dequant_pool_ref(v_pool, v_scale, q.dtype),
        pos, table, window,
    )


def suffix_prefill_int8_ref(
    q, k_suf, v_suf, pool_k, pool_v, k_scale, v_scale, table, starts,
    *, prefix_width=None,
):
    """int8-pool suffix-prefill oracle: dequantized pool through the
    gather-concat reference."""
    return suffix_prefill_ref(
        q, k_suf, v_suf,
        dequant_pool_ref(pool_k, k_scale, q.dtype),
        dequant_pool_ref(pool_v, v_scale, q.dtype),
        table, starts, prefix_width=prefix_width,
    )


def paged_table_decode_ref(
    q: jax.Array,       # (B, Hkv, G, hd)
    k_pool: jax.Array,  # (P, page, Hkv, hd) shared physical page pool
    v_pool: jax.Array,  # (P, page, Hkv, hd)
    pos: jax.Array,     # () or (B,)  tokens already cached per row
    table: jax.Array,   # (B, T) i32 page table
    window: int,        # attention span (0 = all cached)
) -> jax.Array:
    """Page-table decode oracle (kernels/paged_decode.py table mode).

    Gather each row's pages into a contiguous ring, then run the plain ring
    oracle — page placement is pure layout, so the table kernel must be
    bitwise equal to ``swa_decode`` over this gathered cache (tests pin
    it). Capacity is implied by the table width: C = T · page."""
    return swa_decode_ref(
        q, gather_pages_ref(k_pool, table), gather_pages_ref(v_pool, table),
        pos, window,
    )


def suffix_prefill_ref(
    q: jax.Array,        # (n, S, Hkv, G, hd) — roped at starts[r] + i
    k_suf: jax.Array,    # (n, S, Hkv, hd) suffix keys (rotated)
    v_suf: jax.Array,    # (n, S, Hkv, hd)
    pool_k: jax.Array,   # (P, page, Hkv, hd) shared physical page pool
    pool_v: jax.Array,   # (P, page, Hkv, hd)
    table: jax.Array,    # (n, T) i32 page table (row-gathered)
    starts: jax.Array,   # (n,) i32 cached prefix tokens per row
    *,
    prefix_width: int | None = None,
) -> jax.Array:
    """Gather-concat suffix-prefill oracle (kernels/flash_suffix_prefill.py).

    Mirrors the displaced jnp production path in models/transformer.py's
    suffix mode exactly: gather the row's first ``prefix_width`` table
    pages into contiguous ring lanes, banish lanes at/after ``starts[r]``
    to FAR_POS (2**30) so the position mask kills them, concatenate the
    suffix k/v behind, and run one full-softmax attend with absolute query
    positions ``starts[r] + i``. ``prefix_width=None`` streams the full
    table width — bitwise the pre-split engine behavior."""
    n, s, hkv, g, hd = q.shape
    page = pool_k.shape[1]
    t_w = table.shape[1]
    w = t_w if prefix_width is None else min(prefix_width, t_w)
    starts = jnp.asarray(starts, jnp.int32).reshape(-1)
    far = 2**30

    gk = gather_pages_ref(pool_k, table[:, :w])    # (n, w·page, Hkv, hd)
    gv = gather_pages_ref(pool_v, table[:, :w])
    ring_c = jnp.arange(w * page)[None, :]
    prefix_pos = jnp.where(ring_c < starts[:, None], ring_c, far)
    qpos = starts[:, None] + jnp.arange(s)[None, :]           # (n, S)

    k = jnp.concatenate([gk, k_suf], axis=1)
    v = jnp.concatenate([gv, v_suf], axis=1)
    kv_pos = jnp.concatenate([prefix_pos, qpos], axis=1)      # (n, w·page+S)

    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)
    mask = qpos[:, None, None, :, None] >= kv_pos[:, None, None, None, :]
    scores = jnp.where(mask, scores, -2.0**30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_prefill_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Full-softmax GQA attention oracle for the flash_prefill kernel.

    q: (B, S, Hkv, G, hd); k/v: (B, T, Hkv, hd) → (B, S, Hkv, G, hd)."""
    b, s, hkv, g, hd = q.shape
    t = k.shape[1]
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        mask = qpos >= kpos
        if window > 0:
            mask &= (qpos - kpos) < window
        scores = jnp.where(mask[None, None, None], scores, -2.0**30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
