"""Pallas TPU kernels: per-block symmetric int8 quantization (§3.2 uplink).

Two entry points over (nb, 256) fp32 rows:
* ``int8_encode`` — (q int8, scale fp32/row): what actually crosses the
  cross-cloud link (1 byte/elem + 4 bytes/row ≈ 3.98× compression).
* ``int8_roundtrip`` — fused quantize→dequantize: the lossy-channel form the
  jitted sync step consumes (no int8 materialization in HBM).

Both are single-pass VPU tiles: row max-abs reduce → scale → round/clip.
Tile (8, 256) as in topk_compress; the op is memory-bound."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
BLOCK = 256
EPS = 1e-12


def _encode_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, EPS)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _roundtrip_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, EPS)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_encode(x: jax.Array, *, interpret: bool = True):
    nb, block = x.shape
    assert block == BLOCK and nb % ROWS == 0
    return pl.pallas_call(
        _encode_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_roundtrip(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    nb, block = x.shape
    assert block == BLOCK and nb % ROWS == 0
    return pl.pallas_call(
        _roundtrip_kernel,
        out_shape=jax.ShapeDtypeStruct((nb, block), x.dtype),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
        interpret=interpret,
    )(x)
