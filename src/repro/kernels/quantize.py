"""Pallas TPU kernels: per-block symmetric int8 quantization (§3.2 uplink).

Two entry points over (nb, 256) fp32 rows:
* ``int8_encode`` — (q int8, scale fp32/row): what actually crosses the
  cross-cloud link (1 byte/elem + 4 bytes/row ≈ 3.98× compression).
* ``int8_roundtrip`` — fused quantize→dequantize: the lossy-channel form the
  jitted sync step consumes (no int8 materialization in HBM).

Both are single-pass VPU tiles: row max-abs reduce → scale → round/clip.
Tile (8, 256) as in topk_compress; the op is memory-bound.

Row counts need not be multiples of the tile: inputs are zero-padded to the
next ROWS multiple internally and the outputs sliced back, so page-shaped
callers (e.g. int8 KV pools) quantize without reshaping. ``kv_quant`` /
``kv_dequant`` expose the same per-row scheme as plain jnp over an arbitrary
trailing axis — the form the paged engine's int8 KV cache writes use inside
its jitted steps (per token-slot, per kv-head scales)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
BLOCK = 256
EPS = 1e-12


def kv_quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 over the LAST axis: (..., d) → (q int8, scale f32 (...)).

    Exactly the ``_encode_kernel`` row math (max-abs/127 scale, round, clip)
    applied per trailing vector — the int8 KV pool stores one scale per
    token-slot per kv-head this way."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / 127.0, EPS)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127.0, 127.0)
    return q.astype(jnp.int8), scale


def kv_dequant(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of ``kv_quant``: f32 multiply then cast — the kernels'
    in-body dequant reproduces this bitwise."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _pad_rows(x: jax.Array) -> jax.Array:
    """Zero-pad the row axis to the next ROWS multiple (padding rows
    quantize to q=0 / scale=EPS and are sliced off by the callers)."""
    pad = (-x.shape[0]) % ROWS
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)])
    return x


def _encode_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, EPS)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _roundtrip_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, EPS)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_encode(x: jax.Array, *, interpret: bool = True):
    nb, block = x.shape
    assert block == BLOCK
    xp = _pad_rows(x)
    q, s = pl.pallas_call(
        _encode_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((xp.shape[0], block), jnp.int8),
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
        ),
        grid=(xp.shape[0] // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(xp)
    return q[:nb], s[:nb]


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_roundtrip(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    nb, block = x.shape
    assert block == BLOCK
    xp = _pad_rows(x)
    out = pl.pallas_call(
        _roundtrip_kernel,
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], block), x.dtype),
        grid=(xp.shape[0] // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
        interpret=interpret,
    )(xp)
    return out[:nb]
