"""Pallas TPU kernel: flash attention for training/prefill (causal GQA,
optional sliding window).

Why this is the §Roofline hot spot: the jnp chunked-attention path
materializes the (B, Hkv, G, q_chunk, T) probability tensor in HBM between
the two matmuls — at prefill_32k that is the dominant memory term for every
attention architecture (≈100 TB/step/device on the 12B configs). Flash
tiling keeps the running softmax state in VMEM so HBM traffic drops to
O(Q + K + V + O).

Layout:
    grid = (B, Hkv, S/BQ, T/BK); the LAST grid axis streams over KV blocks
    (TPU grid iteration is sequential per core), carrying (m, l, acc) in
    VMEM scratch. One q tile blocks all G = H/Hkv query heads of one KV
    head: the MXU sees (BQ·G, hd) × (hd, BK) — both dims ≥128 for
    hardware-aligned shapes at hd=128, BK=128.

Causality is position arithmetic on block indices; fully-masked (future)
KV blocks are skipped with ``pl.when`` so the streaming pass does no MXU
work above the diagonal (the HBM prefetch of those blocks is hidden by the
sequential grid)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0**30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, bq: int, bk: int, n_k: int, g: int, hd: int,
    causal: bool, window: int, scale: float,
):
    i = pl.program_id(2)          # query block
    j = pl.program_id(3)          # kv block (streaming reduction axis)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip: the whole KV block is in the future of the whole
    # q block (or beyond the window's past edge)
    q_lo = i * bq
    q_hi = q_lo + bq - 1
    k_lo = j * bk
    k_hi = k_lo + bk - 1
    if causal:
        live = k_lo <= q_hi
        if window > 0:
            live = live & (k_hi >= q_lo - (window - 1))
    else:
        live = jnp.asarray(True)

    @pl.when(live)
    def _block():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(bq * g, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)          # (BK, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (BQ·G, BK)

        if causal:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, g, bk), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, g, bk), 2)
            mask = qpos >= kpos
            if window > 0:
                mask &= (qpos - kpos) < window
            s = jnp.where(mask.reshape(bq * g, bk), s, NEG)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_new = acc_prev * alpha + pv
        m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == n_k - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0] = out.reshape(bq, g, hd).astype(o_ref.dtype)


def _block_size(n: int, target: int) -> int:
    for b in (target, target // 2, target // 4, 64, 32, 16, 8):
        if b and n % b == 0 and n >= b:
            return b
    return n


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "interpret", "bq", "bk")
)
def flash_prefill(
    q: jax.Array,          # (B, S, Hkv, G, hd)
    k: jax.Array,          # (B, T, Hkv, hd)
    v: jax.Array,          # (B, T, Hkv, hd)
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, S, Hkv, G, hd) attention output, fp32-accumulated."""
    b, s, hkv, g, hd = q.shape
    t = k.shape[1]
    bq = _block_size(s, bq)
    bk = _block_size(t, bk)
    scale = hd**-0.5
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_k=t // bk, g=g, hd=hd,
        causal=causal, window=window, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, s, hkv, g, hd), q.dtype),
        grid=(b, hkv, s // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, g, hd), lambda b_, h, i, j: (b_, i, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, h, i, j: (b_, j, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, h, i, j: (b_, j, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, bq, 1, g, hd), lambda b_, h, i, j: (b_, i, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
