"""Jit'd public wrappers around the Pallas kernels.

The wrappers own the layout plumbing the kernels don't: flattening pytrees
into (rows, 256) tiles (with padding), restoring shapes, and dispatching
kernel vs. pure-jnp reference (``use_kernel=False`` is the CPU production
path; kernels run interpret=True on CPU for validation and compile natively
on TPU)."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import dp_clip as _dp
from repro.kernels import paged_decode as _paged
from repro.kernels import quantize as _quant
from repro.kernels import ref as _ref
from repro.kernels import swa_decode as _swa
from repro.kernels import topk_compress as _topk

Pytree = Any

BLOCK = 256
ROWS = 8
TILE = BLOCK * ROWS


def _to_tiles(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.astype(jnp.float32).ravel()
    n = flat.shape[0]
    pad = (-n) % TILE
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(-1, BLOCK), n


def _from_tiles(t: jax.Array, n: int, shape, dtype) -> jax.Array:
    return t.ravel()[:n].reshape(shape).astype(dtype)


# ----------------------------------------------------------- top-k sparsify
def topk_sparsify_leaf(
    x: jax.Array, ratio: float, *, use_kernel: bool = False, interpret: bool = True
) -> jax.Array:
    tiles, n = _to_tiles(x)
    k = max(1, int(round(ratio * BLOCK)))
    if use_kernel:
        out = _topk.topk_sparsify(tiles, k, interpret=interpret)
    else:
        out = _ref.topk_sparsify_ref(tiles, k)
    return _from_tiles(out, n, x.shape, x.dtype)


# ------------------------------------------------------------ int8 channel
def int8_roundtrip_leaf(
    x: jax.Array, *, use_kernel: bool = False, interpret: bool = True
) -> jax.Array:
    tiles, n = _to_tiles(x)
    if use_kernel:
        out = _quant.int8_roundtrip(tiles, interpret=interpret)
    else:
        out = _ref.int8_roundtrip_ref(tiles)
    return _from_tiles(out, n, x.shape, x.dtype)


def int8_encode_leaf(x: jax.Array, *, use_kernel: bool = False, interpret: bool = True):
    tiles, n = _to_tiles(x)
    if use_kernel:
        return _quant.int8_encode(tiles, interpret=interpret) + (n,)
    return _ref.int8_encode_ref(tiles) + (n,)


# ------------------------------------------------------------------ DP clip
def tree_sq_norm(
    tree: Pytree, *, use_kernel: bool = False, interpret: bool = True
) -> jax.Array:
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(tree):
        tiles, _ = _to_tiles(leaf)  # zero-padding does not change Σx²
        if use_kernel:
            total = total + _dp.sq_norm(tiles, interpret=interpret)
        else:
            total = total + _ref.sq_norm_ref(tiles)
    return total


def dp_transmit(
    tree: Pytree,
    key: jax.Array,
    clip_norm: float,
    stddev: float,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> Pytree:
    """Fused DP channel: clip the pytree to clip_norm, add N(0, stddev²)."""
    norm = jnp.sqrt(tree_sq_norm(tree, use_kernel=use_kernel, interpret=interpret))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-9))
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        tiles, n = _to_tiles(leaf)
        noise = jax.random.normal(k, tiles.shape, jnp.float32)
        if use_kernel:
            y = _dp.clip_noise(tiles, scale, noise, stddev, interpret=interpret)
        else:
            y = _ref.clip_noise_ref(tiles, scale, noise, stddev)
        out.append(_from_tiles(y, n, leaf.shape, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------- swa decode attention
def swa_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    window: int = 0,
    *,
    use_kernel: bool = False,
    paged: bool = False,
    table: jax.Array | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    interpret: bool = True,
) -> jax.Array:
    """(B, Hkv, G, hd) x ring cache (B, C, Hkv, hd) → (B, Hkv, G, hd).

    ``pos`` is () for a lockstep batch or (B,) for per-slot positions
    (continuous-batching engine). ``paged=True`` selects the length-aware
    paged variant (kernels/paged_decode.py): rows far from ring wrap skip
    dead KV pages entirely — bitwise-identical output, less work.

    ``table`` switches to page-table mode: k/v are a SHARED physical pool
    (P, page, Hkv, hd) and ``table`` (B, T) maps each row's logical pages
    into it (capacity = T·page). The kernel reads the pool through
    scalar-prefetched table rows; the reference path gathers the pages
    into contiguous rings first — both bitwise-match the ring semantics.

    ``k_scale``/``v_scale`` (with ``table``) select the int8-pool variant:
    pages are int8 with (P, page, Hkv) f32 scales; the kernel dequantizes
    in-body and the reference dequantizes the pool before gathering —
    bitwise the same value set either way."""
    if table is not None:
        if use_kernel:
            return _paged.paged_decode(
                q, k_cache, v_cache, pos, window, table=table,
                k_scale=k_scale, v_scale=v_scale, interpret=interpret,
            )
        if k_scale is not None:
            return _ref.paged_table_decode_int8_ref(
                q, k_cache, v_cache, k_scale, v_scale, pos, table, window
            )
        return _ref.paged_table_decode_ref(q, k_cache, v_cache, pos, table, window)
    assert k_scale is None and v_scale is None, (
        "int8 pool scales require page-table mode"
    )
    if use_kernel:
        if paged:
            return _paged.paged_decode(
                q, k_cache, v_cache, pos, window, interpret=interpret
            )
        return _swa.swa_decode(q, k_cache, v_cache, pos, window, interpret=interpret)
    if paged:
        return _ref.paged_decode_ref(q, k_cache, v_cache, pos, window)
    return _ref.swa_decode_ref(q, k_cache, v_cache, pos, window)


# -------------------------------------------------------- flash prefill attn
def flash_prefill_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    window: int = 0, use_kernel: bool = False, interpret: bool = True,
) -> jax.Array:
    """Causal GQA flash attention for training/prefill (see
    kernels/flash_prefill.py). q: (B,S,Hkv,G,hd); k/v: (B,T,Hkv,hd)."""
    from repro.kernels import flash_prefill as _fp

    if use_kernel:
        return _fp.flash_prefill(
            q, k, v, causal=causal, window=window, interpret=interpret
        )
    return _ref.flash_prefill_ref(q, k, v, causal=causal, window=window)


# ------------------------------------------------------- suffix prefill attn
def suffix_prefill_attention(
    q: jax.Array,
    k_suf: jax.Array,
    v_suf: jax.Array,
    pool_k: jax.Array,
    pool_v: jax.Array,
    table: jax.Array,
    starts: jax.Array,
    *,
    prefix_width: int,
    pool_k_scale: jax.Array | None = None,
    pool_v_scale: jax.Array | None = None,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Suffix prefill over a cached prefix held in a shared page pool (see
    kernels/flash_suffix_prefill.py). q: (n,S,Hkv,G,hd) roped at absolute
    positions starts[r]+i; k_suf/v_suf: (n,S,Hkv,hd); pool: (P,page,Hkv,hd);
    table: (n,T); starts: (n,). ``prefix_width`` statically bounds the pages
    streamed per row (engine buckets max(starts) up a pow2 ladder). The
    reference path is the displaced gather-concat attend — the house-rules
    oracle for the kernel. ``pool_k_scale``/``pool_v_scale`` select the
    int8-pool variant (in-body dequant in the kernel, dequantized-pool
    gather in the reference)."""
    if use_kernel:
        from repro.kernels import flash_suffix_prefill as _fsp

        return _fsp.suffix_prefill(
            q, k_suf, v_suf, pool_k, pool_v, table, starts,
            prefix_width=prefix_width, pool_k_scale=pool_k_scale,
            pool_v_scale=pool_v_scale, interpret=interpret,
        )
    if pool_k_scale is not None:
        return _ref.suffix_prefill_int8_ref(
            q, k_suf, v_suf, pool_k, pool_v, pool_k_scale, pool_v_scale,
            table, starts, prefix_width=prefix_width,
        )
    return _ref.suffix_prefill_ref(
        q, k_suf, v_suf, pool_k, pool_v, table, starts,
        prefix_width=prefix_width,
    )
