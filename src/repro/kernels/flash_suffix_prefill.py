"""Pallas TPU kernel: suffix prefill over a shared paged KV pool — flash
attention for the PREFIX-SHARING admission path.

A prefix-cache hit admits a request whose first ``starts[r]`` tokens are
already resident in shared pool pages mapped by the row's page table; only
the uncached suffix runs through prefill. The jnp production path gathers
EVERY table page into a contiguous (n, T·page, Hkv, hd) ring row in HBM and
concatenates the suffix k/v before one full-softmax attend — the gather
alone moves ``table_width × page_size`` lanes per row per layer regardless
of how short the cached prefix is, and the (n, Hkv, G, S, T·page+S) score
tensor is materialized on top.

This kernel removes both terms with the scalar-prefetched table-row idiom
proven in ``kernels/paged_decode.py``: the last grid axis streams

    j in [0, W)        — the row's cached PREFIX pages, read directly from
                         the pool at ``table[b, j]`` (no gather); a page at
                         or beyond the row's live prefix (``j >= ceil(
                         starts[b]/page)``) is skipped with ``pl.when`` and
                         its DMA clamps to the last live page (no fresh
                         traffic — the paged-decode trick);
    j in [W, W + S/BK) — the suffix's own k/v blocks, standard causal
                         flash tiling (``kernels/flash_prefill.py``),

carrying the online-softmax state (m, l, acc) in VMEM scratch. ``W`` is a
STATIC prefix width in pages — the engine buckets ``max(starts)`` up a
pow2 ladder (``launch/engine.py::bucket_pages``) so compile counts stay
gated exactly like the (width, length) shape buckets.

Masking: a prefix lane at ring slot ``c`` is live iff ``c < starts[b]``
(windowless, non-wrapping ring: slot c holds global position c). Causality
is implied — every query sits at an absolute position ``>= starts[b]`` —
so no per-query prefix mask is needed. Suffix blocks mask causally in
LOCAL coordinates, identical to ``flash_prefill``. The streaming order
[prefix pages | suffix blocks] matches the jnp path's concat order, so the
kernel is the flash reassociation of the same reduction; tests pin it
allclose against ``ref.suffix_prefill_ref`` and the engine pins greedy
tokens bitwise through ``use_kernel=True``.

int8 pool mode (``pool_k_scale``/``pool_v_scale`` passed): prefix pages are
int8 with (P, page, Hkv) f32 scales riding the same table indirection;
the prefix phase dequantizes in-body to the q dtype (bitwise
``quantize.kv_dequant``) before the unchanged flash math, while the fresh
suffix k/v stay fp — bitwise equal to the fp kernel over the
jnp-dequantized pool.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_prefill import _block_size

NEG = -2.0**30


def _suffix_kernel(
    starts_ref, pp_ref, table_ref,
    q_ref, ks_ref, vs_ref, pk_ref, pv_ref,
    *rest,
    bq: int, bk: int, w: int, page: int, n_total: int, g: int, hd: int,
    scale: float, deq=None,
):
    # rest = ([pks_ref, pvs_ref,] o_ref, m_ref, l_ref, acc_ref) — with
    # ``deq`` set (int8 pool mode) the POOL pages are int8 and pks/pvs hold
    # one f32 scale per page slot per kv-head; the fresh suffix k/v stay fp.
    if deq is not None:
        pks_ref, pvs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    i = pl.program_id(2)          # query block
    j = pl.program_id(3)          # streaming axis: W prefix pages, then
    #                               S/BK suffix blocks

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _update(s, v):
        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_new = acc_prev * alpha + pv
        m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    # ---- prefix phase: stream the row's live cached pages via the table.
    # A dead page (j >= live prefix pages) does no MXU work; its DMA
    # re-read the last live page (index-map clamp), never fresh traffic.
    @pl.when((j < w) & (j < pp_ref[b]))
    def _prefix_block():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(bq * g, hd)
        k = pk_ref[0, :, 0]                              # (page, hd)
        v = pv_ref[0, :, 0]
        if deq is not None:
            # in-body dequant, bitwise ``kv_dequant(..., dtype=deq)``
            k = (k.astype(jnp.float32) * pks_ref[0, :, 0][:, None]).astype(deq)
            v = (v.astype(jnp.float32) * pvs_ref[0, :, 0][:, None]).astype(deq)
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (BQ·G, page)
        # ring slot c holds global position c (windowless, no wrap); lanes
        # at/after the row's start hold no prefix. Causality is implied:
        # every query position is >= starts[b] > any live prefix lane.
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(kpos < starts_ref[b], s, NEG)
        _update(s, v)

    # ---- suffix phase: standard causal flash tiling in LOCAL suffix
    # coordinates (absolute = starts[b] + local on both sides, so the
    # offset cancels out of the causal comparison).
    jj = j - w
    q_lo = i * bq
    q_hi = q_lo + bq - 1
    k_lo = jj * bk

    @pl.when((j >= w) & (k_lo <= q_hi))
    def _suffix_block():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(bq * g, hd)
        k = ks_ref[0, :, 0].astype(jnp.float32)          # (BK, hd)
        v = vs_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (BQ·G, BK)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, g, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, g, bk), 2)
        s = jnp.where((qpos >= kpos).reshape(bq * g, bk), s, NEG)
        _update(s, v)

    @pl.when(j == n_total - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0] = out.reshape(bq, g, hd).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("prefix_width", "bq", "bk", "interpret")
)
def suffix_prefill(
    q: jax.Array,        # (n, S, Hkv, G, hd) — roped at starts[r] + i
    k_suf: jax.Array,    # (n, S, Hkv, hd) suffix keys (rotated)
    v_suf: jax.Array,    # (n, S, Hkv, hd)
    pool_k: jax.Array,   # (P, page, Hkv, hd) shared physical page pool
    pool_v: jax.Array,   # (P, page, Hkv, hd)
    table: jax.Array,    # (n, T) i32 — row r's logical page j lives at
    #                      pool page table[r, j]
    starts: jax.Array,   # (n,) i32 — cached prefix tokens per row
    *,
    prefix_width: int,   # STATIC pages streamed per row (bucketed
    #                      ceil(max(starts)/page); must cover every row)
    pool_k_scale: jax.Array | None = None,  # (P, page, Hkv) f32 — int8 pool
    pool_v_scale: jax.Array | None = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Returns (n, S, Hkv, G, hd) attention output, fp32-accumulated."""
    n, s, hkv, g, hd = q.shape
    page = pool_k.shape[1]
    t_w = table.shape[1]
    w = min(prefix_width, t_w)
    assert w >= 1, f"prefix_width must be >= 1, got {prefix_width}"
    quant = pool_k_scale is not None
    assert quant == (pool_v_scale is not None), "need both or neither scale"
    bq = _block_size(s, bq)
    bk = _block_size(s, bk)
    scale = hd**-0.5
    n_total = w + s // bk

    starts = jnp.asarray(starts, jnp.int32).reshape(-1)
    # live prefix pages per row; rows beyond the static width were bucketed
    # wrong by the caller — clip keeps the kernel memory-safe regardless
    pp = jnp.clip(-(-starts // page), 0, w)
    table = jnp.asarray(table, jnp.int32)

    kernel = functools.partial(
        _suffix_kernel, bq=bq, bk=bk, w=w, page=page, n_total=n_total,
        g=g, hd=hd, scale=scale, deq=q.dtype if quant else None,
    )

    def q_map(b, h, i, j, *_):
        return (b, i, h, 0, 0)

    def suf_map(b, h, i, j, *_):
        # prefix-phase steps clamp to suffix block 0: already resident,
        # no fresh DMA (the body never touches it before j reaches w)
        return (b, jnp.maximum(j - w, 0), h, 0)

    def pool_map(b, h, i, j, starts_ref, pp_ref, table_ref):
        # page-table indirection with the paged-decode clamp: suffix-phase
        # steps and dead prefix pages re-read the last live page (clamp
        # BEFORE the table lookup so an unallocated entry — scratch page 0
        # by convention — is never the target of a live-block DMA)
        jp = jnp.minimum(jnp.minimum(j, w - 1), pp_ref[b] - 1)
        return (table_ref[b, jnp.maximum(jp, 0)], 0, h, 0)

    def pool_scale_map(b, h, i, j, starts_ref, pp_ref, table_ref):
        jp = jnp.minimum(jnp.minimum(j, w - 1), pp_ref[b] - 1)
        return (table_ref[b, jnp.maximum(jp, 0)], 0, h)

    in_specs = [
        pl.BlockSpec((1, bq, 1, g, hd), q_map),
        pl.BlockSpec((1, bk, 1, hd), suf_map),
        pl.BlockSpec((1, bk, 1, hd), suf_map),
        pl.BlockSpec((1, page, 1, hd), pool_map),
        pl.BlockSpec((1, page, 1, hd), pool_map),
    ]
    inputs = [q, k_suf, v_suf, pool_k, pool_v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, page, 1), pool_scale_map),
            pl.BlockSpec((1, page, 1), pool_scale_map),
        ]
        inputs += [pool_k_scale, pool_v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n, hkv, s // bq, n_total),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, 1, g, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, s, hkv, g, hd), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(starts, pp, table, *inputs)
