"""Mixture-of-Experts FFN: top-k routing, grouped GShard-style dispatch.

TPU adaptation notes:
* Dispatch/combine are one-hot einsums over (group, token, expert, capacity)
  — the classic GShard/Switch TPU formulation. Groups are fixed-size token
  blocks, so every shape is static and the expert dimension shards cleanly
  over the `model` mesh axis (expert parallelism); groups shard over `data`.
* Capacity per expert per group C = ceil(cf * group_tokens * k / E). Tokens
  over capacity are dropped (standard Switch behaviour); the router's
  load-balance auxiliary loss (Switch §2.2) pushes the distribution flat.
* The dispatch einsum costs ~2*T*E*C*D extra FLOPs — visible in the
  roofline's MODEL_FLOPS/HLO_FLOPs ratio. §Perf iterates on group size and
  a ragged-dot variant.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import he_init
from repro.models.sharding import constrain
from repro.models.transformer import FFNHooks

Params = Any


def init_moe(key, cfg: ModelConfig) -> Params:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": he_init(kr, (d, e), jnp.float32),
        "w_gate": he_init(kg, (e, d, f), cfg.dtype, fan_in=d),
        "w_up": he_init(ku, (e, d, f), cfg.dtype, fan_in=d),
        "w_down": he_init(kd, (e, f, d), cfg.dtype, fan_in=f),
    }


def _group_size(n_tokens: int) -> int:
    for gs in (256, 128, 64):
        if n_tokens % gs == 0 and n_tokens >= gs:
            return gs
    return n_tokens


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = cfg.capacity_factor * group_tokens * cfg.experts_per_token / cfg.n_experts
    return max(1, int(math.ceil(c)))


def _topk_iterative(probs: jax.Array, k: int):
    """Top-k by k iterative argmaxes (MaxText-style).

    ``lax.top_k`` lowers to a variadic sort, and XLA SPMD replicates a
    sort's operand across every mesh axis — on the federated mesh that
    all-gathered the full router-probability tensor across pods AND the
    data axis, per layer per microbatch (~50 GB/dev/step cross-pod on
    qwen3-235b). argmax is a plain reduction over the expert dim that
    shards cleanly on all token dims. k ≤ 8 passes over E ≤ 128 experts is
    negligible compute."""
    p = probs
    ws, ids = [], []
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        sel = jax.nn.one_hot(i, p.shape[-1], dtype=p.dtype)
        w = jnp.sum(p * sel, axis=-1)
        ids.append(i)
        ws.append(w)
        p = jnp.where(sel > 0, -jnp.inf, p)
    return jnp.stack(ws, axis=-1), jnp.stack(ids, axis=-1)


def apply_moe(params: Params, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) → (out (B, S, D), load-balance aux loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    gs = _group_size(t)
    g = t // gs
    c = capacity(cfg, gs)
    xf = x.reshape(g, gs, d)

    # --- routing (fp32) ---
    logits = xf.astype(jnp.float32) @ params["router"]          # (g, n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = _topk_iterative(probs, k)                     # (g, n, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9
    )

    # --- Switch load-balance loss: E * <f_e, p_e> ---
    dense_mask = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)  # top-1 frac
    f_e = jnp.mean(dense_mask, axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)

    # --- capacity assignment: j-major order (choice level 0 wins slots) ---
    mask = jax.nn.one_hot(idx, e, dtype=jnp.float32)             # (g, n, k, E)
    mask_jm = mask.transpose(0, 2, 1, 3).reshape(g, k * gs, e)   # j-major
    pos_jm = jnp.cumsum(mask_jm, axis=1) - mask_jm               # slots before
    pos = pos_jm.reshape(g, k, gs, e).transpose(0, 2, 1, 3)      # (g, n, k, E)
    keep = (pos < c) * mask                                      # (g, n, k, E)
    slot = jax.nn.one_hot(pos, c, dtype=jnp.float32) * keep[..., None]

    dispatch = jnp.sum(slot, axis=2)                             # (g, n, E, C)
    combine = jnp.sum(slot * weights[..., None, None], axis=2)   # (g, n, E, C)
    dispatch = constrain(dispatch.astype(x.dtype), "batch", None, "experts", None)

    # --- expert compute ---
    expert_in = jnp.einsum("gnec,gnd->egcd", dispatch, xf)       # (E, g, C, D)
    expert_in = constrain(expert_in, "experts", "batch", None, None)
    gate = jnp.einsum("egcd,edf->egcf", expert_in, params["w_gate"])
    up = jnp.einsum("egcd,edf->egcf", expert_in, params["w_up"])
    act = jax.nn.silu(gate) if cfg.act == "silu" else jax.nn.gelu(gate)
    h = act * up
    out_e = jnp.einsum("egcf,efd->egcd", h, params["w_down"])    # (E, g, C, D)
    out_e = constrain(out_e, "experts", "batch", None, None)

    out = jnp.einsum("gnec,egcd->gnd", combine.astype(x.dtype), out_e)
    return out.reshape(b, s, d), aux


MOE_FFN = FFNHooks(init_moe, apply_moe)
