"""Logical-axis activation sharding constraints (t5x/MaxText style).

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", "seq", "heads", None)``); the launch layer activates
a rule set mapping logical names to physical mesh axes. With no active rules
(unit tests, CPU runs) the annotation is a no-op, so model code never needs
to know about meshes.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


class ShardingRules:
    def __init__(self, mesh: Mesh, logical_to_physical: dict[str, str | None]):
        self.mesh = mesh
        self.map = dict(logical_to_physical)

    def spec(self, *logical_axes: str | None) -> P:
        phys = []
        for ax in logical_axes:
            if ax is None:
                phys.append(None)
                continue
            p = self.map.get(ax)
            phys.append(p)
        return P(*phys)


def active_rules() -> ShardingRules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def tensor_axis() -> str | None:
    return getattr(_state, "tensor_axis", None)


@contextlib.contextmanager
def use_tensor_axis(name: str | None):
    """Activate a named all-gather axis for tensor-parallel attention.

    The serving engine traces the model inside ``shard_map`` with attention
    heads split over the mesh's ``model`` axis; each shard computes its
    contiguous head-slice of the pre-``wo`` attention output (per-head math
    is independent, so the slice is bitwise what the single device computes
    for those heads). ``gather_heads`` reconstructs the full activation by
    all-gather along the feature dim, and the replicated ``wo`` matmul that
    follows is then the identical full matmul on every shard — which is what
    makes sharded serving BITWISE token-identical to the single-device
    engine (a row-parallel wo + psum would round partial sums differently
    and flip near-tied argmaxes in bf16). With no active axis the hook is an
    identity, so ``mesh=None`` traces are bitwise-unchanged."""
    prev = getattr(_state, "tensor_axis", None)
    _state.tensor_axis = name
    try:
        yield
    finally:
        _state.tensor_axis = prev


def gather_heads(x: jax.Array) -> jax.Array:
    """All-gather a per-shard head-slice activation (..., H_local*hd) into
    the full (..., H*hd) over the active tensor axis; identity when off."""
    ax = tensor_axis()
    if ax is None:
        return x
    return jax.lax.all_gather(x, ax, axis=x.ndim - 1, tiled=True)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Apply a sharding constraint if rules are active; else identity."""
    rules = active_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        return x  # shape changed under transformation (e.g. vmap); skip
    spec = rules.spec(*logical_axes)
    # drop constraints that do not divide the dimension
    sizes = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))

    def axis_total(ax) -> int:
        if isinstance(ax, tuple):
            total = 1
            for a in ax:
                total *= sizes.get(a, 1)
            return total
        return sizes.get(ax, 1)

    fixed = []
    used: set[str] = set()  # each mesh axis may appear at most once per spec
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        cand = ax if isinstance(ax, tuple) else (ax,)
        cand = tuple(a for a in cand if a not in used and sizes.get(a, 1) > 1)
        if not cand or dim % axis_total(cand) != 0:
            fixed.append(None)
            continue
        fixed.append(cand if len(cand) > 1 else cand[0])
        used.update(cand)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*fixed))
    )


# Default logical→physical mapping for the production meshes. The federated
# layer maps "batch" to the data axis only (the pod axis is handled by
# shard_map outside the per-cloud step).
DEFAULT_RULES = {
    "batch": "data",
    "seq": None,
    "cache_seq": "data",     # decode: shard long KV caches over the data axis
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "lru": "model",
    "inner": "model",
}
