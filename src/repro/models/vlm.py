"""Pixtral-12B backbone: mistral-nemo-class decoder consuming a multimodal
prefix. [hf:mistralai/Pixtral-12B-2409]

Per the assignment carve-out, the pixtral-ViT vision tower is a STUB: inputs
arrive as precomputed patch embeddings (B, vision_seq, d_model). A learned
projector (the usual adapter layer) maps them into the decoder's embedding
space; text-token loss is masked over the image prefix. Everything downstream
is the real dense decoder from models/transformer.py.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.common import embed_tokens, lm_logits
from repro.models.layers import cross_entropy_loss, he_init

Params = Any


def init_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    params = tfm.init_params(cfg, k1)
    params["projector"] = {
        "w": he_init(k2, (cfg.d_model, cfg.d_model), cfg.dtype),
        "b": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    return params


def _multimodal_embeds(cfg: ModelConfig, params: Params, batch: dict) -> jax.Array:
    """[projected patch embeddings ; token embeddings] along the sequence."""
    patches = batch["patch_embeds"]
    proj = patches @ params["projector"]["w"] + params["projector"]["b"]
    toks = embed_tokens(params["embed"], batch["tokens"])
    return jnp.concatenate([proj.astype(toks.dtype), toks], axis=1)


def forward(cfg: ModelConfig, params: Params, batch: dict):
    x = _multimodal_embeds(cfg, params, batch)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, aux = tfm.forward_embeds(cfg, params, x, pos)
    return lm_logits(params["embed"], x, cfg), aux


def loss_fn(cfg: ModelConfig, params: Params, batch: dict):
    """Next-token loss on the text region only (image prefix masked out)."""
    logits, aux = forward(cfg, params, batch)
    n_patch = batch["patch_embeds"].shape[1]
    text_logits = logits[:, n_patch:, :]
    loss, acc = cross_entropy_loss(text_logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss, "accuracy": acc}


# Decode path: once past prefill, VLM decode is identical to the dense decoder.
init_decode_cache = tfm.init_decode_cache
decode_step = tfm.decode_step


def prefill(cfg: ModelConfig, params: Params, batch: dict, *, window: int = 0, cache_window: int = 0):
    """Multimodal prefill: run image prefix + prompt, build the decode cache."""
    x = _multimodal_embeds(cfg, params, batch)
    b, s, _ = x.shape
    # reuse the dense prefill by going through embeddings: inline variant
    import repro.models.attention as attn
    from repro.models.common import default_q_chunk, positions_for, scan_layers
    from repro.models.layers import rms_norm

    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q_chunk = default_q_chunk(s)
    # cache_window > s allocates headroom for decode continuation;
    # cache_window < s is a sliding-window ring smaller than the prompt.
    cap = cache_window if cache_window > 0 else s
    hd = cfg.resolved_head_dim

    def body(h, lp):
        a = rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        k, v = attn.compute_kv_for_prefill(lp["attn"], a, pos, cfg)
        a = attn.attend_full(
            lp["attn"], a, pos, cfg, causal=True, window=window, q_chunk=q_chunk
        )
        h = h + a
        f = rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        f, _ = tfm.DENSE_FFN.apply(lp["ffn"], f, cfg)
        empty = {
            "k": jnp.zeros((b, cap, cfg.n_kv_heads, hd), cfg.dtype),
            "v": jnp.zeros((b, cap, cfg.n_kv_heads, hd), cfg.dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
        filled = attn.fill_cache(empty, k, v)
        return h + f, (filled["k"], filled["v"])

    x, (ck, cv) = scan_layers(body, x, params["layers"], remat=cfg.remat)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    cache = {
        "k": ck,
        "v": cv,
        "pos": jnp.asarray(s, jnp.int32),
        "window": jnp.asarray(cache_window, jnp.int32),
    }
    return cache, logits
