"""Common model machinery: embeddings, vocab padding, scan-over-layers.

Scan-over-layers (stacked parameter pytrees + ``lax.scan``) keeps HLO size
and compile time independent of depth — required to dry-run the 94-layer
config on a single CPU core. Heterogeneous block patterns (Griffin's
(rec, rec, attn); xLSTM's every-k-th-sLSTM) use ``periodic`` layouts: one
stacked pytree per pattern position, scanned over periods, remainder layers
applied unrolled.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import embed_init
from repro.models.sharding import constrain
from repro.utils.tree import round_up

Params = Any

VOCAB_ALIGN = 256  # pad vocab so TP=16 shards stay (8,128)-tile aligned
NEG_INF = -2.0**30


def padded_vocab(vocab_size: int) -> int:
    return round_up(vocab_size, VOCAB_ALIGN)


# ------------------------------------------------------------- embeddings
def init_embedding(key, cfg: ModelConfig) -> Params:
    vp = padded_vocab(cfg.vocab_size)
    k1, k2 = jax.random.split(key)
    params = {"tok": embed_init(k1, (vp, cfg.d_model), cfg.dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(k2, (cfg.d_model, vp), cfg.dtype)
    return params


def embed_tokens(params: Params, tokens: jax.Array) -> jax.Array:
    x = params["tok"][tokens]
    return constrain(x, "batch", "seq", "embed")


def lm_logits(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """fp32 logits with padded-vocab columns masked out."""
    w = params["tok"].T if cfg.tie_embeddings else params["unembed"]
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    logits = constrain(logits, "batch", "seq", "vocab")
    vp = logits.shape[-1]
    if vp != cfg.vocab_size:
        col = jnp.arange(vp)
        logits = jnp.where(col[None, None, :] < cfg.vocab_size, logits, NEG_INF)
    return logits


# --------------------------------------------------------- scan over layers
def stack_layer_params(per_layer: list[Params]) -> Params:
    """[{...}, {...}] -> {leaf: (L, ...)} stacked pytree."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_layer)


def scan_layers(
    body: Callable,
    x: jax.Array,
    xs: Params,
    *,
    remat: bool = True,
    unroll: int = 1,
):
    """carry = hidden states; xs = stacked per-layer inputs (params [+ cache]).

    ``body(x, layer_slice) -> (x, aux)``; returns (final x, stacked aux).
    """
    fn = body
    if remat:
        fn = jax.checkpoint(fn, prevent_cse=False)
    return jax.lax.scan(fn, x, xs, unroll=unroll)


def periodic_stack(
    per_layer: list[Params], pattern_len: int
) -> tuple[Params | None, list[Params]]:
    """Group per-layer params into (periods, remainder).

    ``periods``: dict {"pos0": stacked, "pos1": stacked, ...} with leading
    dim n_periods; ``remainder``: the trailing layers that do not fill a
    whole period, kept as a plain list (applied unrolled).
    """
    n = len(per_layer)
    n_periods = n // pattern_len
    rem = per_layer[n_periods * pattern_len :]
    if n_periods == 0:
        return None, rem
    periods = {}
    for p in range(pattern_len):
        slot = [per_layer[i * pattern_len + p] for i in range(n_periods)]
        periods[f"pos{p}"] = stack_layer_params(slot)
    return periods, rem


def periodic_scan(
    bodies: list[Callable],
    x: jax.Array,
    periods: Params | None,
    remainder: list[Params],
    *,
    remat: bool = True,
):
    """Apply a repeating heterogeneous block pattern.

    ``bodies[p]``: body for pattern position p, signature
    ``body(x, layer_params) -> (x, aux)``. Aux values from the scanned part
    are stacked per period; remainder aux values are returned as a list.
    """
    pattern_len = len(bodies)
    aux_scanned = None
    if periods is not None:
        def period_body(carry, period_slice):
            auxes = []
            for p in range(pattern_len):
                carry, aux = bodies[p](carry, period_slice[f"pos{p}"])
                auxes.append(aux)
            return carry, tuple(auxes)

        fn = period_body
        if remat:
            fn = jax.checkpoint(fn, prevent_cse=False)
        x, aux_scanned = jax.lax.scan(fn, x, periods)
    aux_rest = []
    for i, lp in enumerate(remainder):
        x, aux = bodies[i % pattern_len](x, lp)
        aux_rest.append(aux)
    return x, (aux_scanned, aux_rest)


def positions_for(tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape[0], tokens.shape[1]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))


def default_q_chunk(seq_len: int) -> int:
    """Query-chunk size for blockwise attention: bound live score memory."""
    if seq_len <= 2048:
        return seq_len
    for c in (1024, 512, 256):
        if seq_len % c == 0:
            return c
    return seq_len
