"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local
sliding-window MQA attention in a repeating (rec, rec, attn) pattern.
[arXiv:2402.19427]

The RG-LRU is a gated diagonal linear recurrence:

    r_t = σ(W_r x_t + b_r)           (recurrence gate, block-diag per head)
    i_t = σ(W_i x_t + b_i)           (input gate, block-diag per head)
    a_t = exp(-c · softplus(Λ) · r_t)          (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training runs it as a ``jax.lax.associative_scan`` over time (log-depth on
the sequence, TPU-friendly); decode is the O(1) single-step update. The
temporal conv (width 4, depthwise, causal) carries a (width-1)-tap state in
decode. Long-context decode is native: state is O(d), no KV growth — this is
why the hybrid runs `long_500k` without any attention approximation (the
local-attention blocks use a ring cache of their 2048 window).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (
    default_q_chunk,
    embed_tokens,
    init_embedding,
    lm_logits,
    periodic_scan,
    periodic_stack,
    positions_for,
)
from repro.models.layers import (
    apply_mlp,
    cross_entropy_loss,
    he_init,
    init_mlp,
    init_rms_norm,
    rms_norm,
)
from repro.models.sharding import constrain

Params = Any
RG_C = 8.0


def _pattern(cfg: ModelConfig) -> tuple[str, ...]:
    return tuple(cfg.block_pattern) or ("rglru", "rglru", "attn")


# ------------------------------------------------------------------- params
def _init_block_diag(key, n_heads: int, width: int, dtype):
    hd = width // n_heads
    return he_init(key, (n_heads, hd, hd), dtype, fan_in=hd)


def _init_rec_mixing(key, cfg: ModelConfig) -> Params:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    kx, ky, ko, kc, kr, ki, kl = jax.random.split(key, 7)
    # Λ init so that a = exp(-c·softplus(Λ)) ∈ [0.9, 0.999]
    import numpy as np

    lo, hi = -np.log(0.999) / RG_C, -np.log(0.9) / RG_C  # softplus targets
    u = np.random.RandomState(0).uniform(lo, hi, size=(w,))
    lam = np.log(np.expm1(u))  # inverse softplus
    return {
        "w_x": he_init(kx, (d, w), cfg.dtype),
        "w_y": he_init(ky, (d, w), cfg.dtype),
        "w_out": he_init(ko, (w, d), cfg.dtype, fan_in=w),
        "conv_w": he_init(kc, (cfg.conv_width, w), cfg.dtype, fan_in=cfg.conv_width),
        "conv_b": jnp.zeros((w,), cfg.dtype),
        "gate_r": _init_block_diag(kr, cfg.n_heads, w, cfg.dtype),
        "gate_r_b": jnp.zeros((w,), cfg.dtype),
        "gate_i": _init_block_diag(ki, cfg.n_heads, w, cfg.dtype),
        "gate_i_b": jnp.zeros((w,), cfg.dtype),
        "lam": jnp.asarray(lam, jnp.float32),
    }


def _init_layer(key, cfg: ModelConfig, kind: str) -> Params:
    k1, k2 = jax.random.split(key)
    mixing = (
        _init_rec_mixing(k1, cfg) if kind == "rglru" else attn.init_attention(k1, cfg)
    )
    return {
        "ln1": init_rms_norm(cfg.d_model, cfg.dtype),
        "mix": mixing,
        "ln2": init_rms_norm(cfg.d_model, cfg.dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    pat = _pattern(cfg)
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = [
        _init_layer(keys[i], cfg, pat[i % len(pat)]) for i in range(cfg.n_layers)
    ]
    periods, rest = periodic_stack(layers, len(pat))
    return {
        "embed": init_embedding(keys[-1], cfg),
        "periods": periods,
        "rest": rest,
        "ln_f": init_rms_norm(cfg.d_model, cfg.dtype),
    }


# ------------------------------------------------------------------- RG-LRU
def _block_diag_apply(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """x: (..., W) with W = H·hd; w: (H, hd, hd)."""
    h, hd, _ = w.shape
    xs = x.reshape(*x.shape[:-1], h, hd)
    out = jnp.einsum("...hi,hij->...hj", xs, w)
    return out.reshape(*x.shape[:-1], h * hd) + b


def _rg_lru_coeffs(p: Params, x: jax.Array):
    """Gate computation. x: (..., W) fp32 → (a, bx) recurrence coefficients."""
    r = jax.nn.sigmoid(_block_diag_apply(p["gate_r"], p["gate_r_b"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag_apply(p["gate_i"], p["gate_i_b"], x).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = i * x.astype(jnp.float32)
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, bx


def rg_lru_scan(p: Params, x: jax.Array, h0: jax.Array | None = None):
    """Training-time parallel scan. x: (B, S, W) → (y (B,S,W), h_final (B,W))."""
    a, bx = _rg_lru_coeffs(p, x)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(p: Params, x: jax.Array, h_prev: jax.Array):
    """Decode-time step. x: (B, 1, W), h_prev: (B, W) fp32."""
    a, bx = _rg_lru_coeffs(p, x)
    h = a[:, 0] * h_prev + bx[:, 0]
    return h.astype(x.dtype)[:, None, :], h


# ------------------------------------------------------- temporal conv (x4)
def causal_conv(p: Params, x: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv. x: (B, S, W); tail: (B, cw-1, W) carried state.

    Returns (y, new_tail)."""
    cw = p["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * p["conv_w"][i][None, None, :]
        for i in range(cw)
    )
    return y + p["conv_b"], xp[:, -(cw - 1) :]


# ------------------------------------------------------------- block bodies
def _rec_mixing(p: Params, x: jax.Array, state: dict | None):
    """Griffin recurrent branch. Returns (out, new_state)."""
    gate = jax.nn.gelu(x @ p["w_y"])
    main = x @ p["w_x"]
    main = constrain(main, "batch", "seq", "lru")
    tail = state["conv"] if state is not None else None
    main, new_tail = causal_conv(p, main, tail)
    if x.shape[1] == 1 and state is not None:
        y, new_h = rg_lru_step(p, main, state["h"])
    else:
        h0 = state["h"] if state is not None else None
        y, h_last = rg_lru_scan(p, main, h0)
        new_h = h_last.astype(jnp.float32)
    out = (y * gate) @ p["w_out"]
    return out, {"h": new_h.astype(jnp.float32), "conv": new_tail}


def _make_bodies(cfg: ModelConfig, mode: str, positions=None, window: int = 0):
    """Bodies for periodic_scan. mode: train | prefill | decode.

    Layer slice is {"p": params} (train) or {"p": params, "c": cache}.
    Aux output is the new cache slice (None in train mode).
    """
    pat = _pattern(cfg)
    q_chunk = default_q_chunk(positions.shape[1]) if positions is not None else 1
    w = window or cfg.local_attn_window

    def rec_body(x, sl):
        p = sl["p"]
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        if mode == "train":
            out, _ = _rec_mixing(p["mix"], h, None)
            new_c = None
        else:
            out, new_c = _rec_mixing(p["mix"], h, sl["c"])
        x = x + out
        f = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], f, cfg.act)
        return x, new_c

    def attn_body(x, sl):
        p = sl["p"]
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        if mode == "decode":
            out, new_c = attn.decode_attend(
                p["mix"], h, {"k": sl["c"]["k"], "v": sl["c"]["v"], "pos": sl["c"]["pos"]},
                cfg, window=w,
            )
            new_c = {"k": new_c["k"], "v": new_c["v"], "pos": new_c["pos"]}
        else:
            out = attn.attend_full(
                p["mix"], h, positions, cfg, causal=True, window=w, q_chunk=q_chunk
            )
            new_c = None
            if mode == "prefill":
                # fill the ALLOCATED ring (sl["c"]) — its capacity may exceed
                # the prompt length (decode-continuation headroom); building a
                # prompt-sized ring here would silently shrink the window.
                k, v = attn.compute_kv_for_prefill(p["mix"], h, positions, cfg)
                empty = {
                    "k": sl["c"]["k"], "v": sl["c"]["v"],
                    "pos": jnp.zeros((), jnp.int32),
                }
                filled = attn.fill_cache(empty, k, v)
                new_c = {"k": filled["k"], "v": filled["v"], "pos": filled["pos"]}
        x = x + out
        f = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], f, cfg.act)
        return x, new_c

    return [rec_body if k == "rglru" else attn_body for k in pat]


# ------------------------------------------------------------- entry points
def forward(cfg: ModelConfig, params: Params, tokens: jax.Array):
    x = embed_tokens(params["embed"], tokens)
    pos = positions_for(tokens)
    bodies = _make_bodies(cfg, "train", positions=pos)
    wrapped = [lambda x, lp, b=b: b(x, {"p": lp}) for b in bodies]
    x, _ = periodic_scan(wrapped, x, params["periods"], params["rest"], remat=cfg.remat)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict):
    logits, _ = forward(cfg, params, batch["tokens"])
    loss, acc = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss, "accuracy": acc}


def _empty_cache_for(cfg: ModelConfig, kind: str, batch: int, window: int):
    w_lru = cfg.lru_width or cfg.d_model
    if kind == "rglru":
        return {
            "h": jnp.zeros((batch, w_lru), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w_lru), cfg.dtype),
        }
    cap = window or cfg.local_attn_window
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cap, cfg.n_kv_heads, hd), cfg.dtype),
        "v": jnp.zeros((batch, cap, cfg.n_kv_heads, hd), cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int, *, window: int = 0):
    pat = _pattern(cfg)
    w = min(window or cfg.local_attn_window, max_seq)
    per_layer = [
        _empty_cache_for(cfg, pat[i % len(pat)], batch, w)
        for i in range(cfg.n_layers)
    ]
    periods, rest = periodic_stack(per_layer, len(pat))
    return {"periods": periods, "rest": rest, "pos": jnp.zeros((), jnp.int32)}


def _run_cached(cfg, params, cache, x, mode, positions=None, window=0):
    pat = _pattern(cfg)
    bodies = _make_bodies(cfg, mode, positions=positions, window=window)
    pos = cache["pos"]

    def with_pos(c, kind):
        if c is not None and kind == "attn" and mode == "decode":
            return dict(c, pos=pos)
        return c

    wrapped = []
    for i, b in enumerate(bodies):
        kind = pat[i]

        def body(x, sl, b=b, kind=kind):
            c = with_pos(sl.get("c"), kind)
            return b(x, {"p": sl["p"], "c": c})

        wrapped.append(body)

    periods = None
    if params["periods"] is not None:
        periods = {"p": params["periods"], "c": cache["periods"]}
        # re-nest: scan slice must be {"p": ..., "c": ...} per position
        periods = {
            f"pos{i}": {"p": params["periods"][f"pos{i}"], "c": cache["periods"][f"pos{i}"]}
            for i in range(len(pat))
        }
    rest = [
        {"p": lp, "c": lc} for lp, lc in zip(params["rest"], cache["rest"])
    ]

    def run_body(x, sl, i):
        return wrapped[i % len(pat)](x, sl)

    # periodic_scan with combined slices
    bodies2 = [
        (lambda x, sl, b=wrapped[i]: b(x, sl)) for i in range(len(pat))
    ]
    x, (aux_scanned, aux_rest) = periodic_scan(
        bodies2, x, periods, rest, remat=(cfg.remat and mode != "decode")
    )
    new_cache = {
        "periods": None,
        "rest": list(aux_rest),
        "pos": pos + x.shape[1] if mode == "decode" else jnp.asarray(
            positions.shape[1] if positions is not None else 0, jnp.int32
        ),
    }
    if aux_scanned is not None:
        new_cache["periods"] = {
            f"pos{i}": aux_scanned[i] for i in range(len(pat))
        }
    return x, new_cache


def decode_step(cfg: ModelConfig, params: Params, cache: dict, tokens: jax.Array, *, window: int = 0):
    x = embed_tokens(params["embed"], tokens)
    x, new_cache = _run_cached(cfg, params, cache, x, "decode", window=window)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg)[:, 0]
    return new_cache, logits


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, *, window: int = 0, cache_window: int = 0):
    b, s = tokens.shape
    # ring capacity covers the continuation (cache_window ≥ s) but never
    # exceeds the attention window — beyond it slots are dead weight.
    cache = init_decode_cache(
        cfg, b, max(cache_window, s), window=window or cfg.local_attn_window
    )
    x = embed_tokens(params["embed"], tokens)
    pos = positions_for(tokens)
    x, new_cache = _run_cached(cfg, params, cache, x, "prefill", positions=pos, window=window)
    new_cache["pos"] = jnp.asarray(s, jnp.int32)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    return new_cache, logits
