from repro.models.model import ModelAPI, build_model

__all__ = ["ModelAPI", "build_model"]
