"""Uniform model API over all 10 assigned architectures.

``build_model(cfg)`` returns a ``ModelAPI`` whose members close over the
config. Batches are dicts:

    training / prefill:  {"tokens": (B,S) i32, "labels": (B,S) i32}
                         + "patch_embeds" (B, vision_seq, D)  for vlm
                         + "audio_embeds" (B, enc_seq, D)     for audio
    decode:              tokens (B,1) against a cache pytree

Decode caches are created by ``init_cache`` and threaded through ``decode``.
``window=0`` means full-context decode (ring capacity = max_seq); a positive
window selects the sliding-window ring buffer (sub-quadratic long-context).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rglru, transformer, vlm, whisper, xlstm
from repro.models.moe import MOE_FFN
from repro.models.transformer import DENSE_FFN

Params = Any


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss: Callable[[Params, dict], tuple[jax.Array, dict]]
    forward: Callable[[Params, dict], jax.Array]
    init_cache: Callable[..., Any]     # (params, batch, max_seq, window=) -> cache
    decode: Callable[..., tuple[Any, jax.Array]]   # (params, cache, tokens, window=)
    prefill: Callable[..., tuple[Any, jax.Array]]  # (params, batch, window=, cache_window=)
    # Continuous-batching slot API (None where the arch doesn't support it):
    # init_slot_cache(params, num_slots, max_seq, window=) -> per-slot cache
    # prefill_slot(params, cache, tokens (1,S), slot, window=) -> (cache, logits)
    # prefill_slots(params, cache, tokens (n,S), lengths (n,), slots (n,),
    #               starts=None, prefix_pages=None, window=) ->
    #               (cache, logits (n, Vp)) — batched admission: n
    #               right-padded prompts into n distinct slots, one forward;
    #               starts (n,) switches to SUFFIX prefill over a
    #               pre-populated page table (prefix sharing: row r's
    #               tokens start at position starts[r]); prefix_pages
    #               statically bounds the prefix pages the attend streams;
    #               return_all_logits=True returns (n, S, Vp) logits at
    #               every padded position (speculative k-token verify)
    # init_paged_cache(params, num_slots, num_pages, page_size, table_width,
    #               window=, kv_dtype=) -> shared paged pool + per-slot page
    #               tables; decode/prefill_slots accept either cache layout;
    #               kv_dtype="int8" stores pages quantized with per-token-
    #               slot per-kv-head fp32 scales ("ks"/"vs" keys)
    init_slot_cache: Callable[..., Any] | None = None
    prefill_slot: Callable[..., tuple[Any, jax.Array]] | None = None
    prefill_slots: Callable[..., tuple[Any, jax.Array]] | None = None
    init_paged_cache: Callable[..., Any] | None = None


def _transformer_api(cfg: ModelConfig, ffn) -> ModelAPI:
    def init(key):
        return transformer.init_params(cfg, key, ffn)

    def loss(params, batch):
        return transformer.loss_fn(cfg, params, batch, ffn=ffn, window=cfg.window)

    def forward(params, batch):
        return transformer.forward(cfg, params, batch["tokens"], ffn=ffn, window=cfg.window)[0]

    def init_cache(params, batch, max_seq, *, window=0):
        b = batch["tokens"].shape[0]
        return transformer.init_decode_cache(cfg, b, max_seq, window=window)

    def decode(params, cache, tokens, *, window=0):
        return transformer.decode_step(cfg, params, cache, tokens, ffn=ffn, window=window)

    def prefill(params, batch, *, window=0, cache_window=0):
        return transformer.prefill(
            cfg, params, batch["tokens"], ffn=ffn, window=window or cfg.window,
            cache_window=cache_window,
        )

    def init_slot_cache(params, num_slots, max_seq, *, window=0):
        return transformer.init_decode_cache(
            cfg, num_slots, max_seq, window=window, per_slot=True
        )

    def prefill_slot(params, cache, tokens, slot, *, window=0):
        return transformer.prefill_into_slot(
            cfg, params, cache, tokens, slot, ffn=ffn, window=window
        )

    def prefill_slots(params, cache, tokens, lengths, slots, *, starts=None,
                      prefix_pages=None, window=0, return_all_logits=False):
        return transformer.prefill_slots(
            cfg, params, cache, tokens, lengths, slots, starts=starts,
            prefix_pages=prefix_pages, ffn=ffn, window=window,
            return_all_logits=return_all_logits,
        )

    def init_paged_cache(
        params, num_slots, num_pages, page_size, table_width, *, window=0,
        kv_dtype="fp",
    ):
        return transformer.init_paged_cache(
            cfg, num_slots, num_pages, page_size, table_width, window=window,
            kv_dtype=kv_dtype,
        )

    return ModelAPI(
        cfg, init, loss, forward, init_cache, decode, prefill,
        init_slot_cache=init_slot_cache, prefill_slot=prefill_slot,
        prefill_slots=prefill_slots, init_paged_cache=init_paged_cache,
    )


def _vlm_api(cfg: ModelConfig) -> ModelAPI:
    def init(key):
        return vlm.init_params(cfg, key)

    def loss(params, batch):
        return vlm.loss_fn(cfg, params, batch)

    def forward(params, batch):
        return vlm.forward(cfg, params, batch)[0]

    def init_cache(params, batch, max_seq, *, window=0):
        b = batch["tokens"].shape[0]
        return vlm.init_decode_cache(cfg, b, max_seq, window=window)

    def decode(params, cache, tokens, *, window=0):
        return vlm.decode_step(cfg, params, cache, tokens, window=window)

    def prefill(params, batch, *, window=0, cache_window=0):
        return vlm.prefill(cfg, params, batch, window=window, cache_window=cache_window)

    return ModelAPI(cfg, init, loss, forward, init_cache, decode, prefill)


def _hybrid_api(cfg: ModelConfig) -> ModelAPI:
    def init(key):
        return rglru.init_params(cfg, key)

    def loss(params, batch):
        return rglru.loss_fn(cfg, params, batch)

    def forward(params, batch):
        return rglru.forward(cfg, params, batch["tokens"])[0]

    def init_cache(params, batch, max_seq, *, window=0):
        b = batch["tokens"].shape[0]
        return rglru.init_decode_cache(cfg, b, max_seq, window=window)

    def decode(params, cache, tokens, *, window=0):
        return rglru.decode_step(cfg, params, cache, tokens, window=window)

    def prefill(params, batch, *, window=0, cache_window=0):
        return rglru.prefill(
            cfg, params, batch["tokens"], window=window, cache_window=cache_window
        )

    return ModelAPI(cfg, init, loss, forward, init_cache, decode, prefill)


def _ssm_api(cfg: ModelConfig) -> ModelAPI:
    def init(key):
        return xlstm.init_params(cfg, key)

    def loss(params, batch):
        return xlstm.loss_fn(cfg, params, batch)

    def forward(params, batch):
        return xlstm.forward(cfg, params, batch["tokens"])[0]

    def init_cache(params, batch, max_seq, *, window=0):
        b = batch["tokens"].shape[0]
        return xlstm.init_decode_cache(cfg, b, max_seq, window=window)

    def decode(params, cache, tokens, *, window=0):
        return xlstm.decode_step(cfg, params, cache, tokens, window=window)

    def prefill(params, batch, *, window=0, cache_window=0):
        return xlstm.prefill(cfg, params, batch["tokens"])

    return ModelAPI(cfg, init, loss, forward, init_cache, decode, prefill)


def _audio_api(cfg: ModelConfig) -> ModelAPI:
    def init(key):
        return whisper.init_params(cfg, key)

    def loss(params, batch):
        return whisper.loss_fn(cfg, params, batch)

    def forward(params, batch):
        return whisper.forward(cfg, params, batch)[0]

    def init_cache(params, batch, max_seq, *, window=0):
        return whisper.init_decode_cache(
            cfg, params, batch["audio_embeds"], max_seq, window=window
        )

    def decode(params, cache, tokens, *, window=0):
        return whisper.decode_step(cfg, params, cache, tokens, window=window)

    def prefill(params, batch, *, window=0, cache_window=0):
        return whisper.prefill(
            cfg, params, batch, window=window, cache_window=cache_window
        )

    return ModelAPI(cfg, init, loss, forward, init_cache, decode, prefill)


def localize_config(cfg: ModelConfig, shards: int) -> ModelConfig:
    """Per-shard view of a tensor-parallel-served config.

    Inside ``shard_map`` each shard sees its head slice of the attention
    weights and KV pages; dividing the head counts (and pinning head_dim,
    which would otherwise re-derive from the unchanged d_model) makes the
    shard-local trace exactly the single-device math on that slice."""
    if shards == 1:
        return cfg
    if cfg.n_heads % shards or cfg.n_kv_heads % shards:
        raise ValueError(
            f"{cfg.name}: n_heads={cfg.n_heads} / n_kv_heads={cfg.n_kv_heads}"
            f" must both divide by the model-axis size {shards}"
        )
    return dataclasses.replace(
        cfg,
        n_heads=cfg.n_heads // shards,
        n_kv_heads=cfg.n_kv_heads // shards,
        head_dim=cfg.resolved_head_dim,
    )


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.arch_type == "dense":
        return _transformer_api(cfg, DENSE_FFN)
    if cfg.arch_type == "moe":
        return _transformer_api(cfg, MOE_FFN)
    if cfg.arch_type == "vlm":
        return _vlm_api(cfg)
    if cfg.arch_type == "hybrid":
        return _hybrid_api(cfg)
    if cfg.arch_type == "ssm":
        return _ssm_api(cfg)
    if cfg.arch_type == "audio":
        return _audio_api(cfg)
    raise ValueError(f"unknown arch_type {cfg.arch_type!r}")
