"""xLSTM: mLSTM (matrix-memory, parallelizable) + sLSTM (scalar-memory,
recurrent-weight) blocks. [arXiv:2405.04517]

* mLSTM trains in its stabilized parallel (quadratic) form — an
  attention-like einsum with exponential-gate decay matrix D — and decodes
  with the O(1) recurrent update of the matrix memory C ∈ R^{h×d×d}. The
  parallel form is query-chunked like attention so prefill_32k stays
  memory-bounded.
* sLSTM has recurrent weights (block-diagonal per head), so training scans
  over time (`lax.scan`); decode is the same cell applied once.
* Block pattern: every ``cfg.slstm_every``-th block is sLSTM, the rest
  mLSTM, via the periodic-scan machinery.

Long-context decode is native: total state is O(h·d²) per mLSTM block —
no KV cache — which is why xlstm runs `long_500k` without approximation.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    embed_tokens,
    init_embedding,
    lm_logits,
    periodic_scan,
    periodic_stack,
)
from repro.models.layers import (
    cross_entropy_loss,
    he_init,
    init_rms_norm,
    rms_norm,
)
from repro.models.rglru import causal_conv
from repro.models.sharding import constrain

Params = Any


def _pattern(cfg: ModelConfig) -> tuple[str, ...]:
    if cfg.slstm_every and cfg.slstm_every > 0:
        return tuple(["mlstm"] * (cfg.slstm_every - 1) + ["slstm"])
    return ("mlstm",)


def _inner(cfg: ModelConfig) -> int:
    return 2 * cfg.d_model  # mLSTM projection factor 2


# ------------------------------------------------------------------- params
def _init_mlstm(key, cfg: ModelConfig) -> Params:
    d, inner = cfg.d_model, _inner(cfg)
    h = cfg.n_heads
    dh = inner // h
    ku, kq, kk, kv, ki, kf, ko, kd = jax.random.split(key, 8)
    return {
        "w_up": he_init(ku, (d, 2 * inner), cfg.dtype),
        "conv_w": he_init(kq, (4, inner), cfg.dtype, fan_in=4),
        "conv_b": jnp.zeros((inner,), cfg.dtype),
        "wq": he_init(kq, (inner, inner), cfg.dtype),
        "wk": he_init(kk, (inner, inner), cfg.dtype),
        "wv": he_init(kv, (inner, inner), cfg.dtype),
        "w_i": he_init(ki, (inner, h), cfg.dtype, fan_in=inner),
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": he_init(kf, (inner, h), cfg.dtype, fan_in=inner),
        "b_f": jnp.full((h,), 3.0, jnp.float32),  # forget-gate bias: keep memory
        "skip": jnp.ones((inner,), cfg.dtype),
        "gn": jnp.ones((inner,), cfg.dtype),      # per-head groupnorm scale
        "w_down": he_init(kd, (inner, d), cfg.dtype, fan_in=inner),
    }


def _init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    kz, ki, kf, ko, rz, ri, rf, ro, kf1, kf2 = jax.random.split(key, 10)
    dh = d // h

    def rec(k):
        return he_init(k, (h, dh, dh), cfg.dtype, fan_in=dh)

    ff = max(1, int(d * 4 / 3) // 64 * 64) or 64
    return {
        "conv_w": he_init(kz, (4, d), cfg.dtype, fan_in=4),
        "conv_b": jnp.zeros((d,), cfg.dtype),
        "w_z": he_init(kz, (d, d), cfg.dtype),
        "w_i": he_init(ki, (d, d), cfg.dtype),
        "w_f": he_init(kf, (d, d), cfg.dtype),
        "w_o": he_init(ko, (d, d), cfg.dtype),
        "r_z": rec(rz),
        "r_i": rec(ri),
        "r_f": rec(rf),
        "r_o": rec(ro),
        "b_z": jnp.zeros((d,), jnp.float32),
        "b_i": jnp.zeros((d,), jnp.float32),
        "b_f": jnp.full((d,), 3.0, jnp.float32),
        "b_o": jnp.zeros((d,), jnp.float32),
        "gn": jnp.ones((d,), cfg.dtype),
        "ff_up": he_init(kf1, (d, 2 * ff), cfg.dtype),
        "ff_down": he_init(kf2, (ff, d), cfg.dtype, fan_in=ff),
    }


def _init_layer(key, cfg: ModelConfig, kind: str) -> Params:
    k1, _ = jax.random.split(key)
    p = _init_mlstm(k1, cfg) if kind == "mlstm" else _init_slstm(k1, cfg)
    return {"ln": init_rms_norm(cfg.d_model, cfg.dtype), "blk": p, "kind_mlstm": kind == "mlstm"}


def init_params(cfg: ModelConfig, key) -> Params:
    pat = _pattern(cfg)
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        lp = _init_layer(keys[i], cfg, pat[i % len(pat)])
        lp.pop("kind_mlstm")
        layers.append(lp)
    periods, rest = periodic_stack(layers, len(pat))
    return {
        "embed": init_embedding(keys[-1], cfg),
        "periods": periods,
        "rest": rest,
        "ln_f": init_rms_norm(cfg.d_model, cfg.dtype),
    }


# ----------------------------------------------------------- mLSTM parallel
def _head_norm(x: jax.Array, scale: jax.Array, h: int, eps: float) -> jax.Array:
    """Per-head RMS norm of (..., inner) viewed as h heads."""
    shp = x.shape
    xs = x.reshape(*shp[:-1], h, shp[-1] // h).astype(jnp.float32)
    var = jnp.mean(jnp.square(xs), axis=-1, keepdims=True)
    xs = xs * jax.lax.rsqrt(var + eps)
    return (xs.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_parallel(
    q: jax.Array, k: jax.Array, v: jax.Array, i_pre: jax.Array, f_pre: jax.Array,
    q_chunk: int = 1024,
):
    """Stabilized parallel mLSTM. q,k,v: (B,S,H,dh); i_pre,f_pre: (B,S,H) fp32.

    Returns h: (B,S,H,dh)."""
    b, s, h, dh = q.shape
    logf = jax.nn.log_sigmoid(f_pre)                    # (B,S,H)
    lf_cum = jnp.cumsum(logf, axis=1)                   # Σ_{r<=t} log f_r
    scale = dh**-0.5
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def block(args):
        qb, lf_b, t_idx = args                          # (B,C,H,dh), (B,C,H), (C,)
        # D̃[t,s] = lf_cum[t] - lf_cum[s] + ĩ_s  (decay over r = s+1..t)
        dtil = (
            lf_b[:, :, None, :]                          # (B,C,1,H)
            - lf_cum[:, None, :, :]                      # (B,1,S,H)
            + i_pre[:, None, :, :]
        )                                                # (B,C,S,H)
        causal = t_idx[:, None] >= jnp.arange(s)[None, :]
        dtil = jnp.where(causal[None, :, :, None], dtil, -jnp.inf)
        m = jnp.max(dtil, axis=2, keepdims=True)         # (B,C,1,H)
        m = jnp.maximum(m, -1e30)                        # guard all -inf rows
        d = jnp.exp(dtil - m)                            # (B,C,S,H)
        scores = jnp.einsum("bchd,bshd->bcsh", qb, kf) * scale
        sw = scores * d
        n = jnp.maximum(jnp.abs(jnp.sum(sw, axis=2)), jnp.exp(-m[:, :, 0, :]))
        out = jnp.einsum("bcsh,bshd->bchd", sw, vf) / n[..., None]
        return out

    if s <= q_chunk:
        out = block((qf, lf_cum, jnp.arange(s)))
    else:
        assert s % q_chunk == 0
        nc = s // q_chunk
        q_r = qf.reshape(b, nc, q_chunk, h, dh).swapaxes(0, 1)
        lf_r = lf_cum.reshape(b, nc, q_chunk, h).swapaxes(0, 1)
        t_r = jnp.arange(s).reshape(nc, q_chunk)
        out = jax.lax.map(block, (q_r, lf_r, t_r))
        out = out.swapaxes(0, 1).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def mlstm_final_state(
    k: jax.Array, v: jax.Array, i_pre: jax.Array, f_pre: jax.Array
):
    """Final (C, n, m) after consuming the whole sequence (for prefill)."""
    b, s, h, dh = k.shape
    logf = jax.nn.log_sigmoid(f_pre)
    lf_cum = jnp.cumsum(logf, axis=1)
    total = lf_cum[:, -1:]                               # (B,1,H)
    # weight of position s in the final state: Π_{r>s} f_r · exp(ĩ_s)
    w_log = total - lf_cum + i_pre                       # (B,S,H)
    m = jnp.max(w_log, axis=1)                           # (B,H)
    w = jnp.exp(w_log - m[:, None, :])
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c = jnp.einsum("bsh,bshd,bshe->bhde", w, kf, vf)     # (B,H,dh,dh)
    n = jnp.einsum("bsh,bshd->bhd", w, kf)
    return c, n, m


def mlstm_step(state, q, k, v, i_pre, f_pre):
    """O(1) decode update. q,k,v: (B,H,dh); i_pre,f_pre: (B,H).

    state = (C (B,H,dh,dh), n (B,H,dh), m (B,H)), all fp32 in stabilized
    space (C,n are scaled by exp(-m))."""
    c, n, m = state
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    f_s = jnp.exp(logf + m - m_new)
    i_s = jnp.exp(i_pre - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = f_s[..., None, None] * c + i_s[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n = f_s[..., None] * n + i_s[..., None] * kf
    qf = q.astype(jnp.float32) * (q.shape[-1] ** -0.5)
    num = jnp.einsum("bhde,bhd->bhe", c, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)), jnp.exp(-m_new))
    out = num / den[..., None]
    return (c, n, m_new), out.astype(q.dtype)


def _mlstm_qkvif(p: Params, x_main: jax.Array, h: int):
    inner = x_main.shape[-1]
    dh = inner // h
    q = (x_main @ p["wq"]).reshape(*x_main.shape[:-1], h, dh)
    k = (x_main @ p["wk"]).reshape(*x_main.shape[:-1], h, dh)
    v = (x_main @ p["wv"]).reshape(*x_main.shape[:-1], h, dh)
    i_pre = (x_main @ p["w_i"]).astype(jnp.float32) + p["b_i"]
    f_pre = (x_main @ p["w_f"]).astype(jnp.float32) + p["b_f"]
    return q, k, v, i_pre, f_pre


def mlstm_block(p: Params, x: jax.Array, cfg: ModelConfig, state: dict | None):
    """x: (B,S,d). Returns (out (B,S,d), new_state)."""
    h = cfg.n_heads
    up = x @ p["w_up"]
    main, gate = jnp.split(up, 2, axis=-1)
    main = constrain(main, "batch", "seq", "inner")
    tail = state["conv"] if state is not None else None
    conv_out, new_tail = causal_conv(p, main, tail)
    conv_out = jax.nn.silu(conv_out)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(p, conv_out, h)
    if x.shape[1] == 1 and state is not None:
        (c, n, m), cell = mlstm_step(
            (state["c"], state["n"], state["m"]),
            q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0],
        )
        cell = cell[:, None]
        new_state = {"c": c, "n": n, "m": m, "conv": new_tail}
    else:
        cell = mlstm_parallel(q, k, v, i_pre, f_pre)
        if state is not None:
            c, n, m = mlstm_final_state(k, v, i_pre, f_pre)
            new_state = {"c": c, "n": n, "m": m, "conv": new_tail}
        else:
            new_state = None
    cell = cell.reshape(*x.shape[:-1], -1)
    cell = _head_norm(cell, p["gn"], h, cfg.norm_eps)
    cell = cell + p["skip"] * conv_out
    out = (cell * jax.nn.silu(gate)) @ p["w_down"]
    return out, new_state


# ------------------------------------------------------------------- sLSTM
def _block_diag(w: jax.Array, x: jax.Array) -> jax.Array:
    h, dh, _ = w.shape
    xs = x.reshape(*x.shape[:-1], h, dh)
    return jnp.einsum("...hi,hij->...hj", xs, w).reshape(x.shape)


def slstm_cell(p: Params, xz, xi, xf, xo, state):
    """One sLSTM step. x*: (B,d) pre-activations from the input; state dict."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    hf = h.astype(xz.dtype)
    z = jnp.tanh((xz + _block_diag(p["r_z"], hf)).astype(jnp.float32) + p["b_z"])
    i_pre = (xi + _block_diag(p["r_i"], hf)).astype(jnp.float32) + p["b_i"]
    f_pre = (xf + _block_diag(p["r_f"], hf)).astype(jnp.float32) + p["b_f"]
    o = jax.nn.sigmoid((xo + _block_diag(p["r_o"], hf)).astype(jnp.float32) + p["b_o"])
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


# -------------------------------------------- sLSTM training scan, custom VJP
#
# Under plain AD, the scan's VJP emits each timestep's recurrent-weight
# gradient contribution inside the backward loop body, and SPMD all-reduces
# it there: one (r_z,r_i,r_f,r_o,b_*) tuple all-reduce PER TIMESTEP per
# sLSTM layer (~55 GB/dev on train_4k — the dominant collective). This
# custom VJP restructures the backward pass the way high-performance RNN
# implementations do:
#   * the reverse-time scan computes ONLY the per-step pre-activation deltas
#     (dzpre/dipre/dfpre/dopre) and the dh/dc/dn carry chain — no weight
#     gradients, hence no collectives in the loop;
#   * weight gradients are one batched einsum over (S, B) AFTER the scan
#     (dR = Σ_t h_{t-1} ⊗ δpre_t), which XLA syncs with a single all-reduce.
#
# The stabilizer m is treated as stop-gradient: c and n both carry the
# common factor exp(-m), which cancels exactly in h = o·c/max(n,eps), so
# ∂h/∂m ≡ 0 in exact arithmetic — the stop-grad is exact, not approximate.

_SLSTM_EPS = 1e-6


def _slstm_gates(p, hf, xz, xi, xf, xo):
    """Pre-activations for one step. hf: (B,d) in storage dtype."""
    zpre = (xz + _block_diag(p["r_z"], hf)).astype(jnp.float32) + p["b_z"]
    ipre = (xi + _block_diag(p["r_i"], hf)).astype(jnp.float32) + p["b_i"]
    fpre = (xf + _block_diag(p["r_f"], hf)).astype(jnp.float32) + p["b_f"]
    opre = (xo + _block_diag(p["r_o"], hf)).astype(jnp.float32) + p["b_o"]
    return zpre, ipre, fpre, opre


@jax.custom_vjp
def slstm_scan_train(rec, xz, xi, xf, xo):
    """Training-time sLSTM over (B,S,d) pre-projected inputs → hs (B,S,d) f32.

    rec = {r_z,r_i,r_f,r_o,b_z,b_i,b_f,b_o}. Zero initial state."""
    hs, _ = _slstm_fwd_core(rec, xz, xi, xf, xo)
    return hs


def _slstm_fwd_core(rec, xz, xi, xf, xo):
    b, s, d = xz.shape
    dt = xz.dtype

    def step(carry, xs):
        c, n, h, m = carry
        xz_t, xi_t, xf_t, xo_t = xs
        zpre, ipre, fpre, opre = _slstm_gates(rec, h.astype(dt), xz_t, xi_t, xf_t, xo_t)
        z = jnp.tanh(zpre)
        o = jax.nn.sigmoid(opre)
        f_sig = jax.nn.sigmoid(fpre)
        logf = jax.nn.log_sigmoid(fpre)
        m_new = jnp.maximum(logf + m, ipre)
        i_s = jnp.exp(ipre - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, _SLSTM_EPS)
        saved = (z, i_s, f_s, o, f_sig, c_new, n_new, h_new)
        return (c_new, n_new, h_new, m_new), saved

    zero = jnp.zeros((b, d), jnp.float32)
    carry0 = (zero, zero, zero, jnp.full((b, d), -1e30, jnp.float32))
    xs = tuple(a.swapaxes(0, 1) for a in (xz, xi, xf, xo))
    _, saved = jax.lax.scan(step, carry0, xs)
    hs = saved[-1].swapaxes(0, 1)  # (B,S,d) f32
    return hs, saved


def _slstm_fwd(rec, xz, xi, xf, xo):
    hs, saved = _slstm_fwd_core(rec, xz, xi, xf, xo)
    return hs, (rec, xz, xi, xf, xo, saved)


def _slstm_bwd(res, dhs):
    rec, xz, xi, xf, xo, saved = res
    z, i_s, f_s, o, f_sig, c_seq, n_seq, h_seq = saved  # all (S,B,d) f32
    s, b, d = z.shape
    dt = xz.dtype
    zero = jnp.zeros((b, d), jnp.float32)

    # previous-step states (shifted by one; zero initial)
    def prev(seq):
        return jnp.concatenate([zero[None], seq[:-1]], axis=0)

    c_prev, n_prev, h_prev = prev(c_seq), prev(n_seq), prev(h_seq)

    def bwd_step(carry, xs):
        dh_rec, dc_next, dn_next = carry
        dhs_t, z_t, i_t, f_t, o_t, fs_t, c_t, n_t, cp, np_ = xs
        dh = dhs_t + dh_rec
        nh = jnp.maximum(n_t, _SLSTM_EPS)
        dc = dh * o_t / nh + dc_next
        dn_raw = -dh * o_t * c_t / (nh * nh)
        dn = jnp.where(n_t > _SLSTM_EPS, dn_raw, 0.0) + dn_next
        do = dh * c_t / nh
        dopre = do * o_t * (1.0 - o_t)
        dzpre = dc * i_t * (1.0 - z_t * z_t)
        dipre = (dc * z_t + dn) * i_t
        dlogf = (dc * cp + dn * np_) * f_t
        dfpre = dlogf * (1.0 - fs_t)
        # recurrent path into h_{t-1}: transpose block-diag matmuls
        def bdT(w, g):
            h_, dh_, _ = w.shape
            gs = g.reshape(b, h_, dh_)
            out = jnp.einsum(
                "bhj,hij->bhi", gs, w.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return out.reshape(b, h_ * dh_)

        dh_prev = (
            bdT(rec["r_z"], dzpre) + bdT(rec["r_i"], dipre)
            + bdT(rec["r_f"], dfpre) + bdT(rec["r_o"], dopre)
        )
        dc_prev = dc * f_t
        dn_prev = dn * f_t
        return (dh_prev, dc_prev, dn_prev), (dzpre, dipre, dfpre, dopre)

    xs = (dhs.swapaxes(0, 1), z, i_s, f_s, o, f_sig, c_seq, n_seq, c_prev, n_prev)
    _, deltas = jax.lax.scan(bwd_step, (zero, zero, zero), xs, reverse=True)
    dzpre, dipre, dfpre, dopre = deltas  # (S,B,d)

    # weight grads: ONE contraction over (S,B) per weight — outside the loop
    h_ = rec["r_z"].shape[0]
    dh_ = rec["r_z"].shape[1]
    hp = h_prev.reshape(s, b, h_, dh_)

    def dR(dpre):
        return jnp.einsum(
            "sbhi,sbhj->hij", hp, dpre.reshape(s, b, h_, dh_),
            preferred_element_type=jnp.float32,
        ).astype(rec["r_z"].dtype)

    drec = {
        "r_z": dR(dzpre), "r_i": dR(dipre), "r_f": dR(dfpre), "r_o": dR(dopre),
        "b_z": jnp.sum(dzpre, (0, 1)), "b_i": jnp.sum(dipre, (0, 1)),
        "b_f": jnp.sum(dfpre, (0, 1)), "b_o": jnp.sum(dopre, (0, 1)),
    }
    dx = tuple(dp.swapaxes(0, 1).astype(dt) for dp in (dzpre, dipre, dfpre, dopre))
    return (drec,) + dx


slstm_scan_train.defvjp(_slstm_fwd, _slstm_bwd)


def slstm_block(p: Params, x: jax.Array, cfg: ModelConfig, state: dict | None):
    b, s, d = x.shape
    tail = state["conv"] if state is not None else None
    conv_out, new_tail = causal_conv(p, x, tail)
    conv_out = jax.nn.silu(conv_out)
    xz = conv_out @ p["w_z"]
    xi = conv_out @ p["w_i"]
    xf = conv_out @ p["w_f"]
    xo = x @ p["w_o"]
    if state is None:
        # training: custom-VJP scan (weight grads leave the loop — see above)
        rec = {k: p[k] for k in ("r_z", "r_i", "r_f", "r_o", "b_z", "b_i", "b_f", "b_o")}
        hs = slstm_scan_train(rec, xz, xi, xf, xo).astype(x.dtype)
        carry = None
    else:
        cell_state = {k: state[k] for k in ("c", "n", "h", "m")}

        def step(carry, xs):
            new = slstm_cell(p, *xs, carry)
            return new, new["h"]

        carry, hs = jax.lax.scan(
            step,
            cell_state,
            (
                xz.swapaxes(0, 1), xi.swapaxes(0, 1),
                xf.swapaxes(0, 1), xo.swapaxes(0, 1),
            ),
        )
        hs = hs.swapaxes(0, 1).astype(x.dtype)           # (B,S,d)
    hs = _head_norm(hs, p["gn"], cfg.n_heads, cfg.norm_eps)
    ff_gate, ff_up = jnp.split(hs @ p["ff_up"], 2, axis=-1)
    out = (jax.nn.gelu(ff_gate) * ff_up) @ p["ff_down"]
    new_state = None
    if state is not None:
        new_state = dict(carry)
        new_state["conv"] = new_tail
    return out, new_state


# ------------------------------------------------------------- entry points
def _bodies(cfg: ModelConfig, mode: str):
    def mk(kind):
        def body(x, sl):
            p = sl["p"]
            h = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
            state = sl.get("c") if mode != "train" else None
            fn = mlstm_block if kind == "mlstm" else slstm_block
            out, new_state = fn(p["blk"], h, cfg, state)
            return x + out, new_state
        return body

    return [mk(k) for k in _pattern(cfg)]


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array):
    x = embed_tokens(params["embed"], tokens)
    bodies = _bodies(cfg, "train")
    wrapped = [lambda x, lp, b=b: b(x, {"p": lp}) for b in bodies]
    x, _ = periodic_scan(wrapped, x, params["periods"], params["rest"], remat=cfg.remat)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict):
    logits, _ = forward(cfg, params, batch["tokens"])
    loss, acc = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss, "accuracy": acc}


def _empty_state(cfg: ModelConfig, kind: str, batch: int):
    d, inner, h = cfg.d_model, _inner(cfg), cfg.n_heads
    if kind == "mlstm":
        dh = inner // h
        return {
            "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, 3, inner), cfg.dtype),
        }
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, d), cfg.dtype),
    }


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int, *, window: int = 0):
    pat = _pattern(cfg)
    per_layer = [
        _empty_state(cfg, pat[i % len(pat)], batch) for i in range(cfg.n_layers)
    ]
    periods, rest = periodic_stack(per_layer, len(pat))
    return {"periods": periods, "rest": rest, "pos": jnp.zeros((), jnp.int32)}


def select_rows(cond: jax.Array, a: dict, b: dict) -> dict:
    """Per-row merge of two decode-cache states (same structure): row r of
    the result takes ``a``'s state where ``cond[r]`` else ``b``'s.

    Layout contract (see ``init_decode_cache``): periods leaves carry the
    batch at axis 1 — (n_rep, B, ...) — rest leaves at axis 0. ``pos`` is a
    batch-free scalar step counter, so it always advances with ``a``. The
    speculative-decoding draft backend uses this both to reset stale rows
    to the empty state and to freeze rows past their own prompt length
    while a batched draft prefill scans to the longest row's."""
    def sel(axis):
        def f(x, y):
            shape = [1] * x.ndim
            shape[axis] = cond.shape[0]
            return jnp.where(cond.reshape(shape), x, y)
        return f

    periods = None
    if a["periods"] is not None:
        periods = jax.tree_util.tree_map(sel(1), a["periods"], b["periods"])
    rest = jax.tree_util.tree_map(sel(0), list(a["rest"]), list(b["rest"]))
    return {"periods": periods, "rest": rest, "pos": a["pos"]}


def gather_snapshots(snaps: dict, idx: jax.Array) -> dict:
    """Select one per-row state from a stack of decode-cache snapshots.

    ``snaps`` is a decode cache whose leaves carry a leading snapshot axis
    (periods leaves (S, n_rep, B, ...), rest leaves (S, B, ...)) — the
    stacked ys of a ``lax.scan`` over ``decode_step``. ``idx`` (B,) picks
    snapshot ``idx[r]`` for batch row r, giving the speculative-decoding
    rollback: restore each draft row to the state just after its last
    ACCEPTED token, discarding the rejected tail's recurrent updates."""
    def g(axis):
        def f(leaf):
            return jax.vmap(
                lambda i, l: l[i], in_axes=(0, axis), out_axes=axis - 1
            )(idx, leaf)
        return f

    periods = None
    if snaps["periods"] is not None:
        periods = jax.tree_util.tree_map(g(2), snaps["periods"])
    rest = jax.tree_util.tree_map(g(1), list(snaps["rest"]))
    return {"periods": periods, "rest": rest, "pos": jnp.zeros((), jnp.int32)}


def _run_cached(cfg, params, cache, x, mode):
    pat = _pattern(cfg)
    bodies = _bodies(cfg, mode)
    wrapped = [
        (lambda x, sl, b=b: b(x, sl)) for b in bodies
    ]
    periods = None
    if params["periods"] is not None:
        periods = {
            f"pos{i}": {"p": params["periods"][f"pos{i}"], "c": cache["periods"][f"pos{i}"]}
            for i in range(len(pat))
        }
    rest = [{"p": lp, "c": lc} for lp, lc in zip(params["rest"], cache["rest"])]
    x, (aux_scanned, aux_rest) = periodic_scan(
        wrapped, x, periods, rest, remat=(cfg.remat and mode != "decode")
    )
    new_cache = {"periods": None, "rest": list(aux_rest), "pos": cache["pos"] + x.shape[1]}
    if aux_scanned is not None:
        new_cache["periods"] = {f"pos{i}": aux_scanned[i] for i in range(len(pat))}
    return x, new_cache


def decode_step(cfg: ModelConfig, params: Params, cache: dict, tokens: jax.Array, *, window: int = 0):
    x = embed_tokens(params["embed"], tokens)
    x, new_cache = _run_cached(cfg, params, cache, x, "decode")
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg)[:, 0]
    return new_cache, logits


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, *, window: int = 0, cache_window: int = 0):
    b, s = tokens.shape
    cache = init_decode_cache(cfg, b, s)
    x = embed_tokens(params["embed"], tokens)
    x, new_cache = _run_cached(cfg, params, cache, x, "prefill")
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    return new_cache, logits
