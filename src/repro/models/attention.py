"""GQA attention: training (blockwise, memory-bounded), prefill, and decode
with linear or ring-buffer (sliding-window) KV caches.

Design notes
------------
* Training/prefill attention scans over **query chunks** so the live score
  tensor is (B, heads, q_chunk, S) instead of (B, heads, S, S). At 32k
  sequence length the full score tensor would be ~128 GiB/device-group; the
  chunked form keeps it at q_chunk/S of that. This is the jnp-level
  flash-attention pattern; the Pallas `swa_decode` kernel covers the decode
  hot path.
* Decode caches are ring buffers of capacity C. For full-attention decode
  C = max context; for sliding-window decode C = window, which is what makes
  `long_500k` (524288-token context) feasible: memory O(window), compute
  O(window) per token.
* RoPE is applied at cache-write time (keys stored rotated), so reads never
  need per-slot position bookkeeping beyond the validity mask.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, he_init
from repro.models.sharding import constrain, gather_heads

Params = Any

NEG_INF = -2.0**30  # large finite negative; avoids NaN from all-masked rows

# Unreachable token position: KV lanes parked here are excluded by every
# causal mask (no real query position reaches 2^30). Used by suffix prefill
# to banish gathered page-table lanes that hold no live prefix.
FAR_POS = 2**30

# When True, decode_attend computes its attention through the Pallas
# flash-decode kernel (repro.kernels.swa_decode) instead of the jnp path.
# The jnp path below IS the kernel's oracle; tests pin them equal.
USE_DECODE_KERNEL = False

# When True (and USE_DECODE_KERNEL), decode_attend uses the length-aware
# paged kernel (repro.kernels.paged_decode): per-slot live lengths are
# scalar-prefetched and KV pages beyond each row's live span are skipped —
# no DMA, no MXU work. Output is bitwise-identical to the unpaged kernel
# (tests/test_paged_decode.py pins it), so flipping this is purely a perf
# decision.
USE_PAGED_DECODE = False

# When True, attend_full runs the Pallas flash-attention kernel
# (repro.kernels.flash_prefill) for training/prefill instead of the jnp
# chunked path. The kernel keeps the softmax state in VMEM — the jnp path
# materializes (B,Hkv,G,chunk,T) probability tensors in HBM, the dominant
# §Roofline memory term at prefill_32k. Kernel assumes dense 0..S-1 query
# positions (true for every training/prefill call site).
USE_PREFILL_KERNEL = False

# When True, prefill_slots' SUFFIX mode (prefix-cache hit admission) runs
# the Pallas suffix-prefill kernel (repro.kernels.flash_suffix_prefill):
# the cached prefix is read directly through the page table via scalar
# prefetch instead of gathering table_width × page_size lanes in HBM, and
# dead prefix pages are skipped with pl.when. The displaced jnp
# gather-concat path below IS the kernel's oracle; tests pin them equal.
USE_SUFFIX_KERNEL = False


def set_decode_kernel(enabled: bool, *, paged: bool = False) -> None:
    global USE_DECODE_KERNEL, USE_PAGED_DECODE
    USE_DECODE_KERNEL = enabled
    USE_PAGED_DECODE = paged


def set_prefill_kernel(enabled: bool) -> None:
    global USE_PREFILL_KERNEL
    USE_PREFILL_KERNEL = enabled


def set_suffix_kernel(enabled: bool) -> None:
    global USE_SUFFIX_KERNEL
    USE_SUFFIX_KERNEL = enabled


# ------------------------------------------------------------------ params
def init_attention(key, cfg: ModelConfig, d_model: int | None = None) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": he_init(kq, (d, cfg.n_heads * hd), cfg.dtype),
        "wk": he_init(kk, (d, cfg.n_kv_heads * hd), cfg.dtype),
        "wv": he_init(kv, (d, cfg.n_kv_heads * hd), cfg.dtype),
        "wo": he_init(ko, (cfg.n_heads * hd, d), cfg.dtype, fan_in=cfg.n_heads * hd),
    }


def _split_heads(x: jax.Array, n_heads: int, head_dim: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,Hkv,G,hd), k: (B,Sk,Hkv,hd) → (B,Hkv,G,Sq,Sk) fp32.

    Inputs stay in their storage dtype (bf16): the MXU natively accumulates
    bf16×bf16 into fp32 (`preferred_element_type`), and explicit
    ``astype(f32)`` casts would materialize an fp32 copy of the entire K
    operand in HBM — at decode that is a cache-sized temp per layer."""
    return jnp.einsum(
        "bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32
    )


def _gqa_out(probs: jax.Array, v: jax.Array, dtype) -> jax.Array:
    """probs: (B,Hkv,G,Sq,Sk) fp32, v: (B,Sk,Hkv,hd) → (B,Sq,Hkv*G*hd).

    probs are cast to the value dtype (the MXU ingests bf16); accumulation
    stays fp32 via preferred_element_type."""
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    b, sq = out.shape[0], out.shape[1]
    return out.reshape(b, sq, -1).astype(dtype)


def attend_full(
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    kv: tuple[jax.Array, jax.Array] | None = None,
    kv_positions: jax.Array | None = None,
    rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill / encoder / cross-attn).

    x: (B, S, D). ``kv``: precomputed (k, v) for cross-attention (already
    head-split and rotated if applicable); otherwise self-attention.
    ``window > 0`` restricts to a causal sliding window.
    """
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    b, s, _ = x.shape

    q = _split_heads(x @ params["wq"], hq, hd)
    q = constrain(q, "batch", "seq", "heads", None)
    if kv is None:
        k = _split_heads(x @ params["wk"], hkv, hd)
        v = _split_heads(x @ params["wv"], hkv, hd)
        if rope and positions is not None:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        kv_pos = positions
    else:
        k, v = kv
        if rope and positions is not None:
            q = apply_rope(q, positions, cfg.rope_theta)
        kv_pos = kv_positions
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    q = q.reshape(b, s, hkv, g, hd)
    scale = hd**-0.5

    t = k.shape[1]
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

    def q_block(q_blk: jax.Array, pos_blk: jax.Array) -> jax.Array:
        # q_blk: (B, C, Hkv, G, hd); pos_blk: (B, C)
        scores = _gqa_scores(q_blk, k) * scale  # (B,Hkv,G,C,T)
        if causal:
            mask = pos_blk[:, None, None, :, None] >= kv_pos[:, None, None, None, :]
            if window > 0:
                mask &= (
                    pos_blk[:, None, None, :, None] - kv_pos[:, None, None, None, :]
                ) < window
            scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return _gqa_out(probs, v, x.dtype)  # (B, C, H*hd)

    if USE_PREFILL_KERNEL:
        from repro.kernels.ops import flash_prefill_attention

        out = flash_prefill_attention(
            q, k, v, causal=causal, window=window, use_kernel=True
        )
        out = constrain(out.reshape(b, s, -1), "batch", "seq", "heads")
        return gather_heads(out) @ params["wo"]

    # query-side positions (kv_pos is the key side — different length under
    # cross-attention, so it must never stand in for the query positions)
    q_pos = (
        positions
        if positions is not None
        else jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    )
    if s <= q_chunk:
        attn = q_block(q, q_pos)
    else:
        n_chunks = s // q_chunk
        assert s % q_chunk == 0, f"seq {s} not divisible by q_chunk {q_chunk}"
        qp = q_pos
        q_r = q.reshape(b, n_chunks, q_chunk, hkv, g, hd).swapaxes(0, 1)
        p_r = qp.reshape(b, n_chunks, q_chunk).swapaxes(0, 1)
        attn = jax.lax.map(lambda qb: q_block(qb[0], qb[1]), (q_r, p_r))
        attn = attn.swapaxes(0, 1).reshape(b, s, -1)

    attn = constrain(attn, "batch", "seq", "heads")
    return gather_heads(attn) @ params["wo"]


# ------------------------------------------------------------------- caches
def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    *,
    window: int = 0,
    dtype=None,
    per_slot: bool = False,
) -> dict:
    """Ring-buffer KV cache. capacity = window if window>0 else max_seq.

    ``per_slot=True`` gives every batch row its own write position (shape
    (B,) instead of scalar), turning rows into independently resettable
    *slots* for the continuous-batching serve engine: a finished request's
    slot is recycled by zeroing its ``pos`` entry — stale k/v need no
    clearing because the validity mask is derived from ``pos``.
    """
    cap = window if (0 < window < max_seq) else max_seq
    hd = cfg.resolved_head_dim
    dtype = dtype or cfg.dtype
    pos_shape = (batch,) if per_slot else ()
    return {
        "k": jnp.zeros((batch, cap, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, cap, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros(pos_shape, jnp.int32),  # tokens already written
    }


def cache_capacity(cache: dict) -> int:
    return cache["k"].shape[1]


def fill_cache(cache: dict, k: jax.Array, v: jax.Array, start: int = 0) -> dict:
    """Prefill: write S tokens (already rotated) into the ring buffer."""
    cap = cache_capacity(cache)
    s = k.shape[1]
    if s >= cap:
        # only the last `cap` tokens survive; ring layout slot = pos % cap.
        # tail_k[i] holds global position first_pos + i and must land at
        # slot (first_pos + i) % cap — a roll by +first_pos (the seed
        # rolled by -first_pos, scrambling any wrap-around prefill).
        tail_k, tail_v = k[:, s - cap :], v[:, s - cap :]
        first_pos = start + s - cap
        roll = first_pos % cap
        new_k = jnp.roll(tail_k, roll, axis=1)
        new_v = jnp.roll(tail_v, roll, axis=1)
    else:
        idx = (start + jnp.arange(s)) % cap
        new_k = cache["k"].at[:, idx].set(k)
        new_v = cache["v"].at[:, idx].set(v)
    return {"k": new_k, "v": new_v, "pos": jnp.asarray(start + s, jnp.int32)}


def fill_cache_rows(
    cache_k: jax.Array,
    cache_v: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    starts: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched per-row ring write for multi-slot prefill.

    Row r writes its first ``lengths[r]`` tokens of k/v (already rotated)
    into its own ring row starting at ring position ``starts[r]`` (0 when
    ``starts`` is None), leaving the ring in the exact state lengths[r]
    sequential one-token writes (slot = pos % cap, pos counted from the
    row's start) would — i.e. the batched sibling of ``fill_cache`` with
    per-row prompt lengths and start offsets. A nonzero start is the
    SUFFIX-prefill case: ring entries below the start already hold a shared
    prefix and must not move. Implemented as a gather (for each ring slot
    c, the LAST prompt index landing on c), not a scatter: scatters with
    duplicate indices (wrap-around) have unspecified winners.

    cache_k/v: (n, C, Hkv, hd) the n target ring rows; k/v: (n, S, Hkv, hd)
    right-padded prompts; lengths: (n,) true lengths. Ring entries a row
    never reaches keep their old value. Returns (new_k, new_v).

    ``starts=None`` traces exactly the pre-existing zero-start math, so
    every legacy caller stays bitwise unchanged.
    """
    cap = cache_k.shape[1]
    c = jnp.arange(cap)[None, :]                      # (1, C)
    last = jnp.asarray(lengths, jnp.int32)[:, None] - 1  # (n, 1)
    if starts is None:
        c_rel = c                                     # ring slot == index
    else:
        # prompt index j lands at ring slot (starts + j) % cap, so the
        # smallest index landing on c is (c - starts) mod cap
        c_rel = (c - jnp.asarray(starts, jnp.int32)[:, None]) % cap
    # largest prompt index j < lengths[r] with j ≡ c_rel (mod cap)
    j_star = c_rel + cap * ((last - c_rel) // cap)    # (n, C)
    written = c_rel <= last
    j_safe = jnp.clip(j_star, 0, k.shape[1] - 1)[:, :, None, None]
    gk = jnp.take_along_axis(k, j_safe, axis=1)       # (n, C, Hkv, hd)
    gv = jnp.take_along_axis(v, j_safe, axis=1)
    keep = written[:, :, None, None]
    return jnp.where(keep, gk, cache_k), jnp.where(keep, gv, cache_v)


def decode_attend(
    params: Params,
    x: jax.Array,
    cache: dict,
    cfg: ModelConfig,
    *,
    window: int = 0,
    rope: bool = True,
) -> tuple[jax.Array, dict]:
    """One decode step. x: (B, 1, D). Returns (out (B,1,D), new cache).

    The cache is a ring buffer; ``window`` is the attention span (0 = all
    cached tokens). Keys are stored rotated, the validity mask reconstructs
    each slot's global position from ``pos``.

    ``cache["pos"]`` may be a scalar (all rows in lockstep — the classic
    single-batch serve path) or shape (B,) (per-slot positions — the
    continuous-batching engine, where each row is an independent request
    at its own depth).
    """
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    b = x.shape[0]
    cap = cache_capacity(cache)
    pos = cache["pos"]  # tokens already cached; current token index == pos
    per_slot = pos.ndim == 1

    q = _split_heads(x @ params["wq"], hq, hd)
    k = _split_heads(x @ params["wk"], hkv, hd)
    v = _split_heads(x @ params["wv"], hkv, hd)
    if rope:
        pos_b = pos[:, None] if per_slot else jnp.broadcast_to(pos[None], (b, 1))
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)

    slot = pos % cap
    # Reshard the ONE-TOKEN k/v to the cache layout BEFORE the in-place
    # write: k/v inherit the wk/wv column-parallel (model-sharded) layout
    # from the projection, and letting that propagate through the
    # dynamic-update-slice makes XLA reshard the ENTIRE cache afterwards
    # (an all-gather of cap·Hkv·hd per layer per step — ~47 GB/dev on
    # stablelm-12b decode_32k — instead of one token's worth).
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    if per_slot:
        # each row writes at its own ring offset — batched scatter
        rows = jnp.arange(b)
        new_k = cache["k"].at[rows, slot].set(k[:, 0])
        new_v = cache["v"].at[rows, slot].set(v[:, 0])
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    new_k = constrain(new_k, "batch", "cache_seq", "kv_heads", None)
    new_v = constrain(new_v, "batch", "cache_seq", "kv_heads", None)

    if USE_DECODE_KERNEL:
        from repro.kernels.ops import swa_decode_attention

        q_k = q.reshape(b, hkv, g, hd)
        out = swa_decode_attention(
            q_k, new_k, new_v, pos, window,
            use_kernel=True, paged=USE_PAGED_DECODE,
        )
        out = out.reshape(b, 1, hkv * g * hd).astype(x.dtype)
    else:
        # global position held by each slot after the write
        slots = jnp.arange(cap)
        pos_c = pos[:, None] if per_slot else pos  # (B,1) or ()
        slot_c = slot[:, None] if per_slot else slot
        gpos = pos_c - (slot_c - slots) % cap  # == pos at the write slot
        lo = pos_c - (window - 1) if window > 0 else 0
        valid = (gpos >= jnp.maximum(lo, 0)) & (gpos <= pos_c)
        mask = (
            valid[:, None, None, None, :]
            if per_slot
            else valid[None, None, None, None, :]
        )

        q = q.reshape(b, 1, hkv, g, hd)
        scores = _gqa_scores(q, new_k) * (hd**-0.5)  # (B,Hkv,G,1,cap)
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, new_v, x.dtype)  # (B,1,H*hd)
    out = gather_heads(out) @ params["wo"]
    new_cache = {"k": new_k, "v": new_v, "pos": pos + 1}
    return out, new_cache


def init_paged_pool(
    cfg: ModelConfig,
    num_slots: int,
    num_pages: int,
    page_size: int,
    table_width: int,
    *,
    dtype=None,
) -> dict:
    """Shared paged KV pool + per-slot page tables (one layer's worth).

    Physical storage is ONE pool of ``num_pages`` pages of ``page_size``
    token slots, shared by every request slot; ``table`` maps each slot's
    logical pages into it. Entry 0 of the pool is the reserved SCRATCH page:
    table entries are 0 until the engine's allocator assigns a real page, so
    writes by retired/unallocated slots land somewhere harmless and reads
    never dereference them (the validity mask kills logical slots beyond
    ``pos`` before any garbage can matter). Logical ring capacity per slot
    is ``table_width * page_size`` — the ring-position math is unchanged,
    only the physical placement is indirected."""
    hd = cfg.resolved_head_dim
    dtype = dtype or cfg.dtype
    return {
        "k": jnp.zeros((num_pages, page_size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((num_pages, page_size, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((num_slots,), jnp.int32),
        "table": jnp.zeros((num_slots, table_width), jnp.int32),
    }


def gather_pages(pool: jax.Array, table: jax.Array) -> jax.Array:
    """pool (P, page, Hkv, hd) × table (B, T) → contiguous (B, T·page, Hkv,
    hd) ring rows. The jnp production path reads the paged cache through
    the SAME gather as every oracle (``kernels.ref.gather_pages_ref``) and
    then runs the EXACT ring math — which is what makes the paged engine
    bitwise token-identical to the contiguous-ring engine."""
    from repro.kernels.ref import gather_pages_ref

    return gather_pages_ref(pool, table)


def decode_attend_paged(
    params: Params,
    x: jax.Array,
    cache: dict,
    cfg: ModelConfig,
    *,
    window: int = 0,
    rope: bool = True,
) -> tuple[jax.Array, dict]:
    """One decode step over the SHARED paged pool. x: (B, 1, D).

    cache: {"k"/"v": (P, page, Hkv, hd) pool, "pos": (B,), "table": (B, T)}.
    Row b's token is written at logical ring slot ``pos[b] % (T·page)``,
    which the page table maps to physical ``(table[b, slot//page],
    slot % page)``. Live slots own their pages exclusively (allocator
    invariant) so the batched scatter has no cross-row collisions except on
    the reserved scratch page 0, whose content is never validly read.

    The attention read is either the page-table Pallas kernel (pool +
    scalar-prefetched table rows, no gather) or the jnp path: gather the
    row's pages into contiguous ring rows and run the same masked-attention
    math as ``decode_attend``'s per-slot branch — bitwise identical to the
    ring engine holding the same values.

    int8 pools (``ks``/``vs`` keys — (P, page, Hkv) fp32 scales) quantize
    the one fresh token vector per kv-head at write time
    (``quantize.kv_quant``) and dequantize at read time: in-body in the
    kernel, or on the gathered rows in the jnp path — the same value set
    either way."""
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    b = x.shape[0]
    pool_k, pool_v = cache["k"], cache["v"]
    pool_ks, pool_vs = cache.get("ks"), cache.get("vs")
    quant = pool_ks is not None
    table = cache["table"]
    page = pool_k.shape[1]
    cap = table.shape[1] * page
    pos = cache["pos"]  # (B,) — paged caches are always per-slot

    q = _split_heads(x @ params["wq"], hq, hd)
    k = _split_heads(x @ params["wk"], hkv, hd)
    v = _split_heads(x @ params["wv"], hkv, hd)
    if rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)

    slot = pos % cap
    rows = jnp.arange(b)
    phys_page = table[rows, slot // page]
    off = slot % page
    # Reshard the ONE-TOKEN k/v to the pool layout BEFORE the in-place
    # write (same reason as decode_attend: k/v inherit the wk/wv
    # column-parallel layout, and letting it propagate through the scatter
    # makes XLA reshard the ENTIRE pool afterwards). The pool has no batch
    # dim — pages shard where the ring cache sharded its sequence axis.
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    if quant:
        from repro.kernels.quantize import kv_dequant, kv_quant

        kq, ksc = kv_quant(k[:, 0])   # (B, Hkv, hd) int8, (B, Hkv) f32
        vq, vsc = kv_quant(v[:, 0])
        new_k = pool_k.at[phys_page, off].set(kq)
        new_v = pool_v.at[phys_page, off].set(vq)
        new_ks = pool_ks.at[phys_page, off].set(ksc)
        new_vs = pool_vs.at[phys_page, off].set(vsc)
        new_ks = constrain(new_ks, "cache_seq", None, "kv_heads")
        new_vs = constrain(new_vs, "cache_seq", None, "kv_heads")
    else:
        new_k = pool_k.at[phys_page, off].set(k[:, 0])
        new_v = pool_v.at[phys_page, off].set(v[:, 0])
    new_k = constrain(new_k, "cache_seq", None, "kv_heads", None)
    new_v = constrain(new_v, "cache_seq", None, "kv_heads", None)

    if USE_DECODE_KERNEL:
        from repro.kernels.ops import swa_decode_attention

        q_k = q.reshape(b, hkv, g, hd)
        out = swa_decode_attention(
            q_k, new_k, new_v, pos, window, use_kernel=True, table=table,
            k_scale=new_ks if quant else None,
            v_scale=new_vs if quant else None,
        )
        out = out.reshape(b, 1, hkv * g * hd).astype(x.dtype)
    else:
        if quant:
            t_w = table.shape[1]
            g_k = kv_dequant(
                gather_pages(new_k, table),
                new_ks[table].reshape(b, t_w * page, hkv), q.dtype,
            )
            g_v = kv_dequant(
                gather_pages(new_v, table),
                new_vs[table].reshape(b, t_w * page, hkv), q.dtype,
            )
        else:
            g_k = gather_pages(new_k, table)
            g_v = gather_pages(new_v, table)
        # identical math to decode_attend's per-slot branch, on the
        # gathered rows — same values, same shapes, same reductions
        slots = jnp.arange(cap)
        pos_c, slot_c = pos[:, None], slot[:, None]
        gpos = pos_c - (slot_c - slots) % cap
        lo = pos_c - (window - 1) if window > 0 else 0
        valid = (gpos >= jnp.maximum(lo, 0)) & (gpos <= pos_c)
        mask = valid[:, None, None, None, :]

        q = q.reshape(b, 1, hkv, g, hd)
        scores = _gqa_scores(q, g_k) * (hd**-0.5)  # (B,Hkv,G,1,cap)
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, g_v, x.dtype)  # (B,1,H*hd)
    out = gather_heads(out) @ params["wo"]
    new_cache = {"k": new_k, "v": new_v, "pos": pos + 1, "table": table}
    if quant:
        new_cache["ks"], new_cache["vs"] = new_ks, new_vs
    return out, new_cache


def compute_kv_for_prefill(
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    rope: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Head-split, rotated (k, v) for writing into a cache after prefill."""
    hd = cfg.resolved_head_dim
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v
