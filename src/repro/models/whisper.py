"""Whisper-medium backbone: transformer encoder-decoder. [arXiv:2212.04356]

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: inputs arrive as precomputed frame embeddings (B, enc_seq=1500,
d_model). Everything downstream — the 24-layer encoder, the 24-layer decoder
with cross-attention, cached decode — is real.

Whisper idioms kept: LayerNorm (with bias), plain GELU MLPs, no RoPE.
Positions are sinusoidal on both sides (real whisper uses learned decoder
positions capped at 448; the assigned decode shapes run 32k/524k-step decode,
so we use the unbounded sinusoidal form and note the deviation in DESIGN.md).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (
    default_q_chunk,
    embed_tokens,
    init_embedding,
    lm_logits,
    positions_for,
    scan_layers,
    stack_layer_params,
)
from repro.models.layers import (
    apply_mlp,
    cross_entropy_loss,
    init_layer_norm,
    init_mlp,
    layer_norm,
)

Params = Any


def sinusoid_positions(seq: int, d: int, offset=0) -> jax.Array:
    pos = (jnp.arange(seq) + offset)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2 - 1))
    angles = pos * inv
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ------------------------------------------------------------------- params
def _init_enc_layer(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_layer_norm(cfg.d_model, cfg.dtype),
        "attn": attn.init_attention(k1, cfg),
        "ln2": init_layer_norm(cfg.d_model, cfg.dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, "plain", cfg.dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_layer_norm(cfg.d_model, cfg.dtype),
        "attn": attn.init_attention(k1, cfg),
        "ln_x": init_layer_norm(cfg.d_model, cfg.dtype),
        "xattn": attn.init_attention(k2, cfg),
        "ln2": init_layer_norm(cfg.d_model, cfg.dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, "plain", cfg.dtype),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    n_enc = cfg.encoder_layers or cfg.n_layers
    keys = jax.random.split(key, n_enc + cfg.n_layers + 1)
    enc_layers = [_init_enc_layer(keys[i], cfg) for i in range(n_enc)]
    dec_layers = [_init_dec_layer(keys[n_enc + i], cfg) for i in range(cfg.n_layers)]
    return {
        "embed": init_embedding(keys[-1], cfg),
        "enc": {
            "layers": stack_layer_params(enc_layers),
            "ln_post": init_layer_norm(cfg.d_model, cfg.dtype),
        },
        "dec": {
            "layers": stack_layer_params(dec_layers),
            "ln_f": init_layer_norm(cfg.d_model, cfg.dtype),
        },
    }


# ------------------------------------------------------------------ encoder
def encode(cfg: ModelConfig, params: Params, audio_embeds: jax.Array) -> jax.Array:
    """audio_embeds: (B, enc_seq, D) from the stub conv frontend."""
    b, s, d = audio_embeds.shape
    x = audio_embeds + sinusoid_positions(s, d).astype(audio_embeds.dtype)[None]

    def body(h, lp):
        a = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
        a = attn.attend_full(
            lp["attn"], a, None, cfg, causal=False, q_chunk=default_q_chunk(s),
            rope=False,
        )
        h = h + a
        f = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
        return h + apply_mlp(lp["mlp"], f, "plain"), jnp.zeros((), jnp.float32)

    x, _ = scan_layers(body, x, params["enc"]["layers"], remat=cfg.remat)
    lnp = params["enc"]["ln_post"]
    return layer_norm(x, lnp["scale"], lnp["bias"], cfg.norm_eps)


# ------------------------------------------------------------------ decoder
def _cross_kv(lp: Params, enc_out: jax.Array, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    k = (enc_out @ lp["xattn"]["wk"]).reshape(*enc_out.shape[:-1], cfg.n_kv_heads, hd)
    v = (enc_out @ lp["xattn"]["wv"]).reshape(*enc_out.shape[:-1], cfg.n_kv_heads, hd)
    return k, v


def decode_forward(
    cfg: ModelConfig, params: Params, tokens: jax.Array, enc_out: jax.Array
) -> jax.Array:
    """Teacher-forced decoder pass (training). Returns fp32 logits."""
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = x + sinusoid_positions(s, cfg.d_model).astype(x.dtype)[None]
    pos = positions_for(tokens)
    q_chunk = default_q_chunk(s)

    def body(h, lp):
        a = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
        a = attn.attend_full(
            lp["attn"], a, pos, cfg, causal=True, q_chunk=q_chunk, rope=False
        )
        h = h + a
        cx = layer_norm(h, lp["ln_x"]["scale"], lp["ln_x"]["bias"], cfg.norm_eps)
        kv = _cross_kv(lp, enc_out, cfg)
        cx = attn.attend_full(
            lp["xattn"], cx, None, cfg, causal=False, kv=kv, q_chunk=q_chunk,
            rope=False,
        )
        h = h + cx
        f = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
        return h + apply_mlp(lp["mlp"], f, "plain"), jnp.zeros((), jnp.float32)

    x, _ = scan_layers(body, x, params["dec"]["layers"], remat=cfg.remat)
    lnf = params["dec"]["ln_f"]
    x = layer_norm(x, lnf["scale"], lnf["bias"], cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg)


def forward(cfg: ModelConfig, params: Params, batch: dict):
    enc_out = encode(cfg, params, batch["audio_embeds"])
    return decode_forward(cfg, params, batch["tokens"], enc_out), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict):
    logits, _ = forward(cfg, params, batch)
    loss, acc = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss, "accuracy": acc}


# ------------------------------------------------------------------- prefill
def prefill(
    cfg: ModelConfig,
    params: Params,
    batch: dict,
    *,
    window: int = 0,
    cache_window: int = 0,
) -> tuple[dict, jax.Array]:
    """Encoder pass + teacher-forced decoder prompt pass.

    Builds the full decode cache (self-attn ring + precomputed cross K/V) and
    returns last-position logits, mirroring ``transformer.prefill``."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    q_chunk = default_q_chunk(s)
    enc_out = encode(cfg, params, batch["audio_embeds"])

    x = embed_tokens(params["embed"], tokens)
    x = x + sinusoid_positions(s, cfg.d_model).astype(x.dtype)[None]
    pos = positions_for(tokens)
    # cache_window > s allocates headroom for decode continuation;
    # cache_window < s is a sliding-window ring smaller than the prompt.
    cap = cache_window if cache_window > 0 else s
    hd = cfg.resolved_head_dim

    def body(h, lp):
        a = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
        k, v = attn.compute_kv_for_prefill(lp["attn"], a, pos, cfg, rope=False)
        a = attn.attend_full(
            lp["attn"], a, pos, cfg, causal=True, window=window, q_chunk=q_chunk,
            rope=False,
        )
        h = h + a
        cx = layer_norm(h, lp["ln_x"]["scale"], lp["ln_x"]["bias"], cfg.norm_eps)
        xk, xv = _cross_kv(lp, enc_out, cfg)
        cx = attn.attend_full(
            lp["xattn"], cx, None, cfg, causal=False, kv=(xk, xv), q_chunk=q_chunk,
            rope=False,
        )
        h = h + cx
        f = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
        layer_cache = attn.fill_cache(
            {
                "k": jnp.zeros((b, cap, cfg.n_kv_heads, hd), cfg.dtype),
                "v": jnp.zeros((b, cap, cfg.n_kv_heads, hd), cfg.dtype),
                "pos": jnp.zeros((), jnp.int32),
            },
            k,
            v,
        )
        return h + apply_mlp(lp["mlp"], f, "plain"), (
            layer_cache["k"], layer_cache["v"], xk, xv,
        )

    x, (ck, cv, xk, xv) = scan_layers(body, x, params["dec"]["layers"], remat=cfg.remat)
    lnf = params["dec"]["ln_f"]
    x = layer_norm(x, lnf["scale"], lnf["bias"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    cache = {
        "k": ck,
        "v": cv,
        "xk": xk,
        "xv": xv,
        "pos": jnp.asarray(s, jnp.int32),
        "window": jnp.asarray(cache_window, jnp.int32),
    }
    return cache, logits


# -------------------------------------------------------------------- decode
def init_decode_cache(
    cfg: ModelConfig,
    params: Params,
    audio_embeds: jax.Array,
    max_seq: int,
    *,
    window: int = 0,
) -> dict:
    """Runs the encoder, precomputes per-layer cross K/V, allocates the
    self-attention ring cache."""
    b = audio_embeds.shape[0]
    enc_out = encode(cfg, params, audio_embeds)

    def layer_kv(lp):
        return _cross_kv(lp, enc_out, cfg)

    xk, xv = jax.vmap(layer_kv)(params["dec"]["layers"])  # (L, B, S_enc, Hkv, hd)
    cap = window if (0 < window < max_seq) else max_seq
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, b, cap, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "xk": xk,
        "xv": xv,
        "pos": jnp.zeros((), jnp.int32),
        "window": jnp.asarray(window, jnp.int32),
    }


def decode_step(
    cfg: ModelConfig, params: Params, cache: dict, tokens: jax.Array, *, window: int = 0
):
    """tokens (B,1) → (cache', logits (B, Vp))."""
    x = embed_tokens(params["embed"], tokens)
    pos = cache["pos"]
    x = x + sinusoid_positions(1, cfg.d_model, offset=pos).astype(x.dtype)[None]

    def body(h, sl):
        lp, ck, cv, xk, xv = sl
        a = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
        a, newc = attn.decode_attend(
            lp["attn"], a, {"k": ck, "v": cv, "pos": pos}, cfg, window=window,
            rope=False,
        )
        h = h + a
        cx = layer_norm(h, lp["ln_x"]["scale"], lp["ln_x"]["bias"], cfg.norm_eps)
        cx = attn.attend_full(
            lp["xattn"], cx, None, cfg, causal=False, kv=(xk, xv), rope=False
        )
        h = h + cx
        f = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
        h = h + apply_mlp(lp["mlp"], f, "plain")
        return h, (newc["k"], newc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec"]["layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    lnf = params["dec"]["ln_f"]
    x = layer_norm(x, lnf["scale"], lnf["bias"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg)[:, 0]
    new_cache = dict(cache, k=nk, v=nv, pos=pos + 1)
    return new_cache, logits
