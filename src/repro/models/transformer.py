"""Decoder-only transformer: train forward, prefill, and cached decode.

This module is the generic engine for the dense, MoE and VLM architectures:
the FFN is a hook (dense MLP or MoE layer), and the embedding entry point is
split out (`forward_embeds`) so the VLM can inject patch embeddings.

All layer iteration is ``lax.scan`` over stacked parameters; decode carries
ring-buffer KV caches as stacked (L, B, C, Hkv, hd) arrays scanned jointly
with the layer params.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import sharding
from repro.models.common import (
    default_q_chunk,
    embed_tokens,
    init_embedding,
    lm_logits,
    positions_for,
    scan_layers,
    stack_layer_params,
)
from repro.models.layers import (
    apply_mlp,
    cross_entropy_loss,
    init_mlp,
    init_rms_norm,
    rms_norm,
)

Params = Any


class FFNHooks(NamedTuple):
    """Pluggable feed-forward: dense MLP (here) or MoE (models/moe.py)."""
    init: Callable[[jax.Array, ModelConfig], Params]
    apply: Callable[[Params, jax.Array, ModelConfig], tuple[jax.Array, jax.Array]]


def _dense_ffn_init(key, cfg: ModelConfig) -> Params:
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype)


def _dense_ffn_apply(params, x, cfg: ModelConfig):
    return apply_mlp(params, x, cfg.act), jnp.zeros((), jnp.float32)


DENSE_FFN = FFNHooks(_dense_ffn_init, _dense_ffn_apply)


# ---------------------------------------------------------------------- init
def init_layer(key, cfg: ModelConfig, ffn: FFNHooks) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model, cfg.dtype),
        "attn": attn.init_attention(k1, cfg),
        "ln2": init_rms_norm(cfg.d_model, cfg.dtype),
        "ffn": ffn.init(k2, cfg),
    }


def init_params(cfg: ModelConfig, key, ffn: FFNHooks = DENSE_FFN) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = [init_layer(keys[i], cfg, ffn) for i in range(cfg.n_layers)]
    return {
        "embed": init_embedding(keys[-1], cfg),
        "layers": stack_layer_params(layers),
        "ln_f": init_rms_norm(cfg.d_model, cfg.dtype),
    }


# ------------------------------------------------------------------- forward
def forward_embeds(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    ffn: FFNHooks = DENSE_FFN,
    window: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward on embeddings. Returns (hidden, aux_loss_sum)."""
    q_chunk = default_q_chunk(x.shape[1])

    def body(h, lp):
        a = rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        a = attn.attend_full(
            lp["attn"], a, positions, cfg, causal=True, window=window,
            q_chunk=q_chunk,
        )
        h = h + a
        f = rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        f, aux = ffn.apply(lp["ffn"], f, cfg)
        return h + f, aux

    x, auxes = scan_layers(body, x, params["layers"], remat=cfg.remat)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    return x, jnp.sum(auxes)


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    *,
    ffn: FFNHooks = DENSE_FFN,
    window: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) → (logits fp32 (B, S, Vp), aux_loss)."""
    x = embed_tokens(params["embed"], tokens)
    pos = positions_for(tokens)
    x, aux = forward_embeds(cfg, params, x, pos, ffn=ffn, window=window)
    return lm_logits(params["embed"], x, cfg), aux


def loss_fn(
    cfg: ModelConfig,
    params: Params,
    batch: dict,
    *,
    ffn: FFNHooks = DENSE_FFN,
    window: int = 0,
) -> tuple[jax.Array, dict]:
    logits, aux = forward(cfg, params, batch["tokens"], ffn=ffn, window=window)
    loss, acc = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "accuracy": acc, "aux_loss": aux}


# -------------------------------------------------------------------- decode
def init_decode_cache(
    cfg: ModelConfig, batch: int, max_seq: int, *, window: int = 0,
    per_slot: bool = False,
) -> dict:
    """Stacked (L, B, C, Hkv, hd) ring caches. ``per_slot=True`` gives each
    batch row an independent position (shape (B,)) so rows act as recyclable
    request slots for the continuous-batching engine."""
    cap = window if (0 < window < max_seq) else max_seq
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, cap, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "pos": jnp.zeros((batch,) if per_slot else (), jnp.int32),
        "window": jnp.asarray(window, jnp.int32),
    }


def init_paged_cache(
    cfg: ModelConfig,
    num_slots: int,
    num_pages: int,
    page_size: int,
    table_width: int,
    *,
    window: int = 0,
    kv_dtype: str = "fp",
) -> dict:
    """Stacked shared paged KV pool: (L, P, page, Hkv, hd) physical pages +
    per-slot page tables (num_slots, T) shared across layers (every layer
    of a slot uses the same logical→physical page map, so ONE table drives
    all L pools). Logical ring capacity per slot is ``table_width *
    page_size``; pool page 0 is the reserved scratch page (see
    ``attention.init_paged_pool``). Total KV memory is ``num_pages`` pages
    regardless of ``num_slots`` — slots share the pool instead of owning
    ``max_seq`` rows each.

    ``kv_dtype="int8"`` stores the pages quantized (kernels/quantize.py
    row scheme): k/v become int8 and ``ks``/``vs`` hold one fp32 scale per
    token-slot per kv-head, (L, P, page, Hkv). Zero-initialized scales
    dequantize unwritten lanes to exactly 0.0 — the same value set the fp
    pool starts with, so the validity-mask story is unchanged."""
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads, hd)
    cache = {
        "pos": jnp.zeros((num_slots,), jnp.int32),
        "table": jnp.zeros((num_slots, table_width), jnp.int32),
        "window": jnp.asarray(window, jnp.int32),
    }
    if kv_dtype == "int8":
        cache["k"] = jnp.zeros(shape, jnp.int8)
        cache["v"] = jnp.zeros(shape, jnp.int8)
        cache["ks"] = jnp.zeros(shape[:-1], jnp.float32)
        cache["vs"] = jnp.zeros(shape[:-1], jnp.float32)
    else:
        assert kv_dtype == "fp", f"unknown kv_dtype {kv_dtype!r}"
        cache["k"] = jnp.zeros(shape, cfg.dtype)
        cache["v"] = jnp.zeros(shape, cfg.dtype)
    return cache


def reset_slot(cache: dict, slot) -> dict:
    """Recycle one slot of a per-slot cache: zero its position. Stale k/v
    rows need no clearing — the decode validity mask derives entirely from
    ``pos``, so a reset slot attends to nothing until rewritten."""
    assert cache["pos"].ndim == 1, "reset_slot requires a per-slot cache"
    return {**cache, "pos": cache["pos"].at[slot].set(0)}


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: dict,
    tokens: jax.Array,
    *,
    ffn: FFNHooks = DENSE_FFN,
    window: int = 0,
) -> tuple[dict, jax.Array]:
    """One token for every sequence. tokens (B, 1) → (cache', logits (B, Vp)).

    Works over both cache layouts: per-row contiguous rings (``init_decode_
    cache``) and the shared paged pool (``init_paged_cache`` — detected by
    the ``table`` key; each layer's pool is scanned jointly with its params
    while the one page table is closed over). An int8 pool (``ks`` key)
    scans its per-layer scale planes alongside the pages."""
    x = embed_tokens(params["embed"], tokens)
    pos = cache["pos"]
    table = cache.get("table")
    quant = "ks" in cache

    def body(h, sl):
        if quant:
            lp, ck, cv, cks, cvs = sl
        else:
            lp, ck, cv = sl
        a = rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        if table is not None:
            layer_cache = {"k": ck, "v": cv, "pos": pos, "table": table}
            if quant:
                layer_cache["ks"], layer_cache["vs"] = cks, cvs
            a, newc = attn.decode_attend_paged(
                lp["attn"], a, layer_cache, cfg, window=window,
            )
        else:
            a, newc = attn.decode_attend(
                lp["attn"], a, {"k": ck, "v": cv, "pos": pos}, cfg, window=window
            )
        h = h + a
        f = rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        f, _ = ffn.apply(lp["ffn"], f, cfg)
        out = (newc["k"], newc["v"])
        if quant:
            out += (newc["ks"], newc["vs"])
        return h + f, out

    xs = (params["layers"], cache["k"], cache["v"])
    if quant:
        xs += (cache["ks"], cache["vs"])
    x, news = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg)[:, 0]
    new_cache = {
        "k": news[0], "v": news[1], "pos": pos + 1, "window": cache["window"],
    }
    if quant:
        new_cache["ks"], new_cache["vs"] = news[2], news[3]
    if table is not None:
        new_cache["table"] = table
    return new_cache, logits


# ------------------------------------------------------------------- prefill
def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    *,
    ffn: FFNHooks = DENSE_FFN,
    window: int = 0,
    cache_window: int = 0,
) -> tuple[dict, jax.Array]:
    """Process a full prompt, build the decode cache, return last-pos logits."""
    b, s = tokens.shape
    q_chunk = default_q_chunk(s)
    x = embed_tokens(params["embed"], tokens)
    pos = positions_for(tokens)
    # cache_window > s allocates headroom for decode continuation;
    # cache_window < s is a sliding-window ring smaller than the prompt.
    cap = cache_window if cache_window > 0 else s

    def body(h, lp):
        a = rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        k, v = attn.compute_kv_for_prefill(lp["attn"], a, pos, cfg)
        a = attn.attend_full(
            lp["attn"], a, pos, cfg, causal=True, window=window, q_chunk=q_chunk
        )
        h = h + a
        f = rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        f, _ = ffn.apply(lp["ffn"], f, cfg)
        layer_cache = attn.fill_cache(
            {
                "k": jnp.zeros((b, cap, cfg.n_kv_heads, cfg.resolved_head_dim), cfg.dtype),
                "v": jnp.zeros((b, cap, cfg.n_kv_heads, cfg.resolved_head_dim), cfg.dtype),
                "pos": jnp.zeros((), jnp.int32),
            },
            k,
            v,
        )
        return h + f, (layer_cache["k"], layer_cache["v"])

    x, (ck, cv) = scan_layers(body, x, params["layers"], remat=cfg.remat)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    cache = {
        "k": ck,
        "v": cv,
        "pos": jnp.asarray(s, jnp.int32),
        "window": jnp.asarray(cache_window, jnp.int32),
    }
    return cache, logits


def prefill_into_slot(
    cfg: ModelConfig,
    params: Params,
    cache: dict,
    tokens: jax.Array,
    slot: jax.Array,
    *,
    ffn: FFNHooks = DENSE_FFN,
    window: int = 0,
) -> tuple[dict, jax.Array]:
    """Chunked prefill of ONE request into row ``slot`` of a shared per-slot
    decode cache (continuous batching: other slots keep their live state).

    tokens: (1, S) — the request's prompt. The full prompt runs through one
    q-chunked ``attend_full`` forward (compute-efficient prefill), and the
    resulting rotated k/v are written into the slot's ring rows; positions
    restart at 0 for the slot. Returns (cache', last-position logits (1, Vp)).
    """
    assert cache["pos"].ndim == 1, "prefill_into_slot requires a per-slot cache"
    b1, s = tokens.shape
    assert b1 == 1, "prefill_into_slot admits one request at a time"
    q_chunk = default_q_chunk(s)
    x = embed_tokens(params["embed"], tokens)
    pos = positions_for(tokens)
    slot = jnp.asarray(slot, jnp.int32)

    def body(h, sl):
        lp, ck, cv = sl  # ck/cv: (B, C, Hkv, hd) — one layer, all slots
        a = rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        k, v = attn.compute_kv_for_prefill(lp["attn"], a, pos, cfg)
        a = attn.attend_full(
            lp["attn"], a, pos, cfg, causal=True, window=window, q_chunk=q_chunk
        )
        h = h + a
        f = rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        f, _ = ffn.apply(lp["ffn"], f, cfg)
        # ring-write the prompt kv into this slot's row only
        row = attn.fill_cache(
            {
                "k": jax.lax.dynamic_slice_in_dim(ck, slot, 1, axis=0),
                "v": jax.lax.dynamic_slice_in_dim(cv, slot, 1, axis=0),
                "pos": jnp.zeros((), jnp.int32),
            },
            k,
            v,
        )
        nk = jax.lax.dynamic_update_slice_in_dim(ck, row["k"], slot, axis=0)
        nv = jax.lax.dynamic_update_slice_in_dim(cv, row["v"], slot, axis=0)
        return h + f, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    new_cache = {
        "k": nk,
        "v": nv,
        "pos": cache["pos"].at[slot].set(s),
        "window": cache["window"],
    }
    return new_cache, logits


def prefill_slots(
    cfg: ModelConfig,
    params: Params,
    cache: dict,
    tokens: jax.Array,
    lengths: jax.Array,
    slots: jax.Array,
    *,
    starts: jax.Array | None = None,
    prefix_pages: int | None = None,
    ffn: FFNHooks = DENSE_FFN,
    window: int = 0,
    return_all_logits: bool = False,
) -> tuple[dict, jax.Array]:
    """Batched chunked prefill: N newly admitted requests in ONE forward.

    tokens: (n, S) prompts right-padded to the batch max; lengths: (n,) true
    prompt lengths; slots: (n,) DISTINCT rows of the shared per-slot decode
    cache. Causal masking makes tail padding invisible to valid positions,
    so each row's activations equal its solo ``prefill_into_slot`` run; row
    r's rotated k/v land in its slot's ring rows (per-row wrap-around via
    ``fill_cache_rows``) and its logits come from position lengths[r]-1.
    Returns (cache', last-valid-position logits (n, Vp)).

    A row with ``lengths[r] == 0`` is a shape-bucket PADDING row (engine
    width bucketing): it writes nothing — ``fill_cache_rows`` writes no ring
    entries and the pos update keeps the slot's previous value — so its
    ``slots[r]`` may name any slot not otherwise in this call, even a live
    one. Its logits row is garbage; callers discard it.

    Paged caches (``table`` key present) route each row's ring write
    through its page table: the row's pages are gathered into contiguous
    ring rows, written exactly as the contiguous path would, and scattered
    back — the engine guarantees every logical page the prompt reaches is
    allocated before this call, and unallocated tail entries point at the
    scratch page 0 so their (never-read) writes stay harmless. A padding
    row's scatter writes back its own gathered bits unchanged.

    SUFFIX MODE (``starts`` not None; paged, windowless only): row r's
    tokens are the UNCACHED SUFFIX of its prompt, occupying absolute
    positions ``starts[r] .. starts[r]+lengths[r]-1`` over a page table
    whose first ``ceil(starts[r]/page)`` entries already hold the shared
    prefix KV (mapped in by the engine's prefix index). Queries run at
    their absolute positions; attention spans the gathered prefix pages
    PLUS the suffix's own k/v (prefix lanes beyond each row's start are
    pushed to an unreachable position, so causal masking kills them); ring
    writes land from ``starts[r]`` via ``fill_cache_rows``. A row with
    ``starts[r] == 0`` is an ordinary cold prefill and produces the same
    tokens as the ``starts=None`` path. ``starts=None`` itself traces the
    pre-existing math unchanged, so non-sharing engines stay bitwise
    identical.

    ``prefix_pages`` statically bounds how many leading table pages the
    suffix attend streams (the engine passes a pow2-bucketed
    ``ceil(max(starts)/page)`` so compile counts stay gated); it must cover
    every row's live prefix. ``None`` streams the full table width —
    bitwise the pre-bounding behavior. When ``attn.USE_SUFFIX_KERNEL`` is
    set, the suffix attend runs the Pallas kernel
    (kernels/flash_suffix_prefill.py), reading the prefix straight through
    the page table with no HBM gather; the jnp gather-concat path below
    stays as its oracle.

    ``return_all_logits=True`` (static) returns logits at EVERY padded
    position, (n, S, Vp), instead of only each row's last valid one —
    the k-token verify of speculative decoding reads a target logit per
    draft position out of one suffix dispatch. Padding positions (at or
    beyond ``lengths[r]``) are garbage; callers slice by true length.
    The cache write is bit-for-bit the ``False`` trace. On int8 pools this
    mode attends the round's own k/v through a quantize/dequantize
    roundtrip — per-token decode writes quant(k) then reads the pool, so
    bitwise-identical verification must see in-round tokens the same way.
    """
    assert cache["pos"].ndim == 1, "prefill_slots requires a per-slot cache"
    n, s = tokens.shape
    q_chunk = default_q_chunk(s)
    x = embed_tokens(params["embed"], tokens)
    slots = jnp.asarray(slots, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    table = cache.get("table")
    quant = "ks" in cache
    assert not quant or table is not None, "int8 KV requires a paged cache"
    if table is not None:
        t_rows = table[slots]                      # (n, T) page map per row
        flat_pages = t_rows.reshape(-1)            # (n·T,)
        page = cache["k"].shape[2]
        t_w = table.shape[1]
    if starts is None:
        pos = positions_for(tokens)
    else:
        assert table is not None, "suffix prefill requires a paged cache"
        assert window == 0, "suffix prefill is windowless (no ring wrap)"
        starts = jnp.asarray(starts, jnp.int32)
        pos = starts[:, None] + positions_for(tokens)
        # static bound on the prefix pages the attend streams; None keeps
        # the full table width (bitwise the pre-bounding trace)
        w_pfx = t_w if prefix_pages is None else max(1, min(prefix_pages, t_w))
        # global position held by ring slot c is c (windowless, no wrap);
        # lanes at/after each row's start hold no prefix yet — banish them
        # beyond any real query position so the causal mask excludes them
        ring_c = jnp.arange(w_pfx * page)[None, :]
        prefix_pos = jnp.where(ring_c < starts[:, None], ring_c, attn.FAR_POS)
    if quant:
        from repro.kernels.quantize import kv_dequant, kv_quant

        # ring slots this prefill writes (exactly fill_cache_rows' ``written``
        # mask): requantization is restricted to them so untouched slots —
        # shared prefix pages above all — keep their original (q, scale)
        # BITWISE. Requantizing a dequantized row can drift the scale one
        # ulp (fp double-rounding of (s·127)/127), which would silently
        # fork pages other rows still read.
        cap_r = t_w * page
        ring = jnp.arange(cap_r)[None, :]
        c_rel = ring if starts is None else (ring - starts[:, None]) % cap_r
        written = c_rel <= (lengths[:, None] - 1)   # (n, cap)

    def body(h, sl):
        if quant:
            lp, ck, cv, cks, cvs = sl
        else:
            lp, ck, cv = sl  # one layer — (B, C, Hkv, hd) or (P, page, Hkv, hd)
        a = rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        k, v = attn.compute_kv_for_prefill(lp["attn"], a, pos, cfg)
        k_att, v_att = k, v
        if quant and return_all_logits:
            from repro.kernels.quantize import kv_dequant as _dq
            from repro.kernels.quantize import kv_quant as _qz

            # speculative verify must reproduce per-token DECODE numerics:
            # decode writes quant(k) and attends the dequantized pool, so
            # tokens of the same round see each other (and themselves)
            # through the int8 roundtrip. Attend the roundtripped view;
            # the cache write below still quantizes the original.
            k_att = _dq(*_qz(k), k.dtype)
            v_att = _dq(*_qz(v), v.dtype)
        if quant:
            # gather the int8 pages + scales once; the fp view feeds the
            # attend and the ring write, the raw (q, scale) pair survives
            # untouched slots
            hkv, hd = ck.shape[-2], ck.shape[-1]
            gkq = ck[flat_pages].reshape(n, t_w * page, hkv, hd)
            gvq = cv[flat_pages].reshape(n, t_w * page, hkv, hd)
            gks = cks[flat_pages].reshape(n, t_w * page, hkv)
            gvs = cvs[flat_pages].reshape(n, t_w * page, hkv)
            gk = kv_dequant(gkq, gks, k.dtype)
            gv = kv_dequant(gvq, gvs, k.dtype)
        if starts is None:
            a = attn.attend_full(
                lp["attn"], a, pos, cfg, causal=True, window=window,
                q_chunk=q_chunk,
            )
        elif attn.USE_SUFFIX_KERNEL:
            # Pallas suffix kernel: the prefix is read straight through the
            # page table (scalar prefetch), no HBM gather, no (w·page+S)
            # score tensor. q is projected/roped here exactly as
            # attend_full would.
            from repro.kernels.ops import suffix_prefill_attention

            hd = cfg.resolved_head_dim
            g = cfg.n_heads // cfg.n_kv_heads
            q = (a @ lp["attn"]["wq"]).reshape(n, s, cfg.n_heads, hd)
            q = attn.apply_rope(q, pos, cfg.rope_theta)
            o = suffix_prefill_attention(
                q.reshape(n, s, cfg.n_kv_heads, g, hd), k_att, v_att, ck, cv,
                t_rows, starts, prefix_width=w_pfx,
                pool_k_scale=cks if quant else None,
                pool_v_scale=cvs if quant else None,
                use_kernel=True,
            )
            a = sharding.gather_heads(
                o.reshape(n, s, -1).astype(a.dtype)
            ) @ lp["attn"]["wo"]
        else:
            # gather the prefix pages once and attend over [prefix | suffix]
            # — the displaced production path, kept as the kernel's oracle.
            # Only the first w_pfx pages enter the attend (bounded score
            # tensor); dead lanes past each row's start are FAR-banished.
            # (int8 pools arrive here pre-gathered and dequantized.)
            if not quant:
                hkv, hd = ck.shape[-2], ck.shape[-1]
                gk = ck[flat_pages].reshape(n, t_w * page, hkv, hd)
                gv = cv[flat_pages].reshape(n, t_w * page, hkv, hd)
            a = attn.attend_full(
                lp["attn"], a, pos, cfg, causal=True, window=window,
                q_chunk=q_chunk,
                kv=(
                    jnp.concatenate([gk[:, : w_pfx * page], k_att], axis=1),
                    jnp.concatenate([gv[:, : w_pfx * page], v_att], axis=1),
                ),
                kv_positions=jnp.concatenate(
                    [prefix_pos, pos], axis=1
                ),
            )
        h = h + a
        f = rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        f, _ = ffn.apply(lp["ffn"], f, cfg)
        if table is not None:
            hkv, hd = ck.shape[-2], ck.shape[-1]
            if not quant and (starts is None or attn.USE_SUFFIX_KERNEL):
                # the ring WRITE always works over full-width gathered rows
                # (fill_cache_rows may land the suffix on any page); the
                # kernel branch above skipped the gather for the attend
                gk = ck[flat_pages].reshape(n, t_w * page, hkv, hd)
                gv = cv[flat_pages].reshape(n, t_w * page, hkv, hd)
            rows_k, rows_v = attn.fill_cache_rows(
                gk, gv, k, v, lengths, starts=starts
            )
            if quant:
                # masked requant: only ``written`` ring slots take fresh
                # (q, scale); everything else scatters back its ORIGINAL
                # int8 bits — shared prefix pages stay bitwise identical
                rq_k, rs_k = kv_quant(rows_k)
                rq_v, rs_v = kv_quant(rows_v)
                w4 = written[:, :, None, None]
                w3 = written[:, :, None]
                nk = ck.at[flat_pages].set(
                    jnp.where(w4, rq_k, gkq).reshape(n * t_w, page, hkv, hd))
                nv = cv.at[flat_pages].set(
                    jnp.where(w4, rq_v, gvq).reshape(n * t_w, page, hkv, hd))
                nks = cks.at[flat_pages].set(
                    jnp.where(w3, rs_k, gks).reshape(n * t_w, page, hkv))
                nvs = cvs.at[flat_pages].set(
                    jnp.where(w3, rs_v, gvs).reshape(n * t_w, page, hkv))
                return h + f, (nk, nv, nks, nvs)
            nk = ck.at[flat_pages].set(rows_k.reshape(n * t_w, page, hkv, hd))
            nv = cv.at[flat_pages].set(rows_v.reshape(n * t_w, page, hkv, hd))
            return h + f, (nk, nv)
        rows_k, rows_v = attn.fill_cache_rows(ck[slots], cv[slots], k, v, lengths)
        return h + f, (ck.at[slots].set(rows_k), cv.at[slots].set(rows_v))

    xs = (params["layers"], cache["k"], cache["v"])
    if quant:
        xs += (cache["ks"], cache["vs"])
    x, news = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    if return_all_logits:
        logits = lm_logits(params["embed"], x, cfg)       # (n, S, Vp)
    else:
        last = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1
        )
        logits = lm_logits(params["embed"], last, cfg)[:, 0]
    end = lengths if starts is None else starts + lengths
    new_cache = {
        "k": news[0],
        "v": news[1],
        # padding rows (length 0) must not touch their slot's position
        "pos": cache["pos"].at[slots].set(
            jnp.where(lengths > 0, end, cache["pos"][slots])
        ),
        "window": cache["window"],
    }
    if quant:
        new_cache["ks"], new_cache["vs"] = news[2], news[3]
    if table is not None:
        new_cache["table"] = table
    return new_cache, logits
