"""Shared neural-net layers: norms, RoPE, MLPs, initializers.

Pure-JAX, pure-functional: parameters are nested dicts of arrays, every layer
is ``apply(params, x) -> y``. Layer compute runs in the model dtype (bf16 by
default) with fp32 internals where numerics demand it (norm statistics,
softmax, recurrence gates).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------- initializers
def he_init(key, shape, dtype, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = (2.0 / max(fan, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def lecun_init(key, shape, dtype, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = (1.0 / max(fan, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}  # stored as (1 + scale)


def init_layer_norm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ----------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies, fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, hd/2)
    angles = angles[..., :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- MLPs
def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("silu", "gelu"):  # gated: SwiGLU / GeGLU
        return {
            "w_gate": he_init(k1, (d_model, d_ff), dtype),
            "w_up": he_init(k2, (d_model, d_ff), dtype),
            "w_down": he_init(k3, (d_ff, d_model), dtype, fan_in=d_ff),
        }
    # plain 2-matrix MLP (whisper)
    return {
        "w_up": he_init(k1, (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": he_init(k2, (d_ff, d_model), dtype, fan_in=d_ff),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def apply_mlp(params: Params, x: jax.Array, act: str) -> jax.Array:
    if "w_gate" in params:
        gate = x @ params["w_gate"]
        up = x @ params["w_up"]
        act_fn = jax.nn.silu if act == "silu" else jax.nn.gelu
        return (act_fn(gate) * up) @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_up"] + params["b_up"])
    return h @ params["w_down"] + params["b_down"]


def unembed_logits(x: jax.Array, w: jax.Array) -> jax.Array:
    """Final projection in fp32 for stable softmax/loss."""
    return (x.astype(jnp.float32) @ w.astype(jnp.float32))


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
):
    """Token-mean cross entropy + top-1 accuracy. logits fp32 (B,S,V)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    acc = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom, jnp.sum(acc * mask) / denom
