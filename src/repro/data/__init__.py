from repro.data.pipeline import SyntheticCorpus, batch_iterator
from repro.data.federated_data import dirichlet_mixtures, federated_batch

__all__ = [
    "SyntheticCorpus",
    "batch_iterator",
    "dirichlet_mixtures",
    "federated_batch",
]
