"""Deterministic synthetic LM corpus with domain structure.

WikiText-103 is unavailable offline, so the paper's §4 experiments run on a
synthetic corpus engineered to have the property the paper's experiment
actually needs: *learnable structure with controllable cross-cloud skew*.

Each domain d is a noisy affine automaton over the vocabulary:

    t_{k+1} = (a_d · t_k + c_d) mod V     with prob 1−ε
    t_{k+1} ~ Uniform(V)                  with prob ε

A model that learns the per-domain transition achieves next-token accuracy
→ (1−ε); mixing coefficients over domains generate exactly the "uneven data
distribution" regime of the paper's Table 3. Everything is jittable and
seeded — batches are pure functions of (seed, step).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp

_PRIMES = jnp.asarray(
    [3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59], jnp.int32
)


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    vocab_size: int
    n_domains: int = 8
    noise: float = 0.1

    def domain_params(self) -> tuple[jax.Array, jax.Array]:
        d = jnp.arange(self.n_domains)
        a = _PRIMES[d % len(_PRIMES)]
        c = (7 * d + 1) % self.vocab_size
        return a, c

    def sample(
        self, key: jax.Array, domain_mix: jax.Array, batch: int, seq: int
    ) -> dict:
        """domain_mix: (n_domains,) simplex. Returns {"tokens","labels","domain"}."""
        ka, kb, kc, kd = jax.random.split(key, 4)
        dom = jax.random.choice(ka, self.n_domains, (batch,), p=domain_mix)
        a_all, c_all = self.domain_params()
        a, c = a_all[dom], c_all[dom]  # (B,)
        t0 = jax.random.randint(kb, (batch,), 0, self.vocab_size)
        noise_mask = jax.random.bernoulli(kc, self.noise, (batch, seq))
        noise_tok = jax.random.randint(kd, (batch, seq), 0, self.vocab_size)

        def step(t, inputs):
            nm, nt = inputs
            nxt = (a * t + c) % self.vocab_size
            nxt = jnp.where(nm, nt, nxt)
            return nxt, nxt

        _, toks = jax.lax.scan(
            step, t0, (noise_mask.T, noise_tok.T)
        )
        toks = jnp.concatenate([t0[None], toks], axis=0).T  # (B, seq+1)
        return {
            "tokens": toks[:, :-1].astype(jnp.int32),
            "labels": toks[:, 1:].astype(jnp.int32),
            "domain": dom.astype(jnp.int32),
        }

    def oracle_accuracy(self) -> float:
        """Best achievable next-token accuracy (predict the affine map)."""
        return (1.0 - self.noise) + self.noise / self.vocab_size


def batch_iterator(
    corpus: SyntheticCorpus,
    seed: int,
    domain_mix: jax.Array,
    batch: int,
    seq: int,
) -> Iterator[dict]:
    """Infinite deterministic batch stream."""
    step = 0
    sample = jax.jit(
        lambda k: corpus.sample(k, domain_mix, batch, seq)
    )
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        yield sample(key)
        step += 1
