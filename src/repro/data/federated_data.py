"""Cross-cloud data distribution: non-IID splits + per-cloud batch streams.

The paper's §3.1 partitions one corpus across clouds. The canonical non-IID
control is a Dirichlet(β) mixture over domains per cloud (β→∞ = IID,
β→0 = each cloud sees one domain). ``federated_batch`` materializes one
synchronized global step: a (n_clouds, per_cloud_batch, seq) batch stack,
which the federated trainer shards over the `pod` mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.pipeline import SyntheticCorpus


def dirichlet_mixtures(
    key: jax.Array, n_clouds: int, n_domains: int, beta: float
) -> jax.Array:
    """(n_clouds, n_domains) rows on the simplex; β controls skew."""
    if beta <= 0:
        # degenerate: cloud i sees only domain i (mod n_domains)
        eye = jnp.eye(n_domains)
        return eye[jnp.arange(n_clouds) % n_domains]
    return jax.random.dirichlet(key, jnp.full((n_domains,), beta), (n_clouds,))


def federated_batch(
    corpus: SyntheticCorpus,
    key: jax.Array,
    mixtures: jax.Array,
    per_cloud_batch: int,
    seq: int,
) -> dict:
    """One global step of data: leaves shaped (n_clouds, B, ...)."""
    n_clouds = mixtures.shape[0]
    keys = jax.random.split(key, n_clouds)
    batches = [
        corpus.sample(keys[i], mixtures[i], per_cloud_batch, seq)
        for i in range(n_clouds)
    ]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)


def cloud_sample_counts(
    key: jax.Array, n_clouds: int, skew: float = 0.0, base: int = 10_000
) -> jnp.ndarray:
    """n_i of formula 1. skew=0 → uniform; skew>0 → lognormal size spread."""
    if skew <= 0:
        return jnp.full((n_clouds,), base, jnp.int32)
    sizes = base * jnp.exp(skew * jax.random.normal(key, (n_clouds,)))
    return jnp.maximum(sizes.astype(jnp.int32), 1)
