"""Production meshes + parameter/state/input sharding rules.

Mesh axes:
    pod   — the cross-cloud boundary (federated replicas; slow DCN links)
    data  — intra-cloud data parallelism (+ FSDP/ZeRO param sharding)
    model — intra-cloud tensor/expert parallelism

Parameter sharding is rule-based on leaf path names (MaxText-style): every
architecture uses the same names for analogous weights (wq/wk/wv/wo,
w_gate/w_up/w_down, tok/unembed, router, ...), so one rule table covers all
10 archs. Rules only assign an axis when it divides the dimension; otherwise
the dim stays replicated (e.g. kv heads < model-axis size under GQA)."""
from __future__ import annotations

import functools
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Pytree = Any


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sim_mesh(n_clouds: int = 1) -> Mesh:
    """CPU simulation mesh: pod axis only (requires host device override)."""
    n = len(jax.devices())
    assert n >= n_clouds, f"need {n_clouds} devices, have {n}"
    return jax.make_mesh((n_clouds,), ("pod",))


def axis_size(mesh, name: str) -> int:
    """Works for both concrete Mesh and AbstractMesh."""
    shape = dict(mesh.shape)
    return int(shape.get(name, 1))


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = int(np.prod([axis_size(mesh, a) for a in axis]))
    else:
        size = axis_size(mesh, axis)
    return size > 1 and dim % size == 0


# --------------------------------------------------------------- param rules
# (regex on the leaf path, rule) — first match wins. The rule maps
# dimension-role → axis; `_spec_for` instantiates it against the leaf shape.
#   "last"/-1 etc. index dims from the END so stacked layer/period/cloud
#   leading dims never shift the rule.
_PARAM_RULES: list[tuple[str, dict[int, str]]] = [
    # embeddings: vocab over model (megatron vocab-parallel)
    (r"embed/tok$", {-2: "model", -1: "fsdp"}),
    (r"embed/unembed$", {-1: "model", -2: "fsdp"}),
    (r"router$", {-1: None}),
    # attention: output-feature dim over model (column parallel), input dim
    # of the out-projection over model (row parallel)
    (r"(attn|xattn)/(wq|wk|wv)$", {-1: "model", -2: "fsdp"}),
    (r"(attn|xattn)/wo$", {-2: "model", -1: "fsdp"}),
    # gated MLPs (dense, griffin, whisper-plain): column/row parallel
    (r"(ffn|mlp)/(w_gate|w_up)$", {-1: "model", -2: "fsdp"}),
    (r"(ffn|mlp)/w_down$", {-2: "model", -1: "fsdp"}),
    # griffin local-attention blocks keep attention weights under mix/
    (r"mix/(wq|wk|wv)$", {-1: "model", -2: "fsdp"}),
    (r"mix/wo$", {-2: "model", -1: "fsdp"}),
    # griffin recurrent block
    (r"mix/(w_x|w_y)$", {-1: "model", -2: "fsdp"}),
    (r"mix/w_out$", {-2: "model", -1: "fsdp"}),
    (r"mix/conv_w$", {-1: "model"}),
    (r"mix/(gate_r|gate_i)$", {}),          # block-diag per head: replicate
    # xLSTM blocks
    (r"blk/w_up$", {-1: "model", -2: "fsdp"}),
    (r"blk/(wq|wk|wv)$", {-1: "model", -2: "fsdp"}),
    (r"blk/(w_i|w_f)$", {-2: "fsdp"}),
    (r"blk/w_down$", {-2: "model", -1: "fsdp"}),
    (r"blk/ff_up$", {-1: "model", -2: "fsdp"}),
    (r"blk/ff_down$", {-2: "model", -1: "fsdp"}),
    (r"blk/conv_w$", {-1: "model"}),
    # vlm projector
    (r"projector/w$", {-1: "model"}),
]


def _leaf_path(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _apply_rule(rule: dict[int, str], shape: tuple, fsdp_axis, mesh: Mesh) -> P:
    axes: list = [None] * len(shape)
    for rel_dim, axis_name in rule.items():
        dim = len(shape) + rel_dim if rel_dim < 0 else rel_dim
        if dim < 0 or dim >= len(shape):
            continue
        axis = fsdp_axis if axis_name == "fsdp" else axis_name
        if axis is not None and _fits(shape[dim], mesh, axis):
            axes[dim] = axis
    return P(*axes)


def param_spec(path: str, shape: tuple, cfg: ModelConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (no pod dim — caller prepends)."""
    if cfg.pure_dp:
        return P()  # replicate everything; batch covers both axes
    fsdp_axis = "data" if cfg.fsdp else None
    if cfg.arch_type == "moe":
        # expert-parallel MoE weights: (L, E, D, F)/(L, E, F, D)
        if re.search(r"ffn/(w_gate|w_up)$", path):
            return _apply_rule({-3: "model", -1: "fsdp"}, shape, fsdp_axis, mesh)
        if re.search(r"ffn/w_down$", path):
            return _apply_rule({-3: "model", -2: "fsdp"}, shape, fsdp_axis, mesh)
    for pat, rule in _PARAM_RULES:
        if re.search(pat, path):
            return _apply_rule(rule, shape, fsdp_axis, mesh)
    return P()  # norms, biases, scalars: replicated


def params_pspec_tree(params_shapes: Pytree, cfg: ModelConfig, mesh: Mesh, prefix: tuple = ()) -> Pytree:
    """Pytree of PartitionSpecs matching ``params_shapes``."""

    def spec_fn(path, leaf):
        return P(*prefix, *param_spec(_leaf_path(path), leaf.shape, cfg, mesh))

    return jax.tree_util.tree_map_with_path(spec_fn, params_shapes)


def shardings_from_pspecs(pspecs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)


# ------------------------------------------------------------ non-param state
def opt_pspec_tree(opt_shapes: Pytree, param_pspecs: Pytree, mesh: Mesh) -> Pytree:
    """AdamW m/v inherit the parameter sharding (ZeRO: fsdp covers them)."""
    return {
        "m": param_pspecs,
        "v": param_pspecs,
        "count": P(),
    }


def batch_pspec(
    batch_shapes: Pytree, mesh: Mesh, *, pod_stacked: bool = False,
    pure_dp: bool = False,
) -> Pytree:
    """tokens/labels (B, S) → P(batch_axes, None); embeds get the same B rule.

    pure_dp: the model axis carries no tensor parallelism, so batch shards
    over (data, model) (or (pod, data, model) when serving multi-pod)."""
    dp = ("data", "model") if pure_dp else ("data",)
    b_axes: Any = (
        ("pod",) + dp if ("pod" in mesh.axis_names and not pod_stacked) else dp
    )
    b_axes = b_axes if len(b_axes) > 1 else b_axes[0]

    def spec_fn(path, leaf):
        dims: list = [None] * len(leaf.shape)
        if pod_stacked:
            dims[0] = "pod"
            if len(leaf.shape) > 1:
                if _fits(leaf.shape[1], mesh, dp):
                    dims[1] = dp if len(dp) > 1 else dp[0]
                elif _fits(leaf.shape[1], mesh, "data"):
                    dims[1] = "data"
        else:
            if _fits(leaf.shape[0], mesh, b_axes):
                dims[0] = b_axes
            elif _fits(leaf.shape[0], mesh, dp):
                dims[0] = dp if len(dp) > 1 else dp[0]
            elif _fits(leaf.shape[0], mesh, "data"):
                dims[0] = "data"
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec_fn, batch_shapes)


def cache_pspec(cache_shapes: Pytree, cfg: ModelConfig, mesh: Mesh, batch: int) -> Pytree:
    """Decode-cache sharding.

    Large-batch decode: shard batch over (pod,data). Batch-1 long-context:
    shard the cache-length dim over (pod,data) instead (context parallelism)
    — this is what makes a 500k-token cache fit."""
    pod = "pod" in mesh.axis_names
    dp: tuple = ("data", "model") if cfg.pure_dp else ("data",)
    b_axes = (("pod",) + dp) if pod else dp
    b_axes = b_axes if len(b_axes) > 1 else b_axes[0]
    seq_axes = b_axes  # used only when batch cannot shard

    batch_shardable = _fits(batch, mesh, b_axes) or _fits(batch, mesh, "data")
    b_axis = b_axes if _fits(batch, mesh, b_axes) else ("data" if _fits(batch, mesh, "data") else None)

    def spec_fn(path, leaf):
        p = _leaf_path(path)
        shape = leaf.shape
        dims: list = [None] * len(shape)
        if re.search(r"(^|/)(k|v|xk|xv)$", p) and len(shape) >= 4:
            # (L, B, C, Hkv, hd) or stacked periods (P, B, C, Hkv, hd)
            bdim, cdim, hdim = len(shape) - 4, len(shape) - 3, len(shape) - 2
            if batch_shardable:
                dims[bdim] = b_axis
                if _fits(shape[hdim], mesh, "model"):
                    dims[hdim] = "model"
            else:
                if _fits(shape[cdim], mesh, seq_axes):
                    dims[cdim] = seq_axes
                if _fits(shape[hdim], mesh, "model"):
                    dims[hdim] = "model"
            return P(*dims)
        # recurrent states: (..., B, W) / (B, H, dh, dh) / conv tails
        if len(shape) >= 2 and not re.search(r"(pos|window)$", p):
            bdim = None
            for d in range(len(shape)):
                if shape[d] == batch:
                    bdim = d
                    break
            if bdim is not None and batch_shardable:
                dims[bdim] = b_axis
            # shard the widest trailing dim over model if divisible
            last = len(shape) - 1
            if _fits(shape[last], mesh, "model") and shape[last] >= 128:
                dims[last] = "model"
            return P(*dims)
        return P()

    return jax.tree_util.tree_map_with_path(spec_fn, cache_shapes)


# ------------------------------------------------------------- serving (TP)
# The continuous-batching engine runs tensor-parallel over a 1-D ``model``
# mesh: attention heads split across shards, the paged KV pool holds each
# shard's kv-head slice of every page (pages are addressed (shard, page) —
# same page id on every shard, different head slice), and page tables stay
# host-side and shard-invariant. Everything outside attention (embeddings,
# norms, FFN, logits) is replicated: each shard redoes that math on identical
# inputs, which keeps the shard-local trace equal to the single-device trace
# on its head slice — the property the engine's token-identity tests pin.

_SERVE_COL = re.compile(r"(attn|xattn)/(wq|wk|wv)$")   # column-parallel


def make_serve_mesh(num_shards: int) -> Mesh:
    """1-D tensor-parallel serving mesh over the ``model`` axis."""
    devs = jax.devices()
    if num_shards < 1 or num_shards > len(devs):
        raise ValueError(
            f"serve mesh wants {num_shards} device(s), have {len(devs)}; on "
            "CPU set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "before the first jax call"
        )
    return Mesh(np.asarray(devs[:num_shards]), ("model",))


def serve_param_specs(params: Pytree) -> Pytree:
    """Attention-TP specs for serving: wq/wk/wv split their output-feature
    (head) dim over ``model``; every other leaf — including wo — replicates.
    wo stays replicated on purpose: the per-shard head slices all-gather
    back to the full pre-wo activation (``sharding.gather_heads``) and every
    shard runs the identical full out-projection, which keeps sharded
    serving bitwise token-identical to the single-device engine. The
    row-parallel wo + psum alternative rounds partial sums differently and
    flips near-tied argmaxes in bf16."""

    def spec(path, leaf):
        p = _leaf_path(path)
        nd = getattr(leaf, "ndim", 0)
        if _SERVE_COL.search(p) and nd >= 1:
            return P(*([None] * (nd - 1)), "model")
        return P()

    return jax.tree_util.tree_map_with_path(spec, params)


def serve_cache_specs(cache: Pytree) -> Pytree:
    """KV caches split the kv-head axis — dim -2 in both the paged pool
    (L, P, page, Hkv, hd) and ring (L, B, C, Hkv, hd) layouts — over
    ``model``; positions and page tables are shard-invariant (replicated).
    An int8 pool's scale planes (``ks``/``vs``: (L, P, page, Hkv)) carry
    the kv-head axis LAST, so they split dim -1 — each shard holds exactly
    the scales of its page slice."""

    def spec(path, leaf):
        name = _leaf_path(path)
        nd = getattr(leaf, "ndim", 0)
        if re.search(r"(^|/)(k|v)$", name) and nd >= 4:
            axes: list = [None] * nd
            axes[-2] = "model"
            return P(*axes)
        if re.search(r"(^|/)(ks|vs)$", name) and nd >= 4:
            axes = [None] * nd
            axes[-1] = "model"
            return P(*axes)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def serve_shardings(pspecs: Pytree, mesh: Mesh) -> Pytree:
    """NamedShardings for a pytree of PartitionSpecs (P is a tuple subclass,
    so plain tree_map would flatten it — pin it as a leaf)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------- constants
# TPU v5e per chip
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (intra-pod)
DCN_BW = 6.25e9              # bytes/s cross-pod (cross-cloud, 50 Gbit/s)
