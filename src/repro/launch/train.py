"""Training driver — runs real federated training (CPU-sized configs here;
the same code path lowers to the production mesh via dryrun.py).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --smoke --steps 200 --aggregation dynamic --clouds 3 --beta 0.2
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.core.scheduler import CloudSpec, events_to_round_masks, simulate_async_schedule
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model
from repro.utils.tree import tree_count_params


def run_training(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 100,
    seq_len: int = 64,
    per_cloud_batch: int = 8,
    n_clouds: int = 3,
    local_steps: int = 4,
    aggregation: str = "fedavg",
    compression: str = "none",
    topk_ratio: float = 0.01,
    dp_clip: float = 0.0,
    dp_noise: float = 0.0,
    beta: float = 0.3,
    lr: float = 1e-3,
    seed: int = 0,
    outer_optimizer: str = "none",
    log_every: int = 10,
    checkpoint_dir: str = "",
    n_domains: int = 8,
    log_fn=print,
) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    fed = FederatedConfig(
        n_clouds=n_clouds,
        local_steps=local_steps,
        aggregation=aggregation,
        compression=compression,
        topk_ratio=topk_ratio,
        dp_clip=dp_clip,
        dp_noise_mult=dp_noise,
        outer_optimizer=outer_optimizer,
    )
    tcfg = TrainConfig(
        seq_len=seq_len, global_batch=per_cloud_batch * n_clouds,
        steps=steps, lr=lr, warmup_steps=max(steps // 10, 1), seed=seed,
    )
    trainer = FederatedTrainer(model, fed, tcfg)
    state = trainer.init_state(jax.random.PRNGKey(seed))
    n_params = tree_count_params(state["global"]["params"])
    log_fn(f"arch={cfg.name} params={n_params:,} agg={aggregation} "
           f"H={local_steps} compression={compression}")

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=n_domains, noise=0.1)
    mixtures = dirichlet_mixtures(jax.random.PRNGKey(seed + 1), n_clouds, n_domains, beta)

    # async arrival schedule from heterogeneous cloud speeds
    clouds = [CloudSpec(f"cloud{i}", speed=1.0 + 0.5 * i) for i in range(n_clouds)]
    n_rounds = max(steps // max(local_steps, 1), 1)
    events = simulate_async_schedule(clouds, local_steps, n_rounds + 1,
                                     base_alpha=fed.async_alpha)
    arrived_rounds, alpha_rounds = events_to_round_masks(events, n_clouds, n_rounds + 1)

    step_fn = jax.jit(trainer.train_step)
    ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
    history = []
    t0 = time.time()
    for i in range(steps):
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 2), i)
        batch = federated_batch(corpus, key, mixtures, per_cloud_batch, seq_len)
        rnd = i // max(local_steps, 1)
        state, metrics = step_fn(
            state, batch,
            jnp.asarray(arrived_rounds[min(rnd, n_rounds)]),
            jnp.asarray(alpha_rounds[min(rnd, n_rounds)]),
        )
        if (i + 1) % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            acc = float(metrics["accuracy"])
            history.append({"step": i + 1, "loss": loss, "accuracy": acc})
            log_fn(f"step {i+1:5d}  loss {loss:.4f}  acc {acc:.4f}  "
                   f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if ckpt and (i + 1) % 100 == 0:
            ckpt.save(i + 1, state["global"]["params"])

    bytes_per_sync = trainer.sync_bytes_per_cloud(state["global"]["params"])
    total_syncs = steps * trainer.syncs_per_step()
    result = {
        "arch": cfg.name,
        "params": n_params,
        "aggregation": aggregation,
        "compression": compression,
        "final_loss": history[-1]["loss"] if history else None,
        "final_accuracy": history[-1]["accuracy"] if history else None,
        "history": history,
        "oracle_accuracy": corpus.oracle_accuracy(),
        "bytes_per_cloud_per_sync": bytes_per_sync,
        "total_comm_gb": bytes_per_sync * total_syncs * n_clouds / 1e9,
        "wall_seconds": time.time() - t0,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--clouds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--aggregation", default="fedavg",
                    choices=["fedavg", "dynamic", "gradient", "async"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "int8", "topk+int8"])
    ap.add_argument("--topk-ratio", type=float, default=0.01)
    ap.add_argument("--dp-clip", type=float, default=0.0)
    ap.add_argument("--dp-noise", type=float, default=0.0)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--outer", default="none", choices=["none", "sgd", "nesterov"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()

    result = run_training(
        args.arch, smoke=args.smoke, steps=args.steps, seq_len=args.seq_len,
        per_cloud_batch=args.batch, n_clouds=args.clouds,
        local_steps=args.local_steps, aggregation=args.aggregation,
        compression=args.compression, topk_ratio=args.topk_ratio,
        dp_clip=args.dp_clip, dp_noise=args.dp_noise, beta=args.beta,
        lr=args.lr, seed=args.seed, outer_optimizer=args.outer,
        checkpoint_dir=args.checkpoint_dir,
    )
    print(f"final: loss={result['final_loss']:.4f} acc={result['final_accuracy']:.4f} "
          f"(oracle acc {result['oracle_accuracy']:.3f}); "
          f"comm {result['total_comm_gb']:.3f} GB")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
