"""Step functions the launcher lowers: train / prefill / decode, single-pod
and multi-pod-federated variants. These are the exact computations the
dry-run compiles and the roofline reads."""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.models.model import ModelAPI
from repro.optim.adamw import adamw_update
from repro.utils.grad import microbatched_value_and_grad

Pytree = Any


def decode_window_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Sliding-window policy: long-context decode on attention archs uses the
    SWA ring buffer; 32k decode keeps the full cache; recurrent families keep
    their native O(1)/local-window state everywhere."""
    if cfg.arch_type in ("ssm", "hybrid"):
        return 0  # native recurrent state / local-attn ring (config-internal)
    if shape.seq_len > 32_768:
        return cfg.decode_window
    return 0


def make_train_step(
    model: ModelAPI, train_cfg: TrainConfig, microbatches: int = 1,
    grad_shardings=None,
) -> Callable:
    def train_step(params, opt, batch):
        (loss, metrics), grads = microbatched_value_and_grad(
            model.loss, params, batch, microbatches,
            grad_shardings=grad_shardings,
        )
        params, opt = adamw_update(train_cfg, grads, opt, params)
        return params, opt, metrics

    return train_step


def make_prefill_step(model: ModelAPI, shape: ShapeConfig) -> Callable:
    def prefill_step(params, batch):
        cache, logits = model.prefill(params, batch)
        return cache, logits

    return prefill_step


def make_decode_step(model: ModelAPI, window: int) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode(params, cache, tokens, window=window)

    return decode_step


def make_federated_step(
    model: ModelAPI,
    fed_cfg: FederatedConfig,
    train_cfg: TrainConfig,
    microbatches: int = 1,
    grad_shardings=None,
    mesh=None,
) -> tuple[FederatedTrainer, Callable]:
    """Multi-pod federated train step (spmd over the pod axis)."""
    trainer = FederatedTrainer(
        model, fed_cfg, train_cfg, spmd_axis="pod", microbatches=microbatches,
        grad_shardings=grad_shardings, mesh=mesh,
    )

    def fed_step(state, batch_stack):
        return trainer.train_step(state, batch_stack)

    return trainer, fed_step
