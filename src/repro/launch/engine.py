"""Continuous-batching serve engine: slot-based scheduler over a shared
per-slot ring-buffer KV cache.

The engine turns the single-batch serve path (launch/serve.py, kept as the
reference oracle) into iteration-level scheduling in the Orca/vLLM style,
sized for this repo's CPU-verifiable models:

* A fixed pool of ``num_slots`` KV-cache slots — the rows of ONE stacked
  (L, B, C, Hkv, hd) ring cache with per-slot positions
  (``models/attention.py``; ``models/transformer.py::init_decode_cache``
  with ``per_slot=True``). Admitting a request claims a free slot and
  resets its position; retiring a request frees the slot for immediate
  backfill. Stale k/v are never cleared — the decode validity mask derives
  entirely from ``pos``.
* Requests arrive at arbitrary times with arbitrary prompt/output lengths
  (mirroring how ``core/scheduler.py`` handles clouds completing at
  different wall times). A FIFO admission queue feeds free slots in
  arrival order.
* Prefill is either **chunked** (the whole prompt in one q-chunked
  ``attend_full`` forward written into the slot's ring rows —
  ``prefill_into_slot``) or **interleaved** (prompt tokens teacher-forced
  one per engine step through the SAME jitted decode step that serves the
  decoding slots, so a step can simultaneously prefill some slots and
  decode others). Both are token-identical to the sequential oracle.
* One jitted decode step per engine iteration advances every live slot by
  one token; sequences retire on EOS or max-new-tokens. The sliding-window
  ring cache (``window > 0``) and the Pallas flash-decode kernel
  (``use_kernel=True``, interpret mode on CPU) thread straight through.
* Hot-path perf, all default-on and output-invisible: admission rounds are
  padded to SHAPE BUCKETS (pow2 width × geometric length ladder) so
  ``prefill_slots`` compiles O(buckets) not O(distinct round shapes) — the
  ``compiles`` counters prove the bound; the KV cache is DONATED through
  every jitted step (no per-step full-cache copy); and with the kernel on,
  decode runs the PAGED variant (``kernels/paged_decode.py``) so each slot
  skips ring pages beyond its live span.

    PYTHONPATH=src python -m repro.launch.serve --continuous \
        --arch stablelm-1.6b --slots 4 --requests 8
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticCorpus
from repro.launch.sampling import SamplingParams, sample_token
from repro.models import attention, build_model
from repro.models.model import ModelAPI
from repro.models.transformer import reset_slot

PREFILL_MODES = ("chunked", "interleaved")

# Smallest padded prompt length the bucket ladder produces. Rounds pad up to
# the next power of two from here, so ``prefill_slots`` compiles at most
# O(log(max_prompt / LEN_BUCKET_MIN)) distinct lengths instead of one per
# distinct round maximum.
LEN_BUCKET_MIN = 8


def bucket_width(n: int, num_slots: int) -> int:
    """Round an admission-round width up to a power of two, capped at the
    slot-pool size — the extra rows are no-op padding rows (length 0)."""
    w = 1
    while w < n:
        w *= 2
    return min(w, num_slots)


def bucket_length(s: int, floor: int = LEN_BUCKET_MIN) -> int:
    """Round a padded prompt length up the geometric ladder
    floor, 2·floor, 4·floor, … — right-padding is invisible to the
    causally-masked prefill, and ring writes stop at each row's true
    length."""
    length = floor
    while length < s:
        length *= 2
    return length


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival_time`` is seconds relative to the
    engine clock; the engine never admits a request before it arrives.
    ``sampling=None`` (or temperature 0) decodes greedily."""
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    sampling: SamplingParams | None = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size > 0, "empty prompt"
        assert self.max_new_tokens > 0, "max_new_tokens must be positive"


@dataclasses.dataclass
class RequestOutput:
    uid: int
    prompt: list[int]
    tokens: list[int]             # generated ids (greedy or sampled), <= max_new
    slot: int                     # slot the request was served from
    finish_reason: str            # "eos" | "length"
    arrival_time: float
    admit_time: float
    first_token_time: float
    finish_time: float

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time

    @property
    def ttft(self) -> float:
        """Time to first token, from arrival (includes queueing)."""
        return self.first_token_time - self.arrival_time


@dataclasses.dataclass
class _Slot:
    """Host-side state of one live slot."""
    req: Request
    pending: collections.deque    # prompt tokens not yet fed (interleaved)
    generated: list[int]
    next_feed: int                # token the next decode step consumes
    admit_time: float
    first_token_time: float = -1.0
    key: jax.Array | None = None  # per-REQUEST sampling stream (None = greedy)


class ServeEngine:
    """Slot-based continuous-batching scheduler around one jitted decode step.

    Parameters
    ----------
    model, params : a ``ModelAPI`` with the slot-cache members (dense / MoE
        transformer family) and its initialized parameters.
    num_slots : size of the fixed KV-slot pool == decode batch width.
    max_seq : ring capacity per slot when ``window == 0``; every request
        must satisfy prompt_len + max_new_tokens <= max_seq.
    window : sliding-window span; > 0 shrinks the ring to the window.
    use_kernel : route decode attention through the Pallas flash-decode
        kernel (interpret mode on CPU).
    prefill : "chunked" (whole prompt in one forward at admission) or
        "interleaved" (teacher-force the prompt through the decode step,
        one token per engine iteration).
    batch_prefill : chunked mode only — prefill ALL slots admitted in one
        scheduling round through ONE ``prefill_slots`` forward (prompts
        right-padded to the round's max length) instead of one dispatch per
        request. Greedy output is token-identical either way; a burst of N
        arrivals costs 1 prefill dispatch instead of N.
    bucket_prefill : pad each batched admission round to a SHAPE BUCKET —
        width to the next power of two (capped at ``num_slots``, extra rows
        are length-0 no-op padding), padded prompt length to the geometric
        ladder ``LEN_BUCKET_MIN · 2^k`` — so ``prefill_slots`` compiles
        O(log num_slots · log max_prompt) times instead of once per distinct
        (round width, round max length). Token-identical to the unbucketed
        path; the ``compiles`` counters prove the bound.
    paged_decode : with ``use_kernel``, route decode attention through the
        length-aware paged kernel (``kernels/paged_decode.py``): each slot
        skips KV pages beyond its live span, so freshly admitted /
        short-prompt slots stop paying full-ring attention cost. Output is
        bitwise-identical to the unpaged kernel.
    donate_cache : donate the KV-cache pytree through the jitted decode and
        prefill steps (``jax.jit(..., donate_argnums=...)``) so XLA updates
        the ring buffers in place instead of copying the full cache through
        every step. The engine never re-reads a donated buffer: ``.cache``
        is rebound to the step's output before any other access.
    eos_id : optional token id that retires a sequence early.
    seed : engine-level sampling seed; requests without an explicit
        ``SamplingParams.seed`` draw from PRNGKey(seed) folded with their
        uid, so slot reuse never reuses a stream.
    time_fn : monotonic clock; injectable for deterministic tests.
    """

    def __init__(
        self,
        model: ModelAPI,
        params,
        *,
        num_slots: int = 4,
        max_seq: int = 128,
        window: int = 0,
        use_kernel: bool = False,
        prefill: str = "chunked",
        batch_prefill: bool = True,
        bucket_prefill: bool = True,
        paged_decode: bool = True,
        donate_cache: bool = True,
        eos_id: int | None = None,
        seed: int = 0,
        time_fn: Callable[[], float] | None = None,
    ):
        if model.init_slot_cache is None or model.prefill_slot is None:
            raise ValueError(
                f"arch {model.cfg.name!r} ({model.cfg.arch_type}) has no "
                "slot-cache API; the engine serves the transformer family"
            )
        if prefill not in PREFILL_MODES:
            raise ValueError(f"prefill {prefill!r} not in {PREFILL_MODES}")
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {max_seq}")
        self.cfg = model.cfg
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.window = window
        self.use_kernel = use_kernel
        self.prefill_mode = prefill
        self.batch_prefill = (
            batch_prefill and prefill == "chunked"
            and model.prefill_slots is not None
        )
        self.bucket_prefill = bucket_prefill and self.batch_prefill
        self.paged_decode = paged_decode
        self.donate_cache = donate_cache
        self.eos_id = eos_id
        self.seed = seed
        self._time_fn = time_fn or time.monotonic
        self._t0 = self._time_fn()

        self.cache = model.init_slot_cache(params, num_slots, max_seq, window=window)
        # Every hot-path jit donates the cache pytree (argument 1): the ring
        # buffers are updated in place instead of being functionally copied
        # through each step. Each wrapper body runs exactly once per input
        # shape signature — at trace time — so the trace counters below ARE
        # compile counters (``self.compiles``).
        self._compiles = {"decode": 0, "prefill": 0, "prefill_slots": 0}
        donate = (1,) if donate_cache else ()

        def _decode_fn(p, c, t):
            self._compiles["decode"] += 1
            return model.decode(p, c, t, window=window)

        def _prefill_fn(p, c, t, s):
            self._compiles["prefill"] += 1
            return model.prefill_slot(p, c, t, s, window=window)

        self._decode = jax.jit(_decode_fn, donate_argnums=donate)
        self._prefill = jax.jit(_prefill_fn, donate_argnums=donate)
        if model.prefill_slots is not None:
            def _prefill_slots_fn(p, c, t, l, s):
                self._compiles["prefill_slots"] += 1
                return model.prefill_slots(p, c, t, l, s, window=window)

            self._prefill_slots = jax.jit(_prefill_slots_fn, donate_argnums=donate)
        else:
            self._prefill_slots = None
        self._sample = jax.jit(
            lambda key, row, t, k, p: sample_token(
                key, row, t, k, p, model.cfg.vocab_size
            )
        )

        # batched per-step sampler: split each slot's stream and draw, one
        # dispatch + one host transfer for ALL sampled slots (mirrors the
        # batched-argmax discipline of the greedy path). Always called at
        # the full (num_slots, Vp) width — greedy/pending rows get dummy
        # keys and their draws are discarded — so it compiles exactly once
        # instead of once per live sampled-slot count.
        def _rows(keys, rows, t, k, p):
            def one(key, row, t1, k1, p1):
                nk, sub = jax.random.split(key)
                return nk, sample_token(sub, row, t1, k1, p1, model.cfg.vocab_size)

            return jax.vmap(one)(keys, rows, t, k, p)

        self._sample_rows = jax.jit(_rows)
        self._dummy_key = jax.random.PRNGKey(0)

        self.waiting: collections.deque[Request] = collections.deque()
        self.slots: list[_Slot | None] = [None] * num_slots
        self.finished: list[RequestOutput] = []
        self.steps = 0            # decode steps executed
        self.prefill_dispatches = 0   # chunked-prefill forwards launched
        self.slot_history: dict[int, list[int]] = {}  # uid -> slots used

    # ------------------------------------------------------------- plumbing
    def _now(self) -> float:
        return self._time_fn() - self._t0

    def reset_clock(self) -> None:
        """Restart the engine clock at 0 — call after warmup so request
        arrival times (relative to the clock) and latency metrics exclude
        jit compilation."""
        self._t0 = self._time_fn()

    def reset_metrics(self) -> None:
        """Drop warmup outputs and counters and restart the clock, so a
        subsequent trace measures steady state, not jit compilation."""
        self.finished.clear()
        self.slot_history.clear()
        self.steps = 0
        self.prefill_dispatches = 0
        self.reset_clock()

    def warm(self, prompt_lens, *, gen_tokens: int = 2,
             sampling: SamplingParams | None = None) -> None:
        """Compile every shape a trace can dispatch, then reset metrics.

        Batched admission specializes ``prefill_slots`` per (round width,
        padded prompt length) — and a mixed round pads to its max length,
        always one of ``prompt_lens`` — so warm each (width, length) pair;
        per-request / interleaved admission only ever sees width 1. With
        shape bucketing, many (width, length) pairs collapse onto one bucket
        shape, so only one representative per bucket is traced. Pass
        ``sampling`` when the trace will sample, so the (fixed-width)
        batched sampler compiles here too."""
        widths = range(1, self.num_slots + 1) if self.batch_prefill else [1]
        seen: set[tuple[int, int]] = set()
        for p in sorted(set(prompt_lens)):
            for w in widths:
                shape = (
                    (bucket_width(w, self.num_slots), bucket_length(p))
                    if self.bucket_prefill
                    else (w, p)
                )
                if shape in seen:
                    continue
                seen.add(shape)
                self.run([
                    Request(uid=-1 - j, prompt=np.zeros(p, np.int32),
                            max_new_tokens=max(gen_tokens, 1),
                            sampling=sampling)
                    for j in range(w)
                ])
        self.reset_metrics()

    @property
    def compiles(self) -> dict[str, int]:
        """Jit specializations per hot-path entry point since construction.
        NOT reset by ``reset_metrics`` — compiled code outlives a metrics
        window, and the whole point of shape bucketing is keeping these
        bounded as traffic diversity grows."""
        return dict(self._compiles)

    @property
    def prefill_compiles(self) -> int:
        """`prefill_slots`` + per-request prefill specializations — the
        number the recompile-guard test bounds by the bucket-ladder size."""
        return self._compiles["prefill_slots"] + self._compiles["prefill"]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    def next_arrival(self) -> float | None:
        """Earliest arrival among waiting requests, or None."""
        return min((r.arrival_time for r in self.waiting), default=None)

    def submit(self, req: Request) -> None:
        if self.window == 0 and len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt {len(req.prompt)} + gen "
                f"{req.max_new_tokens} exceeds max_seq {self.max_seq} "
                "(full-attention ring would overwrite live context)"
            )
        self.waiting.append(req)

    # ------------------------------------------------------------ scheduling
    def _greedy(self, logits_row) -> int:
        return int(jnp.argmax(logits_row[: self.cfg.vocab_size]))

    def _request_key(self, req: Request) -> jax.Array | None:
        """Per-REQUEST sampling stream. Keyed by the request (explicit seed,
        or engine seed + uid), never by the slot: backfilling a retired
        request's slot can't resume the previous occupant's stream."""
        sp = req.sampling
        if sp is None or sp.is_greedy:
            return None
        if sp.seed is not None:
            return jax.random.PRNGKey(sp.seed)
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), req.uid)

    def _next_token(self, slot: _Slot, logits_row) -> int:
        """First/next token for a slot from its row of logits (greedy or
        temperature/top-k/top-p sampling on the request's own stream)."""
        if slot.key is None:
            return self._greedy(logits_row)
        sp = slot.req.sampling
        slot.key, sub = jax.random.split(slot.key)
        return int(self._sample(sub, logits_row, sp.temperature, sp.top_k, sp.top_p))

    def _admit(self, now: float, respect_arrivals: bool) -> None:
        """Fill free slots from the queue in arrival order.

        Chunked mode prefills every request claimed in a round through ONE
        batched ``prefill_slots`` forward (or one dispatch each with
        ``batch_prefill=False``). A request that finishes on its very first
        token frees its slot immediately, so the round loop re-admits into
        it before the next decode step — same backfill behavior as the old
        one-at-a-time path."""
        while True:
            free = [i for i, s in enumerate(self.slots) if s is None]
            claimed: list[int] = []
            while free and self.waiting:
                req = self.waiting[0]
                if respect_arrivals and req.arrival_time > now:
                    break
                self.waiting.popleft()
                i = free.pop(0)
                self.cache = reset_slot(self.cache, i)
                slot = _Slot(
                    req=req,
                    pending=collections.deque(req.prompt.tolist()),
                    generated=[],
                    next_feed=-1,
                    admit_time=now,
                    key=self._request_key(req),
                )
                self.slot_history.setdefault(req.uid, []).append(i)
                self.slots[i] = slot
                if self.prefill_mode == "chunked":
                    claimed.append(i)
                else:  # interleaved: decode step consumes prompt tokens
                    slot.next_feed = slot.pending.popleft()
            if not claimed:
                return
            retired = self._prefill_claimed(claimed)
            if not retired:
                return  # no slot freed, nothing more to admit this round

    def _prefill_claimed(self, claimed: list[int]) -> bool:
        """Chunked-prefill the claimed slots; returns True if any retired.

        ``first_token_time`` is stamped per slot AFTER its token is
        extracted (``_next_token``'s host transfer forces the async jax
        dispatch), so TTFT includes the prefill compute it waited on."""
        retired = False

        def emit(i, row):
            nonlocal retired
            slot = self.slots[i]
            slot.pending.clear()
            g = self._next_token(slot, row)
            slot.first_token_time = self._now()
            slot.generated.append(g)
            slot.next_feed = g
            if self._done(slot, g):
                self._retire(i, slot)
                retired = True

        if self.batch_prefill:
            prompts = [self.slots[i].req.prompt for i in claimed]
            round_len = max(p.size for p in prompts)
            if self.bucket_prefill:
                width = bucket_width(len(claimed), self.num_slots)
                padded_len = bucket_length(round_len)
            else:
                width = len(claimed)
                padded_len = round_len
            tokens = np.zeros((width, padded_len), np.int32)
            lengths = np.zeros(width, np.int32)
            slot_ids = np.zeros(width, np.int32)
            for j, (i, p) in enumerate(zip(claimed, prompts)):
                tokens[j, : p.size] = p
                lengths[j] = p.size
                slot_ids[j] = i
            if width > len(claimed):
                # width-bucket padding rows: length 0 (prefill_slots writes
                # nothing for them), aimed at DISTINCT slots outside the
                # claimed set — width <= num_slots guarantees enough spares.
                spare = [i for i in range(self.num_slots) if i not in set(claimed)]
                slot_ids[len(claimed):] = spare[: width - len(claimed)]
            self.cache, logits = self._prefill_slots(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(lengths), jnp.asarray(slot_ids),
            )
            self.prefill_dispatches += 1
            for j, i in enumerate(claimed):
                emit(i, logits[j])
        else:
            for i in claimed:
                self.cache, lg = self._prefill(
                    self.params, self.cache,
                    jnp.asarray(self.slots[i].req.prompt[None, :]), i,
                )
                self.prefill_dispatches += 1
                emit(i, lg[0])
        return retired

    def _done(self, slot: _Slot, last: int) -> bool:
        if self.eos_id is not None and last == self.eos_id:
            return True
        return len(slot.generated) >= slot.req.max_new_tokens

    def _retire(self, i: int, slot: _Slot) -> None:
        reason = (
            "eos"
            if self.eos_id is not None and slot.generated[-1] == self.eos_id
            else "length"
        )
        self.finished.append(
            RequestOutput(
                uid=slot.req.uid,
                prompt=slot.req.prompt.tolist(),
                tokens=list(slot.generated),
                slot=i,
                finish_reason=reason,
                arrival_time=slot.req.arrival_time,
                admit_time=slot.admit_time,
                first_token_time=slot.first_token_time,
                finish_time=self._now(),
            )
        )
        self.slots[i] = None

    def step(self, *, respect_arrivals: bool = False) -> list[RequestOutput]:
        """One engine iteration: admit → one batched decode step → retire.

        Returns the requests that finished during this iteration. With
        ``respect_arrivals`` the admission gate compares each request's
        ``arrival_time`` against the engine clock; otherwise the queue
        drains in arrival order as slots free up (virtual time).
        """
        n_done = len(self.finished)
        attention.set_decode_kernel(self.use_kernel, paged=self.paged_decode)
        try:
            self._admit(self._now(), respect_arrivals)
            live = [i for i, s in enumerate(self.slots) if s is not None]
            if live:
                feed = np.zeros((self.num_slots, 1), np.int32)
                for i in live:
                    feed[i, 0] = self.slots[i].next_feed
                self.cache, logits = self._decode(
                    self.params, self.cache, jnp.asarray(feed)
                )
                self.steps += 1
                # one batched argmax + host transfer per step, not per slot
                # (skipped entirely when every emitting slot samples)
                need_greedy = any(
                    self.slots[i].key is None and not self.slots[i].pending
                    for i in live
                )
                greedy = (
                    np.asarray(jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1))
                    if need_greedy
                    else None
                )
                # sampled slots batch the same way: split every stream and
                # draw in ONE fixed-width dispatch (dummy rows for greedy /
                # mid-prefill slots), then one host transfer
                samp = [
                    i for i in live
                    if self.slots[i].key is not None and not self.slots[i].pending
                ]
                sampled: dict[int, int] = {}
                if samp:
                    keys, temps, ks, ps = [], [], [], []
                    for i in range(self.num_slots):
                        if i in samp:
                            sp = self.slots[i].req.sampling
                            keys.append(self.slots[i].key)
                            temps.append(sp.temperature)
                            ks.append(sp.top_k)
                            ps.append(sp.top_p)
                        else:
                            keys.append(self._dummy_key)
                            temps.append(1.0)
                            ks.append(1)
                            ps.append(1.0)
                    new_keys, toks = self._sample_rows(
                        jnp.stack(keys), logits,
                        jnp.asarray(temps, jnp.float32),
                        jnp.asarray(ks, jnp.int32),
                        jnp.asarray(ps, jnp.float32),
                    )
                    toks = np.asarray(toks)
                    for i in samp:
                        self.slots[i].key = new_keys[i]
                        sampled[i] = int(toks[i])
                now = self._now()
                for i in live:
                    slot = self.slots[i]
                    if slot.pending:  # mid-prefill: logits are teacher-forced
                        slot.next_feed = slot.pending.popleft()
                        continue
                    g = sampled[i] if slot.key is not None else int(greedy[i])
                    if slot.first_token_time < 0:
                        slot.first_token_time = now
                    slot.generated.append(g)
                    slot.next_feed = g
                    if self._done(slot, g):
                        self._retire(i, slot)  # freed; backfilled next admit
        finally:
            attention.set_decode_kernel(False)
        return self.finished[n_done:]

    def run(
        self, requests=(), *, realtime: bool = False
    ) -> list[RequestOutput]:
        """Drain ``requests`` (plus anything already queued) to completion.

        ``realtime=True`` honors arrival times against the wall clock,
        sleeping while all slots are idle and the next arrival is in the
        future — the benchmark's Poisson-trace mode. ``realtime=False``
        replays the queue in arrival order at full speed (deterministic)."""
        for req in sorted(requests, key=lambda r: r.arrival_time):
            self.submit(req)
        outs: list[RequestOutput] = []
        while self.has_work:
            if realtime and self.active_slots == 0:
                nxt = self.next_arrival()
                if nxt is not None:
                    delay = nxt - self._now()
                    if delay > 0:
                        time.sleep(delay)
            outs.extend(self.step(respect_arrivals=realtime))
        return sorted(outs, key=lambda o: o.uid)


# ----------------------------------------------------------------- helpers
def make_requests(
    cfg,
    *,
    n_requests: int,
    prompt_len: int,
    gen_tokens: int,
    seed: int = 0,
    stagger: float = 0.0,
) -> list[Request]:
    """Synthetic request trace with the serve oracle's prompt distribution:
    row r of the (n_requests, prompt_len) corpus sample is request r, so the
    uid-r output is directly comparable against ``serve_batch`` row r."""
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.0)
    prompts = corpus.sample(
        jax.random.PRNGKey(seed + 1), jnp.ones(4) / 4, n_requests, prompt_len
    )["tokens"]
    prompts = np.asarray(prompts, np.int32)
    return [
        Request(
            uid=r,
            prompt=prompts[r],
            max_new_tokens=gen_tokens,
            arrival_time=r * stagger,
        )
        for r in range(n_requests)
    ]


def serve_continuous(
    arch: str,
    *,
    smoke: bool = True,
    num_slots: int = 4,
    n_requests: int = 8,
    prompt_len: int = 32,
    gen_tokens: int = 32,
    window: int = 0,
    use_kernel: bool = False,
    prefill: str = "chunked",
    batch_prefill: bool = True,
    bucket_prefill: bool = True,
    paged_decode: bool = True,
    donate_cache: bool = True,
    sampling: SamplingParams | None = None,
    seed: int = 0,
    stagger: float = 0.0,
    log_fn=print,
) -> dict:
    """Build a model + engine, serve a synthetic trace, report throughput."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServeEngine(
        model,
        params,
        num_slots=num_slots,
        max_seq=prompt_len + gen_tokens,
        window=window,
        use_kernel=use_kernel,
        prefill=prefill,
        batch_prefill=batch_prefill,
        bucket_prefill=bucket_prefill,
        paged_decode=paged_decode,
        donate_cache=donate_cache,
        seed=seed,
    )
    reqs = make_requests(
        cfg, n_requests=n_requests, prompt_len=prompt_len,
        gen_tokens=gen_tokens, seed=seed, stagger=stagger,
    )
    if sampling is not None and not sampling.is_greedy:
        for r in reqs:
            # distinct stream per request even under a shared CLI seed
            r.sampling = dataclasses.replace(
                sampling,
                seed=None if sampling.seed is None else sampling.seed + r.uid,
            )
    # trace prefill + decode outside the measured window so the reported
    # throughput/latency are steady-state, not jit compilation
    engine.warm([prompt_len], gen_tokens=min(2, gen_tokens), sampling=sampling)
    t0 = time.time()
    outs = engine.run(reqs, realtime=stagger > 0)
    wall = time.time() - t0
    total = sum(len(o.tokens) for o in outs)
    lat = [o.latency for o in outs] or [0.0]
    result = {
        "arch": cfg.name,
        "num_slots": num_slots,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "gen_tokens": gen_tokens,
        "window": window,
        "use_kernel": use_kernel,
        "prefill": prefill,
        "batch_prefill": engine.batch_prefill,
        "bucket_prefill": engine.bucket_prefill,
        "paged_decode": engine.paged_decode,
        "donate_cache": engine.donate_cache,
        "sampling": None if sampling is None else dataclasses.asdict(sampling),
        "engine_steps": engine.steps,
        "prefill_dispatches": engine.prefill_dispatches,
        "compiles": engine.compiles,
        "wall_seconds": wall,
        "tokens_per_second": total / max(wall, 1e-9),
        "generated": [o.tokens for o in outs],
        "slots": [o.slot for o in outs],
        "latency_p50": float(np.percentile(lat, 50)),
        "latency_p95": float(np.percentile(lat, 95)),
    }
    log_fn(
        f"{cfg.name}: {n_requests} reqs × {gen_tokens} tok over "
        f"{num_slots} slots in {engine.steps} steps "
        f"+ {engine.prefill_dispatches} prefill dispatches, {wall:.2f}s "
        f"({result['tokens_per_second']:.1f} tok/s, "
        f"p50 {result['latency_p50']:.2f}s p95 {result['latency_p95']:.2f}s)"
    )
    return result
