"""Continuous-batching serve engine: slot-based scheduler over a shared
per-slot ring-buffer KV cache.

The engine turns the single-batch serve path (launch/serve.py, kept as the
reference oracle) into iteration-level scheduling in the Orca/vLLM style,
sized for this repo's CPU-verifiable models:

* A fixed pool of ``num_slots`` KV-cache slots — the rows of ONE stacked
  (L, B, C, Hkv, hd) ring cache with per-slot positions
  (``models/attention.py``; ``models/transformer.py::init_decode_cache``
  with ``per_slot=True``). Admitting a request claims a free slot and
  resets its position; retiring a request frees the slot for immediate
  backfill. Stale k/v are never cleared — the decode validity mask derives
  entirely from ``pos``.
* Requests arrive at arbitrary times with arbitrary prompt/output lengths
  (mirroring how ``core/scheduler.py`` handles clouds completing at
  different wall times). A FIFO admission queue feeds free slots in
  arrival order.
* Prefill is either **chunked** (the whole prompt in one q-chunked
  ``attend_full`` forward written into the slot's ring rows —
  ``prefill_into_slot``) or **interleaved** (prompt tokens teacher-forced
  one per engine step through the SAME jitted decode step that serves the
  decoding slots, so a step can simultaneously prefill some slots and
  decode others). Both are token-identical to the sequential oracle.
* One jitted decode step per engine iteration advances every live slot by
  one token; sequences retire on EOS or max-new-tokens. The sliding-window
  ring cache (``window > 0``) and the Pallas flash-decode kernel
  (``use_kernel=True``, interpret mode on CPU) thread straight through.
* Hot-path perf, all default-on and output-invisible: admission rounds are
  padded to SHAPE BUCKETS (pow2 width × geometric length ladder) so
  ``prefill_slots`` compiles O(buckets) not O(distinct round shapes) — the
  ``compiles`` counters prove the bound; the KV cache is DONATED through
  every jitted step (no per-step full-cache copy); and with the kernel on,
  decode runs the PAGED variant (``kernels/paged_decode.py``) so each slot
  skips ring pages beyond its live span.
* PAGED KV CACHE (``paged_cache=True``): instead of per-slot contiguous
  rings sized ``num_slots × max_seq``, ONE shared pool of fixed-size
  physical pages plus per-slot page tables (vLLM-PagedAttention layout).
  A host-side ``PagePool`` free-list allocator hands pages out at
  admission (enough for the prompt) and LAZILY one page per slot as
  decode crosses page boundaries; retirement frees them for immediate
  reuse. When the pool runs dry mid-decode the YOUNGEST slot is preempted
  back to the head of the waiting queue (its pages freed, its generated
  tokens carried in a resume record) and re-admitted later by re-prefilling
  prompt+generated — token-identical to an uninterrupted run. The
  ``prompt + gen ≤ max_seq`` admission guard disappears: a sequence is
  bounded by POOL pages (logical capacity = table_width × page_size), so
  one request may stretch across memory that ring mode would have
  statically split across all slots. The ring path stays as the oracle —
  paged output is pinned bitwise token-identical to it.
* PREFIX SHARING (``prefix_cache=True``, paged mode): pages carry
  REFCOUNTS, and a radix trie (``launch/prefix_cache.py``) indexes retired
  requests' full prompt pages by page-sized token chunks. A new request
  whose prompt starts with an indexed prefix maps those logical pages onto
  the SAME physical pages (``PagePool.share``) and prefills only the
  uncached suffix through ``prefill_slots(starts=...)`` — one prefill,
  many readers. A fully cached prompt re-prefills just its final token
  into a COPY-ON-WRITE split of the last shared page (the index copy
  stays immutable). Index entries are LRU-evicted under pool pressure —
  before watermark throttling and before OOM preemption — so a cache-hot
  pool degrades gracefully to the no-sharing engine. Output stays
  token-identical to the non-shared paged engine, which stays the oracle.

    PYTHONPATH=src python -m repro.launch.serve --continuous \
        --arch stablelm-1.6b --slots 4 --requests 8 --page-size 16
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import logging
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticCorpus
from repro.launch.mesh import (
    axis_size,
    make_serve_mesh,
    serve_cache_specs,
    serve_param_specs,
    serve_shardings,
)
from repro.launch.prefix_cache import PrefixCache
from repro.launch.sampling import (
    SamplingParams,
    sample_token,
    speculative_acceptance,
)
from repro.launch.spec_decode import make_draft_backend
from repro.models import attention, build_model
from repro.models.model import ModelAPI, localize_config
from repro.models.sharding import use_tensor_axis
from repro.models.transformer import reset_slot

PREFILL_MODES = ("chunked", "interleaved")

# Smallest padded prompt length the bucket ladder produces. Rounds pad up to
# the next power of two from here, so ``prefill_slots`` compiles at most
# O(log(max_prompt / LEN_BUCKET_MIN)) distinct lengths instead of one per
# distinct round maximum.
LEN_BUCKET_MIN = 8


def bucket_width(n: int, num_slots: int) -> int:
    """Round an admission-round width up to a power of two, capped at the
    slot-pool size — the extra rows are no-op padding rows (length 0)."""
    w = 1
    while w < n:
        w *= 2
    return min(w, num_slots)


def bucket_length(s: int, floor: int = LEN_BUCKET_MIN) -> int:
    """Round a padded prompt length up the geometric ladder
    floor, 2·floor, 4·floor, … — right-padding is invisible to the
    causally-masked prefill, and ring writes stop at each row's true
    length."""
    length = floor
    while length < s:
        length *= 2
    return length


def bucket_pages(pages: int, table_width: int) -> int:
    """Round a suffix round's max cached-prefix width (pages) up the pow2
    ladder 1, 2, 4, …, capped at the table width — the static
    ``prefix_pages`` bound handed to the suffix-prefill trace, so compile
    counts stay O(log table_width) across arbitrary start diversity. Rows
    whose prefix is shorter than the bucket attend dead lanes that the
    FAR-position mask (jnp path) / ``pl.when`` page skip (kernel) kill."""
    w = 1
    while w < pages:
        w *= 2
    return min(w, max(table_width, 1))


class AdmissionError(ValueError):
    """Structured submit-time rejection.

    Raised by ``ServeEngine.submit`` for requests the engine could NEVER
    serve (they exceed static capacity) — rejecting at submit keeps a
    doomed request out of the queue entirely, so a scheduling round can
    never wedge on it. ``uid`` and ``reason`` let callers map the failure
    back to the request without parsing the message; ``reason`` is one of
    ``"exceeds_max_seq"`` (ring mode) or ``"exceeds_pool"`` (paged mode).
    Subclasses ValueError so pre-existing callers' handlers keep working.
    """

    def __init__(self, uid: int, reason: str, message: str):
        super().__init__(message)
        self.uid = uid
        self.reason = reason


class PagePool:
    """Host-side refcounted free-list allocator over the shared physical KV
    page pool.

    Page 0 is the reserved SCRATCH page: it is never handed out, and every
    unallocated page-table entry points at it, so stray writes (retired
    slots whose ``pos`` keeps drifting inside the jitted decode step, tail
    entries of a prefill scatter) land somewhere harmless.

    Pages carry REFCOUNTS so one physical page can back several logical
    views (shared prompt prefixes, the prefix-cache index): ``alloc`` hands
    a page out at rc=1, ``share`` adds a reference to an already-live page,
    and ``free`` drops one reference — only a page whose count reaches 0
    returns to the free list. Sharing a free page and over-freeing a live
    one are both hard errors (rc-underflow / double-free guards), because
    either would let two owners scribble on one page.

    The free list is a LIFO stack: ``free`` pushes, ``alloc`` pops, so the
    MOST RECENTLY freed pages are reused first (they are the likeliest to
    still be resident in any cache hierarchy) — ``tests/test_page_pool.py``
    pins this order. A fresh pool allocates pages in ascending order
    1, 2, …, P-1.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page 0 is reserved), got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        # stack: pop() yields 1, 2, 3, … on a fresh pool
        self._free = list(range(num_pages - 1, 0, -1))
        self._rc: dict[int, int] = {}
        self.peak_in_use = 0

    @property
    def capacity(self) -> int:
        """Allocatable pages (the scratch page is not)."""
        return self.num_pages - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - self.available

    @property
    def live_refs(self) -> int:
        """Total outstanding references across all live pages (≥ in_use;
        the excess is the number of shared views)."""
        return sum(self._rc.values())

    def refcount(self, page: int) -> int:
        return self._rc.get(page, 0)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages at rc=1 each, or None (and no partial
        allocation) if the pool cannot cover the request."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def share(self, page: int) -> int:
        """Add a reference to a LIVE page (prefix sharing / index pin).
        Returns the new count. Sharing a free or foreign page is an error —
        a free page may be re-allocated and overwritten at any moment."""
        if self._rc.get(page, 0) < 1:
            raise ValueError(f"share of free/foreign page {page}")
        self._rc[page] += 1
        return self._rc[page]

    def free(self, pages) -> None:
        """Drop one reference per listed page; pages reaching rc=0 return
        to the free list (LIFO). Freeing a page with no live references is
        the double-free / rc-underflow guard firing."""
        for p in pages:
            rc = self._rc.get(p, 0)
            if rc < 1:
                raise ValueError(f"double/foreign free of page {p}")
            if rc == 1:
                del self._rc[p]
                self._free.append(p)
            else:
                self._rc[p] = rc - 1


class HostTier:
    """Host-RAM page store backing the device pool: the second tier of the
    KV cache hierarchy.

    Two kinds of entry share one LRU budget of ``capacity_pages``:

    * SWAP entries (key ``("swap", uid)``): every page of a preempted slot,
      gathered device→host before the pool reference drops. Re-admission
      restores them with one batched host→device scatter instead of
      recomputing the KV through a resume re-prefill.
    * PREFIX entries (key ``("prefix", token_tuple)``): a prefix-index page
      demoted at LRU eviction; a later radix match promotes it back into a
      freshly allocated pool page.

    Content is immutable once stored (pages are copied, never aliased), so
    a dropped entry is never a correctness event — the engine falls back to
    recompute (swap) or a cold prefill (prefix). Pure host-side numpy; all
    device traffic lives in the engine's gather/scatter jits."""

    def __init__(self, capacity_pages: int):
        if capacity_pages < 1:
            raise ValueError(
                f"host tier capacity must be >= 1 page, got {capacity_pages}"
            )
        self.capacity_pages = capacity_pages
        self._entries: collections.OrderedDict[tuple, dict] = (
            collections.OrderedDict()
        )
        self._pages = 0
        self.evictions = 0  # entries dropped by LRU pressure

    @property
    def pages(self) -> int:
        """Pages currently resident in the tier."""
        return self._pages

    def put(self, key: tuple, arrays: dict, n_pages: int) -> bool:
        """Store ``arrays`` (name → (L, n_pages, …) numpy) under ``key``,
        LRU-evicting older entries to fit. False (and no store) when the
        entry alone exceeds the tier."""
        if n_pages > self.capacity_pages:
            return False
        self.pop(key)
        while self._pages + n_pages > self.capacity_pages:
            _, old = self._entries.popitem(last=False)
            self._pages -= old["n"]
            self.evictions += 1
        self._entries[key] = {"arrays": arrays, "n": n_pages}
        self._pages += n_pages
        return True

    def get(self, key: tuple) -> dict | None:
        """Entry arrays for ``key`` (LRU touch), or None."""
        entry = self._entries.get(key)
        if entry is None:
            return None
        self._entries.move_to_end(key)
        return entry["arrays"]

    def n_pages(self, key: tuple) -> int:
        entry = self._entries.get(key)
        return 0 if entry is None else entry["n"]

    def pop(self, key: tuple) -> dict | None:
        entry = self._entries.pop(key, None)
        if entry is None:
            return None
        self._pages -= entry["n"]
        return entry["arrays"]

    def clear(self) -> None:
        self._entries.clear()
        self._pages = 0


@dataclasses.dataclass
class _ResumeState:
    """Generation state of a preempted request, carried across its trip
    back through the waiting queue. Re-admission prefills prompt +
    generated[:-1] in one chunked forward, restores these fields, and
    continues decoding exactly where the preempted slot stopped.

    ``host_key`` marks a SWAPPED preemption: the slot's KV pages were
    copied to the ``HostTier`` before its pool refs dropped, and
    re-admission restores them with a device scatter (no prefill at all) —
    bitwise the pages the slot held, so token-identity is trivial. A
    dropped tier entry (LRU) falls back to the recompute path above.
    ``pos`` is the slot's write position at preemption (tokens written =
    prompt + generated[:-1] for a decoding slot).

    ``host_arrays`` carries the page CONTENT itself (name → (L, n, …)
    numpy) when the record migrates BETWEEN engines (``export_inflight``):
    a host-tier key is meaningless outside the engine that owns the tier,
    but the copied pages are engine-independent — ``import_inflight``
    adopts layout-compatible arrays into the local tier so a migrated
    request swaps in instead of re-prefilling its whole history."""
    generated: list[int]
    key: jax.Array | None
    first_token_time: float
    admit_time: float
    host_key: tuple | None = None
    pos: int = 0
    host_arrays: dict | None = None


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival_time`` is seconds relative to the
    engine clock; the engine never admits a request before it arrives.
    ``sampling=None`` (or temperature 0) decodes greedily.

    ``priority`` orders preemption, not admission: when the paged pool
    runs dry the LOWEST-priority live slot is preempted first (ties break
    youngest-first, the pre-SLO behavior — priority 0 everywhere
    reproduces it exactly). Higher numbers are more important.
    ``deadline_s`` is an SLO relative to ``arrival_time``: a request still
    QUEUED past its deadline is shed with a structured
    ``AdmissionError("deadline_exceeded")`` record instead of being served
    uselessly late (and instead of wedging admission behind it). A request
    already decoding is never deadline-shed — its tokens are real work."""
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    sampling: SamplingParams | None = None
    priority: int = 0
    deadline_s: float | None = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size > 0, "empty prompt"
        assert self.max_new_tokens > 0, "max_new_tokens must be positive"


@dataclasses.dataclass
class RequestOutput:
    uid: int
    prompt: list[int]
    tokens: list[int]             # generated ids (greedy or sampled), <= max_new
    slot: int                     # slot the request was served from
    finish_reason: str            # "eos" | "length" | "timeout"
    arrival_time: float
    admit_time: float
    first_token_time: float
    finish_time: float

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time

    @property
    def ttft(self) -> float:
        """Time to first token, from arrival (includes queueing)."""
        return self.first_token_time - self.arrival_time


@dataclasses.dataclass
class _Slot:
    """Host-side state of one live slot."""
    req: Request
    pending: collections.deque    # prompt tokens not yet fed (interleaved)
    generated: list[int]
    next_feed: int                # token the next decode step consumes
    admit_time: float
    first_token_time: float = -1.0
    key: jax.Array | None = None  # per-REQUEST sampling stream (None = greedy)
    feed: np.ndarray | None = None  # tokens to prefill / teacher-force —
    #                                 the prompt, or prompt + generated[:-1]
    #                                 when resuming a preempted request
    prefix_len: int = 0           # leading feed tokens already resident in
    #                               shared prefix pages (chunked prefill
    #                               covers only feed[prefix_len:])
    resumed: bool = False         # suppress the next emission: the token is
    #                               already known (generated[-1])
    pos_host: int = 0             # host mirror of the slot's write position
    #                               (tokens written so far) — drives lazy
    #                               page allocation in paged mode
    seq: int = 0                  # admission sequence number (preemption
    #                               picks the YOUNGEST = max seq)


class ServeEngine:
    """Slot-based continuous-batching scheduler around one jitted decode step.

    Parameters
    ----------
    model, params : a ``ModelAPI`` with the slot-cache members (dense / MoE
        transformer family) and its initialized parameters.
    num_slots : size of the fixed KV-slot pool == decode batch width.
    max_seq : ring capacity per slot when ``window == 0``; every request
        must satisfy prompt_len + max_new_tokens <= max_seq.
    window : sliding-window span; > 0 shrinks the ring to the window.
    use_kernel : route decode attention through the Pallas flash-decode
        kernel (interpret mode on CPU).
    prefill : "chunked" (whole prompt in one forward at admission) or
        "interleaved" (teacher-force the prompt through the decode step,
        one token per engine iteration).
    batch_prefill : chunked mode only — prefill ALL slots admitted in one
        scheduling round through ONE ``prefill_slots`` forward (prompts
        right-padded to the round's max length) instead of one dispatch per
        request. Greedy output is token-identical either way; a burst of N
        arrivals costs 1 prefill dispatch instead of N.
    bucket_prefill : pad each batched admission round to a SHAPE BUCKET —
        width to the next power of two (capped at ``num_slots``, extra rows
        are length-0 no-op padding), padded prompt length to the geometric
        ladder ``LEN_BUCKET_MIN · 2^k`` — so ``prefill_slots`` compiles
        O(log num_slots · log max_prompt) times instead of once per distinct
        (round width, round max length). Token-identical to the unbucketed
        path; the ``compiles`` counters prove the bound.
    paged_decode : with ``use_kernel``, route decode attention through the
        length-aware paged kernel (``kernels/paged_decode.py``): each slot
        skips KV pages beyond its live span, so freshly admitted /
        short-prompt slots stop paying full-ring attention cost. Output is
        bitwise-identical to the unpaged kernel.
    donate_cache : donate the KV-cache pytree through the jitted decode and
        prefill steps (``jax.jit(..., donate_argnums=...)``) so XLA updates
        the ring buffers in place instead of copying the full cache through
        every step. The engine never re-reads a donated buffer: ``.cache``
        is rebound to the step's output before any other access.
    mesh : optional 1-D ``jax.sharding.Mesh`` with a ``model`` axis
        (``launch.mesh.make_serve_mesh``) — serve tensor-parallel. Attention
        heads split over the axis; the KV pool (paged or ring) splits its
        kv-head dim, so each shard holds its head slice of every physical
        page while page tables stay host-side and shard-invariant; every
        hot-path jit runs through ``shard_map``, each shard tracing the
        single-device math on its head slice (``localize_config``) with an
        all-gather of attention outputs before the replicated wo matmul.
        That combine keeps sharded serving BITWISE token-identical to
        ``mesh=None`` — which itself still traces the old single-device
        code unchanged, so the unsharded engine remains the oracle.
        Requires ``n_heads`` and ``n_kv_heads`` divisible by the axis size.
    paged_cache : replace the per-slot contiguous rings with ONE shared
        pool of physical pages + per-slot page tables. Decoupling logical
        sequence state from physical placement removes the
        ``prompt + gen <= max_seq`` admission guard (sequences are bounded
        by pool pages) and lets heterogeneous traffic share memory that
        ring mode statically splits ``num_slots`` ways. Token-identical to
        ring mode on any trace both can serve.
    page_size : tokens per physical page (paged mode). Small pages waste
        less memory on partial tails but make tables longer and decode DMA
        more scattered; large pages amortize indirection but strand up to
        ``page_size - 1`` dead token slots per sequence.
    num_pages : total physical pages INCLUDING the reserved scratch page 0.
        0 (default) sizes the pool to ring-equivalent capacity:
        ``num_slots * ceil(capacity / page_size) + 1``. Undersizing it
        oversubscribes memory — admission throttles on a watermark and
        decode OOM preempts the youngest slot.
    table_width : logical pages per slot (windowless paged mode). 0
        (default) bounds it to RING-EQUIVALENT width (``num_slots ×
        pages_per_ring``) so the jnp decode/prefill gather+attend work per
        step stays at ring scale even over an oversized pool; an explicit
        value (or ``long_requests``) widens it.
    long_requests : give every slot whole-pool logical width
        (``num_pages - 1`` table entries) — one request may stretch across
        every allocatable page, at ``num_slots×`` the per-step jnp gather
        cost the ring engine paid.
    watermark_pages : free pages admission must leave in reserve while any
        OTHER slot is live (paged mode) — headroom that lets running slots
        keep decoding without immediate preemption. Waived when nothing
        else is live, so progress is always possible.
    prefix_cache : index retired requests' full prompt pages in a radix
        trie (``launch/prefix_cache.py``) keyed by page-sized token
        chunks, and map common prompt prefixes of later requests onto the
        SAME physical pages — only the uncached suffix is prefilled. Pages
        are refcounted; the last shared page splits copy-on-write when a
        suffix or re-prefilled token would overwrite it; index entries are
        LRU-evicted under pool pressure (before watermark throttling and
        OOM preemption), so a cache-hot pool degrades to the no-sharing
        engine instead of thrashing. Output is token-identical to
        ``prefix_cache=False``. Requires chunked prefill and ``window ==
        0`` (silently off otherwise).
    prefix_cache_pages : cap on pages the prefix index may pin (0 = the
        pool's allocatable capacity).
    draft_model, draft_params, spec_tokens : speculative decoding. A
        second, cheap model (``spec_decode.make_draft_backend`` picks its
        state layout: small KV ring for transformer-family drafts,
        recurrent snapshots for ssm drafts like ``xlstm_125m``) proposes
        ``spec_tokens`` lookahead tokens per live slot per scheduling
        round; the TARGET model then scores ALL of them in ONE batched
        suffix-prefill dispatch (``prefill_slots(starts=..., return_all_
        logits=True)``) over the shared page pool instead of k sequential
        decode dispatches. Accepted tokens keep the KV pages the verify
        pass just wrote; the first rejection rolls back by pos truncation
        plus freeing the round's unreached fresh pages — a table edit, no
        recompute. Greedy requests emit BITWISE the tokens of the
        non-speculative engine (the per-token decode path stays as the
        oracle); sampled requests run Leviathan rejection sampling on
        their request-uid PRNG streams, preserving the target
        distribution (not bitwise-pinned). Requires paged_cache, chunked
        prefill, window == 0, no mesh, and matching draft/target vocab;
        all three arguments travel together.
    eos_id : optional token id that retires a sequence early.
    seed : engine-level sampling seed; requests without an explicit
        ``SamplingParams.seed`` draw from PRNGKey(seed) folded with their
        uid, so slot reuse never reuses a stream.
    max_wall_s : per-request wall-clock watchdog (0 = off). A live slot
        older than this (measured from its ORIGINAL admission — preemption
        round trips don't reset it) is retired with
        ``finish_reason="timeout"`` and whatever tokens it generated, so a
        request whose slot stops advancing (a stalled dispatch under fault
        injection, a runaway generation) can never wedge ``run()`` forever.
        Timed-out prompt pages are freed WITHOUT being published to the
        prefix index (a mid-prefill timeout may hold partially written
        pages).
    time_fn : monotonic clock; injectable for deterministic tests.
    """

    def __init__(
        self,
        model: ModelAPI,
        params,
        *,
        num_slots: int = 4,
        max_seq: int = 128,
        window: int = 0,
        use_kernel: bool = False,
        prefill: str = "chunked",
        batch_prefill: bool = True,
        bucket_prefill: bool = True,
        paged_decode: bool = True,
        donate_cache: bool = True,
        mesh: Mesh | None = None,
        paged_cache: bool = False,
        page_size: int = 16,
        num_pages: int = 0,
        table_width: int = 0,
        long_requests: bool = False,
        watermark_pages: int = 0,
        prefix_cache: bool = False,
        prefix_cache_pages: int = 0,
        kv_dtype: str = "fp",
        host_pages: int = 0,
        swap: bool = True,
        draft_model: ModelAPI | None = None,
        draft_params=None,
        spec_tokens: int = 0,
        eos_id: int | None = None,
        seed: int = 0,
        max_wall_s: float = 0.0,
        time_fn: Callable[[], float] | None = None,
    ):
        if model.init_slot_cache is None or model.prefill_slot is None:
            raise ValueError(
                f"arch {model.cfg.name!r} ({model.cfg.arch_type}) has no "
                "slot-cache API; the engine serves the transformer family"
            )
        if prefill not in PREFILL_MODES:
            raise ValueError(f"prefill {prefill!r} not in {PREFILL_MODES}")
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {max_seq}")
        self.cfg = model.cfg
        self.model = model
        self.params = params
        # Tensor-parallel serving: resolve the shard count and the PER-SHARD
        # model. Inside shard_map each shard sees 1/S of the heads, so the
        # shard-local trace is built from a localized config; a 1-shard mesh
        # still exercises the full shard_map plumbing (useful as the
        # any-machine identity probe) but keeps the global model.
        self.mesh = mesh
        self.num_shards = 1
        self._tp_axis: str | None = None
        serve_model = model
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError(
                    f"serving mesh needs a 'model' axis, got {mesh.axis_names}"
                )
            self.num_shards = axis_size(mesh, "model")
            self._tp_axis = "model"
            if self.num_shards > 1:
                serve_model = build_model(
                    localize_config(model.cfg, self.num_shards)
                )
        self._serve_model = serve_model
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.window = window
        self.use_kernel = use_kernel
        self.prefill_mode = prefill
        self.batch_prefill = (
            batch_prefill and prefill == "chunked"
            and model.prefill_slots is not None
        )
        self.bucket_prefill = bucket_prefill and self.batch_prefill
        self.paged_decode = paged_decode
        self.donate_cache = donate_cache
        self.eos_id = eos_id
        self.seed = seed
        self.max_wall_s = max_wall_s
        self._time_fn = time_fn or time.monotonic
        self._t0 = self._time_fn()

        self.paged_cache = paged_cache
        self.preemptions = 0
        self.occupancy: list[float] = []  # pool fill fraction per decode step
        # SLO bookkeeping (both cache modes): deadline-expired queued
        # requests are recorded here as structured AdmissionErrors instead
        # of being raised (shedding happens inside the scheduler, where
        # there is no caller to catch); watchdog retirements count below.
        self.shed: list[AdmissionError] = []
        self.shed_requests = 0
        self.timeouts = 0
        # preemption-resume records + admission sequence live in BOTH cache
        # modes: a router may migrate another engine's in-flight requests
        # into this one (``import_inflight``), and the re-prefill resume
        # path is layout-independent. Only paged mode CREATES records
        # itself (ring mode never preempts).
        self._resume: dict[int, _ResumeState] = {}
        self._admit_seq = 0
        if kv_dtype not in ("fp", "int8"):
            raise ValueError(f"kv_dtype must be 'fp' or 'int8', got {kv_dtype!r}")
        if kv_dtype == "int8" and not paged_cache:
            raise ValueError(
                "kv_dtype='int8' quantizes POOL pages; it requires "
                "paged_cache=True (the contiguous ring cache stays fp)"
            )
        if host_pages > 0 and not paged_cache:
            raise ValueError(
                "host_pages tiers the page pool; it requires paged_cache=True"
            )
        self.kv_dtype = kv_dtype
        if paged_cache:
            if model.init_paged_cache is None or model.prefill_slots is None:
                raise ValueError(
                    f"arch {model.cfg.name!r} has no paged-cache API; "
                    "use the contiguous ring engine"
                )
            cap_ring = window if (0 < window < max_seq) else max_seq
            pages_per_ring = -(-cap_ring // page_size)
            if num_pages <= 0:
                # ring-equivalent capacity: same total KV memory as the
                # contiguous engine, now shareable across slots
                num_pages = num_slots * pages_per_ring + 1
            self.page_size = page_size
            self.num_pages = num_pages
            # Logical ring capacity per slot: the window when sliding-window
            # attention bounds context anyway; else RING-EQUIVALENT width
            # (num_slots × pages_per_ring — the work the jnp decode/prefill
            # gathers scale with) by default, or the WHOLE allocatable pool
            # with ``long_requests`` / an explicit ``table_width`` so one
            # request may stretch across every page. Logical width may
            # exceed the PHYSICAL pool (a tight pool oversubscribes);
            # ``submit`` rejects what the physical pool can never hold.
            if 0 < window < max_seq:
                self.table_width = pages_per_ring
                if num_pages - 1 < self.table_width:
                    raise ValueError(
                        f"num_pages {num_pages} cannot back a table of "
                        f"{self.table_width} pages (window {window})"
                    )
            elif table_width > 0:
                self.table_width = table_width
            elif long_requests:
                self.table_width = num_pages - 1
            else:
                self.table_width = num_slots * pages_per_ring
            self.cap = self.table_width * page_size
            self.pool = PagePool(num_pages, page_size)
            self.watermark_pages = watermark_pages
            self._table_np = np.zeros((num_slots, self.table_width), np.int32)
            self._table_dirty = False
            self._slot_pages: list[list[int]] = [[] for _ in range(num_slots)]
            self.cache = model.init_paged_cache(
                params, num_slots, num_pages, page_size, self.table_width,
                window=window, kv_dtype=kv_dtype,
            )
            # Host tier: second level of the KV hierarchy. Gated off under a
            # mesh — the pool is sharded across devices there and the
            # host-side gather/scatter below assumes a single-device layout.
            self.swap_disabled_reason = None
            if host_pages > 0 and mesh is not None:
                self.swap_disabled_reason = (
                    "mesh serving (KV pool is sharded; host tier assumes a "
                    "single-device pool)"
                )
            self.host = (
                HostTier(host_pages)
                if host_pages > 0 and self.swap_disabled_reason is None
                else None
            )
            self.swap = swap and self.host is not None
            # Prefix sharing rides the page table: it needs chunked prefill
            # (suffix rounds) and a non-wrapping logical ring (windowless).
            # A requested-but-unsatisfiable config stays off, WITH a named
            # reason — logged once here and exposed via
            # pool_stats["prefix_cache_enabled"] — so "default-on" callers
            # (serve.py) can tell sharing from a silently degraded engine.
            self.prefix_disabled_reason = None
            if prefix_cache:
                if window > 0:
                    self.prefix_disabled_reason = (
                        f"window={window} (sliding-window ring wraps; "
                        "prefix pages would be overwritten)"
                    )
                elif prefill != "chunked":
                    self.prefix_disabled_reason = (
                        f"prefill={prefill!r} (suffix rounds need chunked "
                        "batched admission)"
                    )
            self.prefix = (
                PrefixCache(
                    self.pool, prefix_cache_pages,
                    demote_fn=self._demote_prefix_page if self.host else None,
                    promote_fn=self._promote_prefix_page if self.host else None,
                )
                if prefix_cache and self.prefix_disabled_reason is None
                else None
            )
        else:
            self.pool = None
            self.prefix = None
            self.host = None
            self.swap = False
            self.swap_disabled_reason = None
            self.prefix_disabled_reason = (
                "paged_cache=False (prefix sharing rides the page table)"
                if prefix_cache
                else None
            )
            self.cache = model.init_slot_cache(
                params, num_slots, max_seq, window=window
            )
        # Mesh serving: commit params + cache as sharded arrays. wq/wk/wv
        # split their head (output-feature) dim, KV pools split the kv-head
        # axis — each shard's slice of every page — and everything else
        # (incl. page tables / positions: host-mirrored, shard-invariant)
        # replicates. ``serve_param_specs`` documents why wo replicates.
        if mesh is not None:
            self._pspecs = serve_param_specs(params)
            self._cspecs = serve_cache_specs(self.cache)
            self.params = jax.device_put(
                params, serve_shardings(self._pspecs, mesh)
            )
            self.cache = jax.device_put(
                self.cache, serve_shardings(self._cspecs, mesh)
            )
        if self.prefix_disabled_reason is not None:
            logging.getLogger(__name__).warning(
                "prefix_cache requested but disabled: %s",
                self.prefix_disabled_reason,
            )
        self.prefix_cache = self.prefix is not None
        # prefix-sharing counters (reset by reset_metrics): hit/lookup
        # tokens drive the hit rate, prefill_tokens counts tokens actually
        # run through chunked prefill (the FLOPs the cache saves), and
        # cow_copies counts copy-on-write page splits.
        # prefix_resume_hit_tokens tracks preemption-resume re-admissions
        # separately: a resume replays a feed the engine itself published
        # (prompt + generated-so-far), so its near-total prefix hit says
        # nothing about cross-request sharing and must not inflate the
        # externally-reported prefix_hit_rate.
        self.prefix_hit_pages = 0
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self.prefix_resume_hit_tokens = 0
        self.prefill_tokens = 0
        self.cow_copies = 0
        self.swapped_out_pages = 0
        self.swapped_in_pages = 0
        self.host_demoted_pages = 0
        self.host_promote_hits = 0
        # Every hot-path jit donates the cache pytree (argument 1): the ring
        # buffers are updated in place instead of being functionally copied
        # through each step. Each wrapper body runs exactly once per input
        # shape signature — at trace time — so the trace counters below ARE
        # compile counters (``self.compiles``).
        self._compiles = {
            "decode": 0, "prefill": 0, "prefill_slots": 0,
            "prefill_suffix": 0,
        }
        donate = (1,) if donate_cache else ()
        tp_axis = self._tp_axis

        def _shard(fn, n_extra):
            """Wrap a hot-path fn ``(params, cache, *operands)`` in
            shard_map on the serving mesh: params/cache by their serve
            specs, every other operand replicated, (cache, logits) out.
            Replication of the logits is real, not asserted-away — each
            shard all-gathers the attention heads and runs the identical
            replicated tail (``check_rep=False`` only because the rep
            checker has no rule for the interpret-mode Pallas calls).
            ``mesh=None`` returns fn untouched, so the single-device trace
            stays bitwise the pre-mesh one."""
            if mesh is None:
                return fn
            rep = PartitionSpec()
            return shard_map(
                fn, mesh=mesh,
                in_specs=(self._pspecs, self._cspecs) + (rep,) * n_extra,
                out_specs=(self._cspecs, rep),
                check_rep=False,
            )

        def _decode_fn(p, c, t):
            self._compiles["decode"] += 1
            with use_tensor_axis(tp_axis):
                return serve_model.decode(p, c, t, window=window)

        def _prefill_fn(p, c, t, s):
            self._compiles["prefill"] += 1
            with use_tensor_axis(tp_axis):
                return serve_model.prefill_slot(p, c, t, s, window=window)

        self._decode = jax.jit(_shard(_decode_fn, 1), donate_argnums=donate)
        self._prefill = jax.jit(_shard(_prefill_fn, 2), donate_argnums=donate)
        if model.prefill_slots is not None:
            def _prefill_slots_fn(p, c, t, l, s):
                self._compiles["prefill_slots"] += 1
                with use_tensor_axis(tp_axis):
                    return serve_model.prefill_slots(
                        p, c, t, l, s, window=window
                    )

            self._prefill_slots = jax.jit(
                _shard(_prefill_slots_fn, 3), donate_argnums=donate
            )

            # suffix-prefill entry (prefix sharing): its own compile
            # counter (cold rounds must never touch it — tests pin that)
            # and its own shape axis, the static pow2-bucketed prefix-page
            # width, so the recompile gate bounds (width, length,
            # prefix_pages) triples
            def _prefill_suffix_fn(p, c, t, l, s, st, pw):
                self._compiles["prefill_suffix"] += 1
                with use_tensor_axis(tp_axis):
                    return serve_model.prefill_slots(
                        p, c, t, l, s, starts=st, prefix_pages=pw,
                        window=window,
                    )

            if mesh is None:
                self._prefill_suffix = jax.jit(
                    _prefill_suffix_fn, donate_argnums=donate,
                    static_argnums=(6,),
                )
            else:
                # bind the static prefix width BEFORE the shard_map wrap —
                # shard_map maps array operands only
                def _suffix_entry(p, c, t, l, s, st, pw):
                    return _shard(
                        functools.partial(_prefill_suffix_fn, pw=pw), 4
                    )(p, c, t, l, s, st)

                self._prefill_suffix = jax.jit(
                    _suffix_entry, donate_argnums=donate, static_argnums=(6,),
                )
        else:
            self._prefill_slots = None
            self._prefill_suffix = None

        # Pool arrays that carry page content (int8 mode adds the scale
        # planes) — the unit every page-granular copy/swap moves together.
        kv_names = tuple(
            n for n in ("k", "v", "ks", "vs")
            if paged_cache and n in self.cache
        )
        self._kv_names = kv_names

        # COW page split: copy one physical page (all layers, every pool
        # plane) inside the donated cache — in place under donation, one
        # compile total
        def _copy_page_fn(c, src, dst):
            out = dict(c)
            for n in kv_names:
                out[n] = c[n].at[:, dst].set(c[n][:, src])
            return out

        self._copy_page = jax.jit(
            _copy_page_fn, donate_argnums=(0,) if donate_cache else ()
        )

        # Host-tier traffic: batched page gather (device→host reads the
        # cache, NOT donated) and scatter (host→device rewrites pages in
        # the donated cache). Page-batch sizes are pow2-bucketed by the
        # callers (padding with scratch page 0) so compile counts stay
        # bounded like every other hot-path shape axis.
        def _gather_pages_fn(c, idx):
            return tuple(c[n][:, idx] for n in kv_names)

        def _scatter_pages_fn(c, idx, arrs):
            out = dict(c)
            for n, a in zip(kv_names, arrs):
                out[n] = c[n].at[:, idx].set(a)
            return out

        self._gather_pages_jit = jax.jit(_gather_pages_fn)
        self._scatter_pages_jit = jax.jit(
            _scatter_pages_fn, donate_argnums=(0,) if donate_cache else ()
        )
        self._sample = jax.jit(
            lambda key, row, t, k, p: sample_token(
                key, row, t, k, p, model.cfg.vocab_size
            )
        )

        # batched per-step sampler: split each slot's stream and draw, one
        # dispatch + one host transfer for ALL sampled slots (mirrors the
        # batched-argmax discipline of the greedy path). Always called at
        # the full (num_slots, Vp) width — greedy/pending rows get dummy
        # keys and their draws are discarded — so it compiles exactly once
        # instead of once per live sampled-slot count.
        def _rows(keys, rows, t, k, p):
            def one(key, row, t1, k1, p1):
                nk, sub = jax.random.split(key)
                return nk, sample_token(sub, row, t1, k1, p1, model.cfg.vocab_size)

            return jax.vmap(one)(keys, rows, t, k, p)

        self._sample_rows = jax.jit(_rows)
        self._dummy_key = jax.random.PRNGKey(0)

        # ---------------------------------------------- speculative decoding
        # counters exist in every mode (pool_stats/bench schema stability);
        # the machinery only when a draft is wired up
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.draft = None
        self.spec_tokens = 0
        if draft_model is not None or draft_params is not None or spec_tokens:
            blockers = []
            if draft_model is None or draft_params is None:
                blockers.append("draft_model and draft_params are required")
            if spec_tokens < 1:
                blockers.append("spec_tokens must be >= 1")
            if not paged_cache:
                blockers.append(
                    "paged_cache=False (rollback is a page-table edit)"
                )
            if prefill != "chunked":
                blockers.append(
                    f"prefill={prefill!r} (verification is a batched "
                    "suffix-prefill round)"
                )
            if window != 0:
                blockers.append(
                    f"window={window} (suffix prefill is windowless)"
                )
            if mesh is not None:
                blockers.append("mesh serving (single-device verify only)")
            if model.prefill_slots is None:
                blockers.append("target arch has no prefill_slots API")
            if (
                draft_model is not None
                and draft_model.cfg.vocab_size != model.cfg.vocab_size
            ):
                blockers.append(
                    f"draft vocab {draft_model.cfg.vocab_size} != target "
                    f"vocab {model.cfg.vocab_size}"
                )
            if blockers:
                raise ValueError(
                    "speculative decoding unavailable: " + "; ".join(blockers)
                )
            self.spec_tokens = spec_tokens
            self._compiles.update(
                {"spec_verify": 0, "draft_propose": 0, "draft_prefill": 0}
            )
            limit = min(self.cap, self.pool.capacity * self.page_size)
            self.draft = make_draft_backend(
                draft_model, draft_params, num_slots=num_slots, cap=limit,
                spec_tokens=spec_tokens, compiles=self._compiles,
                donate=donate_cache,
            )
            # host mirror of each draft row's consumed-token count; -1 =
            # diverged/dead, forcing a re-sync prefill before the next
            # propose (slot reuse can never alias the old occupant's state)
            self._draft_pos = np.full(num_slots, -1, np.int64)
            self._spec_dummy_keys = jnp.stack([self._dummy_key] * num_slots)

            # k-token verify: the suffix-prefill trace with logits at EVERY
            # position (the cache write is bit-for-bit the plain suffix
            # trace — tests pin greedy identity through this entry)
            def _spec_verify_fn(p, c, t, l, s, st, pw):
                self._compiles["spec_verify"] += 1
                return serve_model.prefill_slots(
                    p, c, t, l, s, starts=st, prefix_pages=pw,
                    window=window, return_all_logits=True,
                )

            self._spec_verify = jax.jit(
                _spec_verify_fn, donate_argnums=donate, static_argnums=(6,),
            )

            # masked pos correction: verify advances every dispatched row
            # to starts+lengths (= p + k + 1); acceptance truncates each to
            # its accepted span. One compile, reused every round.
            def _fix_pos_fn(c, pos_vec, mask):
                return {
                    **c, "pos": jnp.where(mask, pos_vec, c["pos"]),
                }

            self._fix_pos = jax.jit(
                _fix_pos_fn, donate_argnums=(0,) if donate_cache else ()
            )

            # batched per-row round-key split (mirrors _sample_rows'
            # fixed-width discipline: dummy rows for greedy slots)
            def _split_fn(keys):
                def one(k):
                    nk, sub = jax.random.split(k)
                    return nk, sub

                return jax.vmap(one)(keys)

            self._spec_split = jax.jit(_split_fn)

            # batched acceptance: one vmapped rejection-sampling dispatch
            # over the verify round's rows (greedy/padding rows ride along
            # with dummy keys; their outputs are discarded host-side).
            # fold_in(sub, 2) keeps the acceptance uniforms on a stream
            # disjoint from the draft's (sub, 1, t) proposal draws.
            kk = spec_tokens
            vocab = model.cfg.vocab_size

            def _accept_fn(keys, vlog, dtoks, dlogq, klive, temps, tks, tps):
                def one(key, tl, dt, dq, kl, t, k, p):
                    # verify rows are padded to the round's length bucket;
                    # clip-take exactly k+1 positions (rows past each row's
                    # own k_live are never read by the acceptance math)
                    tl = jnp.take(
                        tl,
                        jnp.minimum(jnp.arange(kk + 1), tl.shape[0] - 1),
                        axis=0,
                    )
                    return speculative_acceptance(
                        jax.random.fold_in(key, 2), tl, dt, dq, kl,
                        t, k, p, vocab,
                    )

                return jax.vmap(one)(
                    keys, vlog, dtoks, dlogq, klive, temps, tks, tps
                )

            self._spec_accept = jax.jit(_accept_fn)

        self.waiting: collections.deque[Request] = collections.deque()
        self.slots: list[_Slot | None] = [None] * num_slots
        self.finished: list[RequestOutput] = []
        self.steps = 0            # decode steps executed
        self.prefill_dispatches = 0   # chunked-prefill forwards launched
        # split-admission dispatch counters: every batched round is
        # partitioned into a COLD dispatch (starts == 0, the pre-existing
        # prefill_slots trace) and a HIT dispatch (suffix trace) so cold
        # rows never pay the prefix tax — these count each kind launched
        self.suffix_dispatches = 0
        self.cold_dispatches = 0
        self.slot_history: dict[int, list[int]] = {}  # uid -> slots used
        # bucket shapes already warmed, keyed by the full dispatch
        # configuration (see ``warm``) — persists across warm() calls
        self._warmed: set[tuple] = set()

    # ------------------------------------------------------------- plumbing
    def _now(self) -> float:
        return self._time_fn() - self._t0

    def reset_clock(self) -> None:
        """Restart the engine clock at 0 — call after warmup so request
        arrival times (relative to the clock) and latency metrics exclude
        jit compilation."""
        self._t0 = self._time_fn()

    def reset_metrics(self) -> None:
        """Drop warmup outputs and counters and restart the clock, so a
        subsequent trace measures steady state, not jit compilation."""
        self.finished.clear()
        self.slot_history.clear()
        self.steps = 0
        self.prefill_dispatches = 0
        self.preemptions = 0
        self.shed.clear()
        self.shed_requests = 0
        self.timeouts = 0
        self.occupancy = []
        self.prefix_hit_pages = 0
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self.prefix_resume_hit_tokens = 0
        self.prefill_tokens = 0
        self.cow_copies = 0
        self.swapped_out_pages = 0
        self.swapped_in_pages = 0
        self.host_demoted_pages = 0
        self.host_promote_hits = 0
        self.suffix_dispatches = 0
        self.cold_dispatches = 0
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        if self.paged_cache:
            self.pool.peak_in_use = self.pool.in_use
        if self.prefix is not None:
            self.prefix.reset_stats()
        self.reset_clock()

    def warm(self, prompt_lens, *, gen_tokens: int = 2,
             sampling: SamplingParams | None = None) -> None:
        """Compile every shape a trace can dispatch, then reset metrics.

        Batched admission specializes ``prefill_slots`` per (round width,
        padded prompt length) — and a mixed round pads to its max length,
        always one of ``prompt_lens`` — so warm each (width, length) pair;
        per-request / interleaved admission only ever sees width 1. With
        shape bucketing, many (width, length) pairs collapse onto one bucket
        shape, so only one representative per bucket is traced. Pass
        ``sampling`` when the trace will sample, so the (fixed-width)
        batched sampler compiles here too.

        Dedup is keyed by the full dispatch configuration — bucket shape
        plus the mesh shard count and whether prefix sharing is on (which
        decides if a warm run traces the cold path alone or cold + suffix
        rounds) — and PERSISTS across calls: re-warming an engine, or
        warming a sharded engine after construction-time probing, skips
        every shape already traced instead of re-running it (a sharded
        engine dispatches only its own shard-count configuration, never
        the single-device shapes)."""
        widths = range(1, self.num_slots + 1) if self.batch_prefill else [1]
        for p in sorted(set(prompt_lens)):
            for w in widths:
                shape = (
                    (bucket_width(w, self.num_slots), bucket_length(p))
                    if self.bucket_prefill
                    else (w, p)
                )
                key = (self.num_shards, self.prefix_cache, *shape)
                if key in self._warmed:
                    continue
                self._warmed.add(key)
                self.run([
                    Request(uid=-1 - j, prompt=np.zeros(p, np.int32),
                            max_new_tokens=max(gen_tokens, 1),
                            sampling=sampling)
                    for j in range(w)
                ])
        if self.prefix is not None:
            # warm traffic published its zero-token pages (deliberately —
            # repeated warm rounds hit them, tracing the suffix-prefill and
            # COW paths too); real traffic must start from an empty index
            self.prefix.clear()
        if self.host is not None:
            # warm preemptions/demotions may have parked synthetic pages on
            # the host tier; real traffic starts from an empty tier
            self.host.clear()
        self.reset_metrics()

    @property
    def compiles(self) -> dict[str, int]:
        """Jit specializations per hot-path entry point since construction.
        NOT reset by ``reset_metrics`` — compiled code outlives a metrics
        window, and the whole point of shape bucketing is keeping these
        bounded as traffic diversity grows."""
        return dict(self._compiles)

    @property
    def prefill_compiles(self) -> int:
        """``prefill_slots`` + suffix + per-request prefill specializations
        — the number the recompile-guard test bounds by the bucket-ladder
        size."""
        return (
            self._compiles["prefill_slots"]
            + self._compiles["prefill_suffix"]
            + self._compiles["prefill"]
        )

    @property
    def pool_stats(self) -> dict | None:
        """Paged-pool occupancy and preemption counters (None in ring
        mode). ``occupancy_*`` summarize the per-decode-step pool fill
        fraction since the last ``reset_metrics``."""
        if not self.paged_cache:
            return None
        occ = self.occupancy
        return {
            "shards": self.num_shards,
            "mesh_axes": (
                dict(self.mesh.shape) if self.mesh is not None else None
            ),
            # per-shard pool fill: page tables are shard-invariant — every
            # shard holds its kv-head slice of the same live pages — so
            # each shard's occupancy equals the pool's. Reported per shard
            # anyway: the equal entries ARE the invariant, and a future
            # per-shard allocator would show skew here.
            "occupancy": [
                self.pool.in_use / max(self.pool.capacity, 1)
            ] * self.num_shards,
            "page_size": self.page_size,
            "num_pages": self.num_pages,
            "allocatable_pages": self.pool.capacity,
            "pages_in_use": self.pool.in_use,
            "peak_pages_in_use": self.pool.peak_in_use,
            "preemptions": self.preemptions,
            "shed_requests": self.shed_requests,
            "timeouts": self.timeouts,
            "occupancy_mean": float(np.mean(occ)) if occ else 0.0,
            "occupancy_max": float(np.max(occ)) if occ else 0.0,
            "prefix_cache": self.prefix_cache,
            "prefix_cache_enabled": self.prefix_cache,
            "prefix_disabled_reason": self.prefix_disabled_reason,
            "prefix_hit_pages": self.prefix_hit_pages,
            # hit rate over FRESH lookups only — resume re-admissions
            # (prefix_resume_hit_tokens) replay engine-published tokens
            # and are excluded from both numerator and denominator
            "prefix_hit_rate": (
                self.prefix_hit_tokens / self.prefix_lookup_tokens
                if self.prefix_lookup_tokens
                else 0.0
            ),
            "prefix_resume_hit_tokens": self.prefix_resume_hit_tokens,
            "prefix_lookup_tokens": self.prefix_lookup_tokens,
            "prefill_tokens": self.prefill_tokens,
            "cow_copies": self.cow_copies,
            "suffix_dispatches": self.suffix_dispatches,
            "cold_dispatches": self.cold_dispatches,
            "prefix_pages_cached": (
                self.prefix.size if self.prefix is not None else 0
            ),
            "prefix_evicted_pages": (
                self.prefix.evicted_pages if self.prefix is not None else 0
            ),
            "kv_dtype": self.kv_dtype,
            "swap_enabled": self.swap,
            "swap_disabled_reason": self.swap_disabled_reason,
            "host_capacity_pages": (
                self.host.capacity_pages if self.host is not None else 0
            ),
            "host_tier_pages": (
                self.host.pages if self.host is not None else 0
            ),
            "swapped_out_pages": self.swapped_out_pages,
            "swapped_in_pages": self.swapped_in_pages,
            "host_demoted_pages": self.host_demoted_pages,
            "host_promote_hits": self.host_promote_hits,
            # speculative decoding: accept_rate is accepted DRAFTS over
            # proposed drafts (the bonus/rejection token is free either
            # way); dispatches_per_token is target decode dispatches per
            # emitted token — 1.0 for the non-spec engine, 1/(k+1) at full
            # acceptance
            "spec_enabled": self.draft is not None,
            "spec_tokens": self.spec_tokens,
            "spec_rounds": self.spec_rounds,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_emitted": self.spec_emitted,
            "spec_accept_rate": (
                self.spec_accepted / self.spec_drafted
                if self.spec_drafted
                else 0.0
            ),
            "spec_dispatches_per_token": (
                self.spec_rounds / self.spec_emitted
                if self.spec_emitted
                else 0.0
            ),
        }

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    def next_arrival(self) -> float | None:
        """Earliest arrival among waiting requests, or None."""
        return min((r.arrival_time for r in self.waiting), default=None)

    def prefix_probe(self, tokens) -> int:
        """Predicted cached-prefix TOKENS for a prompt: a READ-ONLY walk
        of the radix index — no LRU touch, no hit/lookup counting, no
        page refs taken. The trie's page-chunk keys make hit prediction
        O(prompt/page_size) dict lookups, so a router can score every
        replica's affinity for a prompt without prefilling anything (and
        without the probe itself perturbing eviction order or the honest
        ``prefix_hit_rate``). 0 when prefix sharing is off."""
        if self.prefix is None:
            return 0
        return self.prefix.probe(tokens) * self.page_size

    def capacity_shortfall(self, req: Request) -> int:
        """Tokens by which ``req`` exceeds this engine's STATIC capacity
        (0 = servable). Non-mutating — a router probes every replica with
        this before rejecting a request anywhere, so the best-fit shortfall
        it reports is the true system-wide one, not one pool's."""
        need = len(req.prompt) + req.max_new_tokens
        if self.window != 0:
            return 0  # the sliding-window ring wraps; any length fits
        if self.paged_cache:
            # Windowless sequences are bounded by BOTH limits: the logical
            # table (cap tokens) and the physical pool (allocatable pages —
            # a tight pool may be smaller than the table, and a request
            # whose pages can never all be resident would otherwise sit at
            # the queue head forever while alloc keeps returning None).
            limit = min(self.cap, self.pool.capacity * self.page_size)
            return max(0, need - limit)
        return max(0, need - self.max_seq)

    def submit(self, req: Request) -> None:
        """Enqueue a request, or reject it with a structured
        ``AdmissionError`` if the engine could NEVER serve it. Rejection
        happens HERE, not mid-``_admit``: a doomed request must not enter
        the queue, where it would wedge a scheduling round at the head of
        FIFO admission. A rejected submit leaves the engine fully usable."""
        short = self.capacity_shortfall(req)
        if short > 0:
            if self.paged_cache:
                raise AdmissionError(
                    req.uid, "exceeds_pool",
                    f"request {req.uid}: prompt {len(req.prompt)} + gen "
                    f"{req.max_new_tokens} exceeds pool capacity by {short} "
                    f"tokens "
                    f"({min(self.cap, self.pool.capacity * self.page_size)} "
                    f"tokens: table {self.table_width} pages × "
                    f"{self.page_size}, pool {self.pool.capacity} "
                    "allocatable pages)",
                )
            raise AdmissionError(
                req.uid, "exceeds_max_seq",
                f"request {req.uid}: prompt {len(req.prompt)} + gen "
                f"{req.max_new_tokens} exceeds max_seq {self.max_seq} by "
                f"{short} tokens "
                "(full-attention ring would overwrite live context)",
            )
        self.waiting.append(req)

    # ------------------------------------------------------------ scheduling
    def _shed_expired(self, now: float) -> None:
        """Shed QUEUED requests whose deadline has passed, recording a
        structured ``AdmissionError("deadline_exceeded")`` per shed instead
        of raising (shedding happens inside the scheduler — there is no
        submit caller to catch). Serving an already-expired request wastes
        slots and, worse, an unservable-but-expired head would sit in front
        of FIFO admission forever. Mid-stream requests (a preemption-resume
        record with generated tokens — the client has already received
        output) are exempt: their remaining tokens are real work."""
        if not any(r.deadline_s is not None for r in self.waiting):
            return
        kept: collections.deque[Request] = collections.deque()
        while self.waiting:
            req = self.waiting.popleft()
            resume = self._resume.get(req.uid)
            mid_stream = resume is not None and bool(resume.generated)
            if (
                req.deadline_s is not None
                and not mid_stream
                and now - req.arrival_time > req.deadline_s
            ):
                dropped = self._resume.pop(req.uid, None)
                if (
                    dropped is not None and dropped.host_key is not None
                    and self.host is not None
                ):
                    self.host.pop(dropped.host_key)
                self.shed.append(AdmissionError(
                    req.uid, "deadline_exceeded",
                    f"request {req.uid}: queued {now - req.arrival_time:.3f}s"
                    f" past arrival, deadline was {req.deadline_s:.3f}s; "
                    "shed unserved",
                ))
                self.shed_requests += 1
            else:
                kept.append(req)
        self.waiting = kept

    def _greedy(self, logits_row) -> int:
        return int(jnp.argmax(logits_row[: self.cfg.vocab_size]))

    def _request_key(self, req: Request) -> jax.Array | None:
        """Per-REQUEST sampling stream. Keyed by the request (explicit seed,
        or engine seed + uid), never by the slot: backfilling a retired
        request's slot can't resume the previous occupant's stream."""
        sp = req.sampling
        if sp is None or sp.is_greedy:
            return None
        if sp.seed is not None:
            return jax.random.PRNGKey(sp.seed)
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), req.uid)

    def _next_token(self, slot: _Slot, logits_row) -> int:
        """First/next token for a slot from its row of logits (greedy or
        temperature/top-k/top-p sampling on the request's own stream)."""
        if slot.key is None:
            return self._greedy(logits_row)
        sp = slot.req.sampling
        slot.key, sub = jax.random.split(slot.key)
        return int(self._sample(sub, logits_row, sp.temperature, sp.top_k, sp.top_p))

    def _admit(self, now: float, respect_arrivals: bool) -> None:
        """Fill free slots from the queue in arrival order.

        Chunked mode prefills every request claimed in a round through ONE
        batched ``prefill_slots`` forward (or one dispatch each with
        ``batch_prefill=False``). A request that finishes on its very first
        token frees its slot immediately, so the round loop re-admits into
        it before the next decode step — same backfill behavior as the old
        one-at-a-time path.

        Paged mode allocates each claim's prompt pages up front (resumed
        requests: prompt + already-generated) and stops claiming — without
        dequeuing — when the pool can't cover the next request plus the
        watermark; the request waits for retirements to free pages. The
        watermark is waived when no other slot is live, so the queue can
        always make progress."""
        self._shed_expired(now)
        while True:
            free = [i for i, s in enumerate(self.slots) if s is None]
            claimed: list[int] = []
            while free and self.waiting:
                req = self.waiting[0]
                if respect_arrivals and req.arrival_time > now:
                    break
                resume = self._resume.get(req.uid)
                if resume is not None and resume.host_key is not None and (
                    self.host is None
                    or self.host.n_pages(resume.host_key) == 0
                ):
                    # tier dropped the entry (LRU) or the record migrated in
                    # from another engine — fall back to recompute-resume
                    resume.host_key = None
                if resume is not None and resume.host_key is not None:
                    # SWAP-IN: the preempted slot's pages are resident on
                    # the host tier. Restore them with one batched scatter,
                    # rebuild the table row, and continue decoding — no
                    # prefill at all. The restored pages are bitwise the
                    # ones the slot held, so token identity vs. the
                    # recompute oracle is structural.
                    n_need = self.host.n_pages(resume.host_key)
                    others_live = any(s is not None for s in self.slots)
                    hold = self.watermark_pages if others_live else 0
                    if self.pool.available < n_need + hold:
                        if self.prefix is not None:
                            self.prefix.evict(
                                n_need + hold - self.pool.available
                            )
                        if self.pool.available < n_need + hold:
                            break  # stays queued; recompute needs no fewer
                    pages = self.pool.alloc(n_need)
                    self.waiting.popleft()
                    i = free.pop(0)
                    self._resume.pop(req.uid)
                    self._restore_pages(
                        pages, self.host.pop(resume.host_key)
                    )
                    self.swapped_in_pages += n_need
                    self._slot_pages[i] = pages
                    self._table_np[i, :] = 0
                    self._table_np[i, : n_need] = pages
                    self._table_dirty = True
                    self.cache = {
                        **self.cache,
                        "pos": self.cache["pos"].at[i].set(resume.pos),
                    }
                    # written tokens = stream[:pos]; the slot re-feeds
                    # stream[pos] next step and (for a mid-prefill victim)
                    # teacher-forces the remaining prompt through pending
                    stream = [int(t) for t in req.prompt] + list(
                        resume.generated
                    )
                    slot = _Slot(
                        req=req,
                        pending=collections.deque(stream[resume.pos + 1:]),
                        generated=list(resume.generated),
                        next_feed=stream[resume.pos],
                        admit_time=resume.admit_time,
                        key=resume.key,
                        feed=None,
                        prefix_len=0,
                    )
                    slot.first_token_time = resume.first_token_time
                    slot.pos_host = resume.pos
                    self._admit_seq += 1
                    slot.seq = self._admit_seq
                    self.slot_history.setdefault(req.uid, []).append(i)
                    self.slots[i] = slot
                    continue
                feed = req.prompt
                if resume is not None and resume.generated:
                    feed = np.concatenate([
                        req.prompt,
                        np.asarray(resume.generated[:-1], np.int32),
                    ])
                hits: list[int] = []
                suffix_start = 0
                cow = False
                if self.paged_cache:
                    if self.prefill_mode == "chunked":
                        total_pages = min(
                            -(-len(feed) // self.page_size), self.table_width
                        )
                        if self.prefix is not None:
                            # map cached prefix pages straight into the
                            # table; SHARE them first so eviction below can
                            # never recycle a page we are about to alias
                            hits = self.prefix.match(feed)
                            for p in hits:
                                self.pool.share(p)
                            # at least one suffix token must run through
                            # prefill (the emission needs its logits); a
                            # fully cached prompt re-prefills its last
                            # token into a COW copy of the final hit page
                            suffix_start = min(
                                len(hits) * self.page_size, len(feed) - 1
                            )
                            cow = len(hits) * self.page_size > suffix_start
                        n_fresh = total_pages - len(hits) + (1 if cow else 0)
                    else:
                        n_fresh = 1  # interleaved: pages arrive lazily
                    # slots claimed earlier this round are already assigned
                    # into self.slots, so this also covers them
                    others_live = any(s is not None for s in self.slots)
                    hold = self.watermark_pages if others_live else 0
                    if self.pool.available < n_fresh + hold:
                        # pool pressure: shed cold index entries before
                        # throttling (graceful degradation to no-sharing)
                        if self.prefix is not None:
                            self.prefix.evict(
                                n_fresh + hold - self.pool.available
                            )
                        if self.pool.available < n_fresh + hold:
                            self.pool.free(hits)  # undo the shares
                            break  # request stays queued
                self.waiting.popleft()
                i = free.pop(0)
                self.cache = reset_slot(self.cache, i)
                slot = _Slot(
                    req=req,
                    pending=collections.deque(feed.tolist()),
                    generated=[],
                    next_feed=-1,
                    admit_time=now,
                    key=self._request_key(req),
                    feed=feed,
                    prefix_len=suffix_start,
                )
                self._admit_seq += 1
                slot.seq = self._admit_seq
                if self.paged_cache:
                    self._table_np[i, :] = 0
                    if self.prefill_mode == "chunked":
                        pages = list(hits)
                        if cow:
                            # the suffix overwrites the tail of the last
                            # shared page: split it (copy-on-write) so the
                            # index copy stays immutable
                            src = pages[-1]
                            dst = self.pool.alloc(1)[0]
                            self.cache = self._copy_page(
                                self.cache,
                                jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32),
                            )
                            self.pool.free([src])  # drop our share
                            pages[-1] = dst
                            self.cow_copies += 1
                        fresh = self.pool.alloc(total_pages - len(pages))
                        pages.extend(fresh)
                        self._slot_pages[i] = pages
                        self._table_np[i, : len(pages)] = pages
                        if resume is None:
                            self.prefix_hit_pages += len(hits)
                            self.prefix_hit_tokens += suffix_start
                            self.prefix_lookup_tokens += len(feed)
                        else:
                            # a resume replays tokens the engine itself
                            # published — its (near-total) hit is real work
                            # saved but says nothing about cross-request
                            # sharing, so it must not inflate the external
                            # prefix_hit_rate
                            self.prefix_resume_hit_tokens += suffix_start
                    else:
                        self._slot_pages[i] = []
                    self._table_dirty = True
                if resume is not None:
                    # resume restoration is cache-layout independent: the
                    # re-prefill of prompt + generated[:-1] (the feed built
                    # above) works over rings and page tables alike, so a
                    # router may migrate paged-engine state into any engine
                    self._resume.pop(req.uid)
                    slot.generated = list(resume.generated)
                    slot.key = resume.key
                    slot.first_token_time = resume.first_token_time
                    slot.admit_time = resume.admit_time
                    slot.resumed = bool(resume.generated)
                self.slot_history.setdefault(req.uid, []).append(i)
                self.slots[i] = slot
                if self.prefill_mode == "chunked":
                    slot.pos_host = len(feed)
                    claimed.append(i)
                else:  # interleaved: decode step consumes prompt tokens
                    slot.pos_host = 0
                    slot.next_feed = slot.pending.popleft()
            if not claimed:
                return
            retired = self._prefill_claimed(claimed)
            if not retired:
                return  # no slot freed, nothing more to admit this round

    def _prefill_claimed(self, claimed: list[int]) -> bool:
        """Chunked-prefill the claimed slots; returns True if any retired.

        ``first_token_time`` is stamped per slot AFTER its token is
        extracted (``_next_token``'s host transfer forces the async jax
        dispatch), so TTFT includes the prefill compute it waited on.

        Each slot prefills its ``feed`` — the prompt, or prompt +
        generated[:-1] for a preemption resume, whose next token is already
        known: its logits row is discarded and the stored token re-fed, so
        neither the greedy argmax nor the sampling stream replays a draw."""
        retired = False

        def emit(i, row):
            nonlocal retired
            slot = self.slots[i]
            slot.pending.clear()
            if slot.resumed:
                # resume: every generated token survived preemption; decode
                # continues by re-feeding the last one. The slot was live
                # when preempted, so it cannot be done here.
                slot.resumed = False
                slot.next_feed = slot.generated[-1]
                return
            g = self._next_token(slot, row)
            slot.first_token_time = self._now()
            slot.generated.append(g)
            slot.next_feed = g
            if self._done(slot, g):
                self._retire(i, slot)
                retired = True

        self._sync_table()
        if self.batch_prefill:
            # SPLIT ADMISSION: partition the round into a cold group
            # (prefix_len == 0 — the pre-existing prefill_slots trace,
            # bitwise unchanged) and a hit group (suffix trace), so one
            # cache hit never routes the whole round — each cold row's
            # padded length and trace — through the suffix path, and cold
            # rounds compile/dispatch ZERO suffix traces.
            cold = [i for i in claimed if self.slots[i].prefix_len == 0]
            hits = [i for i in claimed if self.slots[i].prefix_len > 0]
            logits_by_slot: dict[int, jax.Array] = {}
            for group, suffix in ((cold, False), (hits, True)):
                if not group:
                    continue
                # each row prefills only the UNCACHED SUFFIX of its feed
                sufs = [
                    self.slots[i].feed[self.slots[i].prefix_len:]
                    for i in group
                ]
                round_len = max(p.size for p in sufs)
                if self.bucket_prefill:
                    width = bucket_width(len(group), self.num_slots)
                    padded_len = bucket_length(round_len)
                else:
                    width = len(group)
                    padded_len = round_len
                tokens = np.zeros((width, padded_len), np.int32)
                lengths = np.zeros(width, np.int32)
                starts = np.zeros(width, np.int32)
                slot_ids = np.zeros(width, np.int32)
                for j, (i, p) in enumerate(zip(group, sufs)):
                    tokens[j, : p.size] = p
                    lengths[j] = p.size
                    starts[j] = self.slots[i].prefix_len
                    slot_ids[j] = i
                if width > len(group):
                    # width-bucket padding rows: length 0 (prefill_slots
                    # writes nothing for them), aimed at DISTINCT slots
                    # outside THIS call — slots outside the whole claimed
                    # set first, the other group's slots as overflow (a
                    # zero-length row reads and rewrites their pages
                    # unchanged, so ordering between the two dispatches
                    # doesn't matter). width <= num_slots guarantees
                    # enough spares.
                    in_group = set(group)
                    spare = [
                        i for i in range(self.num_slots)
                        if i not in in_group and i not in set(claimed)
                    ] + [i for i in set(claimed) - in_group]
                    slot_ids[len(group):] = spare[: width - len(group)]
                if suffix:
                    # static pow2-bucketed prefix width: the suffix attend
                    # streams only this many leading table pages per row
                    pw = bucket_pages(
                        -(-max(int(s) for s in starts) // self.page_size),
                        self.table_width,
                    )
                    self.cache, logits = self._prefill_suffix(
                        self.params, self.cache, jnp.asarray(tokens),
                        jnp.asarray(lengths), jnp.asarray(slot_ids),
                        jnp.asarray(starts), pw,
                    )
                    self.suffix_dispatches += 1
                else:
                    self.cache, logits = self._prefill_slots(
                        self.params, self.cache, jnp.asarray(tokens),
                        jnp.asarray(lengths), jnp.asarray(slot_ids),
                    )
                    self.cold_dispatches += 1
                self.prefill_dispatches += 1
                self.prefill_tokens += int(sum(p.size for p in sufs))
                for j, i in enumerate(group):
                    logits_by_slot[i] = logits[j]
            for i in claimed:  # emit in admission order
                emit(i, logits_by_slot[i])
        elif self.paged_cache:
            # per-request dispatches, but through prefill_slots (the paged
            # writer) at width 1 — prefill_into_slot is ring-only
            for i in claimed:
                slot = self.slots[i]
                suf = slot.feed[slot.prefix_len:]
                if slot.prefix_len:
                    pw = bucket_pages(
                        -(-slot.prefix_len // self.page_size),
                        self.table_width,
                    )
                    self.cache, lg = self._prefill_suffix(
                        self.params, self.cache, jnp.asarray(suf[None, :]),
                        jnp.asarray([suf.size], np.int32),
                        jnp.asarray([i], np.int32),
                        jnp.asarray([slot.prefix_len], np.int32), pw,
                    )
                    self.suffix_dispatches += 1
                else:
                    self.cache, lg = self._prefill_slots(
                        self.params, self.cache, jnp.asarray(suf[None, :]),
                        jnp.asarray([suf.size], np.int32),
                        jnp.asarray([i], np.int32),
                    )
                    self.cold_dispatches += 1
                self.prefill_dispatches += 1
                self.prefill_tokens += int(suf.size)
                emit(i, lg[0])
        else:
            for i in claimed:
                self.cache, lg = self._prefill(
                    self.params, self.cache,
                    jnp.asarray(self.slots[i].feed[None, :]), i,
                )
                self.prefill_dispatches += 1
                self.prefill_tokens += int(self.slots[i].feed.size)
                emit(i, lg[0])
        return retired

    def _done(self, slot: _Slot, last: int) -> bool:
        if self.eos_id is not None and last == self.eos_id:
            return True
        return len(slot.generated) >= slot.req.max_new_tokens

    def _retire(self, i: int, slot: _Slot) -> None:
        reason = (
            "eos"
            if self.eos_id is not None and slot.generated[-1] == self.eos_id
            else "length"
        )
        self.finished.append(
            RequestOutput(
                uid=slot.req.uid,
                prompt=slot.req.prompt.tolist(),
                tokens=list(slot.generated),
                slot=i,
                finish_reason=reason,
                arrival_time=slot.req.arrival_time,
                admit_time=slot.admit_time,
                first_token_time=slot.first_token_time,
                finish_time=self._now(),
            )
        )
        self.slots[i] = None
        if self.draft is not None:
            self._draft_pos[i] = -1  # next occupant must re-sync the draft
        if self.paged_cache:
            if self.prefix is not None:
                # publish the slot's FULL prompt pages into the prefix
                # index (the index takes its own refs) BEFORE dropping the
                # slot's — already-indexed chunks dedupe to their existing
                # physical page. Generated tokens and partial tail pages
                # are never indexed.
                n_pub = len(slot.req.prompt) // self.page_size
                n_pub = min(n_pub, len(self._slot_pages[i]))
                if n_pub > 0:
                    self.prefix.insert(
                        slot.req.prompt, self._slot_pages[i][:n_pub]
                    )
            # the slot's refs return to the pool for IMMEDIATE reuse (pages
            # the index pinned stay live); the table row reverts to the
            # scratch page so the retired slot's drifting ``pos`` writes
            # nothing anyone reads
            self.pool.free(self._slot_pages[i])
            self._slot_pages[i] = []
            self._table_np[i, :] = 0
            self._table_dirty = True

    def _retire_timeout(self, i: int, slot: _Slot) -> None:
        """Watchdog retirement: the slot exceeded ``max_wall_s`` of wall
        clock since its ORIGINAL admission. It leaves with a structured
        ``finish_reason="timeout"`` result carrying whatever it generated,
        so callers distinguish a timed-out stream from a complete one.
        Pages are freed WITHOUT publishing to the prefix index — a
        mid-prefill (interleaved) timeout may hold a partially written
        final page, which must never be aliased by another request."""
        self.timeouts += 1
        self.finished.append(
            RequestOutput(
                uid=slot.req.uid,
                prompt=slot.req.prompt.tolist(),
                tokens=list(slot.generated),
                slot=i,
                finish_reason="timeout",
                arrival_time=slot.req.arrival_time,
                admit_time=slot.admit_time,
                first_token_time=slot.first_token_time,
                finish_time=self._now(),
            )
        )
        self.slots[i] = None
        if self.draft is not None:
            self._draft_pos[i] = -1
        if self.paged_cache:
            self.pool.free(self._slot_pages[i])
            self._slot_pages[i] = []
            self._table_np[i, :] = 0
            self._table_dirty = True

    def _watchdog(self) -> None:
        """Per-request wall-clock guard (``max_wall_s``): retire any live
        slot older than the budget. Runs at the top of every engine step,
        so even a step loop whose slots never advance (a stalled dispatch
        under fault injection) keeps shedding rather than hanging."""
        if self.max_wall_s <= 0:
            return
        now = self._now()
        for i, slot in enumerate(self.slots):
            if slot is not None and now - slot.admit_time > self.max_wall_s:
                self._retire_timeout(i, slot)

    # ----------------------------------------------------------- paged pool
    def _sync_table(self) -> None:
        """Push the host page-table mirror to the device before a dispatch.
        The mirror is authoritative — allocation, retirement and preemption
        all mutate it — and the device copy is refreshed lazily, once per
        batch of changes."""
        if self.paged_cache and self._table_dirty:
            self.cache = {**self.cache, "table": jnp.asarray(self._table_np)}
            self._table_dirty = False

    # -------------------------------------------------------- host tier I/O
    @staticmethod
    def _page_bucket(n: int) -> int:
        """Pow2 page-batch bucket: keeps the gather/scatter jits to
        O(log pool) compiled shapes, like every other hot-path axis."""
        m = 1
        while m < n:
            m *= 2
        return m

    def _gather_host(self, pages: list[int]) -> dict:
        """Copy page CONTENT device→host: name → (L, n, page, …) numpy.
        ``np.asarray`` blocks until the copy lands, so callers may free
        (and let the pool rewrite) the source pages immediately after."""
        n = len(pages)
        m = self._page_bucket(n)
        idx = jnp.asarray(np.asarray(list(pages) + [0] * (m - n), np.int32))
        arrs = self._gather_pages_jit(self.cache, idx)
        return {
            name: np.asarray(a[:, :n])
            for name, a in zip(self._kv_names, arrs)
        }

    def _restore_pages(self, pages: list[int], arrays: dict) -> None:
        """Scatter host content back into freshly allocated pool pages.
        Bucket padding targets scratch page 0 (reserved: writes harmless,
        never validly read)."""
        n = len(pages)
        m = self._page_bucket(n)
        idx = np.asarray(list(pages) + [0] * (m - n), np.int32)
        arrs = []
        for name in self._kv_names:
            a = arrays[name]
            if m > n:
                pad = np.zeros((a.shape[0], m - n) + a.shape[2:], a.dtype)
                a = np.concatenate([a, pad], axis=1)
            arrs.append(jnp.asarray(a))
        self.cache = self._scatter_pages_jit(
            self.cache, jnp.asarray(idx), tuple(arrs)
        )

    def _demote_prefix_page(self, key: tuple, page: int) -> None:
        """PrefixCache eviction hook: copy the page's content to the host
        tier (keyed by the full token prefix it caches) before the index
        drops its pool ref. Content is copied, never aliased — co-readers
        still holding the page are unaffected."""
        if self.host is None:
            return
        if self.host.put(("prefix", key), self._gather_host([page]), 1):
            self.host_demoted_pages += 1

    def _promote_prefix_page(self, key: tuple) -> int | None:
        """PrefixCache miss hook: restore a demoted prefix page into a
        fresh pool page; the returned rc=1 ref becomes the index's. None
        when the tier holds no copy or the pool is too tight to spend a
        page on caching (promotion must never starve live admission)."""
        if self.host is None or self.host.n_pages(("prefix", key)) != 1:
            return None
        if self.pool.available <= self.watermark_pages + 1:
            return None
        pages = self.pool.alloc(1)
        if pages is None:
            return None
        self._restore_pages(pages, self.host.pop(("prefix", key)))
        self.host_promote_hits += 1
        return pages[0]

    def _preempt_victim(self) -> int:
        """SLO-aware preemption order: the LOWEST-priority live slot goes
        first; within a priority tier, the YOUNGEST (max admission seq) —
        stalling the most recently admitted work keeps the oldest requests
        flowing, the recency order vLLM uses. All-default-priority traffic
        reproduces the pre-SLO youngest-first behavior exactly."""
        return min(
            (i for i, s in enumerate(self.slots) if s is not None),
            key=lambda i: (self.slots[i].req.priority, -self.slots[i].seq),
        )

    def _preempt(self, i: int) -> None:
        """Evict slot ``i`` back to the HEAD of the waiting queue (it must
        re-admit before anything that arrived after it), freeing its pages.
        Generated tokens, the sampling stream and timing stamps ride along
        in a resume record — re-admission recomputes the KV state by
        prefilling prompt + generated and continues token-identically.

        With the host tier on, the pages are first copied device→host
        (BEFORE the pool refs drop — a freed page may be rewritten by the
        very next decode): re-admission then swaps them back in with one
        scatter instead of re-prefilling. The recompute path stays the
        fallback (and the oracle) whenever the tier dropped the entry."""
        slot = self.slots[i]
        pages = self._slot_pages[i]
        host_key = None
        if self.swap and pages:
            key = ("swap", slot.req.uid)
            if self.host.put(key, self._gather_host(pages), len(pages)):
                host_key = key
                self.swapped_out_pages += len(pages)
        self.pool.free(pages)
        self._slot_pages[i] = []
        self._table_np[i, :] = 0
        self._table_dirty = True
        self._resume[slot.req.uid] = _ResumeState(
            generated=list(slot.generated),
            key=slot.key,
            first_token_time=slot.first_token_time,
            admit_time=slot.admit_time,
            host_key=host_key,
            pos=slot.pos_host,
        )
        self.waiting.appendleft(slot.req)
        self.slots[i] = None
        if self.draft is not None:
            self._draft_pos[i] = -1
        self.preemptions += 1

    # ------------------------------------------------------------ migration
    def export_inflight(self) -> list[tuple[Request, _ResumeState | None]]:
        """Strip EVERY in-flight request off this engine for migration to
        another one: live slots first (admission order), then the waiting
        queue (front first, with any preemption-resume records attached).
        Slots are cleared and their pages freed — after this the engine
        holds no work.

        The returned records are pure host-side state: generated tokens,
        the sampling key, and timing stamps. That is exactly what a
        router fronting real replica processes would hold anyway — it has
        streamed every generated token to the client, and the request-keyed
        PRNG stream is derivable from (seed, uid, tokens emitted), since
        each emission advances the key by one ``jax.random.split``.

        KV pages ride along as HOST-SIDE COPIES (``host_arrays``) when the
        engine can take them: a live slot's pages are gathered
        device→host before being freed, and a waiting request's
        already-swapped tier entry is popped into its record (the tier KEY
        is meaningless to another engine; the content is not). A
        layout-compatible importer adopts them into its own tier and the
        migrated request swaps back in with one scatter — no re-prefill.
        Incompatible or absent arrays fall back to the recompute-resume
        path, which remains the oracle."""
        items: list[tuple[Request, _ResumeState | None]] = []
        live = sorted(
            (i for i, s in enumerate(self.slots) if s is not None),
            key=lambda i: self.slots[i].seq,
        )
        can_carry = self.paged_cache and self.mesh is None
        for i in live:
            slot = self.slots[i]
            resume = None
            if slot.generated:
                resume = _ResumeState(
                    generated=list(slot.generated),
                    key=slot.key,
                    first_token_time=slot.first_token_time,
                    admit_time=slot.admit_time,
                    pos=slot.pos_host,
                )
                if can_carry and self._slot_pages[i]:
                    # copy BEFORE the free below — a freed page may be
                    # rewritten by the importer's very first dispatch
                    resume.host_arrays = self._gather_host(
                        self._slot_pages[i]
                    )
            items.append((slot.req, resume))
            self.slots[i] = None
            if self.draft is not None:
                self._draft_pos[i] = -1
            if self.paged_cache:
                self.pool.free(self._slot_pages[i])
                self._slot_pages[i] = []
                self._table_np[i, :] = 0
                self._table_dirty = True
        while self.waiting:
            req = self.waiting.popleft()
            resume = self._resume.pop(req.uid, None)
            if resume is not None and resume.host_key is not None:
                # pop the swapped pages out of THIS engine's tier and carry
                # their content in the record itself
                if self.host is not None:
                    resume.host_arrays = self.host.pop(resume.host_key)
                resume.host_key = None
            items.append((req, resume))
        return items

    def _adopt_host_arrays(
        self, uid: int, resume: _ResumeState, arrays: dict
    ) -> bool:
        """Take a migrated record's page content into the LOCAL host tier
        (under this engine's own ("swap", uid) key) so admission swaps the
        request in instead of recomputing. Adoption requires an exactly
        matching pool layout — same plane set (fp vs int8+scales), same
        layer count, page shape and dtypes — anything else recomputes."""
        if self.host is None or resume.pos <= 0:
            return False
        if set(arrays) != set(self._kv_names):
            return False
        for name in self._kv_names:
            ref = self.cache[name]
            a = arrays[name]
            if (
                a.shape[0] != ref.shape[0]
                or a.shape[2:] != tuple(ref.shape[2:])
                or a.dtype != np.dtype(ref.dtype)
            ):
                return False
        n = int(arrays[self._kv_names[0]].shape[1])
        key = ("swap", uid)
        if not self.host.put(key, arrays, n):
            return False
        resume.host_key = key
        return True

    def import_inflight(
        self, items: list[tuple[Request, _ResumeState | None]]
    ) -> None:
        """Adopt migrated requests at the FRONT of the queue, preserving
        their order — in-flight work from a failed replica is older than
        anything queued locally, and FIFO admission owes it first service.
        Requests with generated tokens re-enter through the preemption-
        resume path (re-prefill prompt + generated[:-1], re-feed the last
        token, continue the sampling stream where it stopped), so the
        merged output stream is token-identical to an uninterrupted run."""
        for req, resume in reversed(items):
            if self.capacity_shortfall(req) > 0:
                raise AdmissionError(
                    req.uid, "exceeds_pool",
                    f"migrated request {req.uid} exceeds this engine's "
                    "static capacity",
                )
            if resume is not None and resume.generated:
                if resume.host_arrays is not None:
                    self._adopt_host_arrays(req.uid, resume, resume.host_arrays)
                    resume.host_arrays = None
                self._resume[req.uid] = resume
            self.waiting.appendleft(req)

    def _ensure_decode_pages(self, live: list[int]) -> None:
        """Lazy per-step allocation: before a decode dispatch, every live
        slot whose next write position crosses into an unallocated logical
        page gets one. When the pool is dry, the LOWEST-priority-then-
        youngest slot is preempted (repeatedly, until a page frees up) —
        see ``_preempt_victim``. If the starving slot preempts ITSELF the
        loop stops: its request is back in the queue, its pages freed."""
        for i in live:
            slot = self.slots[i]
            if slot is None:
                continue  # preempted while serving an earlier slot's need
            pi = (slot.pos_host % self.cap) // self.page_size
            if self._table_np[i, pi] != 0:
                continue
            while True:
                pages = self.pool.alloc(1)
                if pages is not None:
                    self._slot_pages[i].append(pages[0])
                    self._table_np[i, pi] = pages[0]
                    self._table_dirty = True
                    break
                # shed cold prefix-index pages before preempting live work
                if self.prefix is not None and self.prefix.evict(1) > 0:
                    continue
                victim = self._preempt_victim()
                self._preempt(victim)
                if victim == i:
                    break  # the needy slot itself went back to the queue

    # ------------------------------------------------------- spec decoding
    def _ensure_spec_pages(
        self, live: list[int], k_r: dict[int, int]
    ) -> dict[int, list[tuple[int, int]]]:
        """BEST-EFFORT lookahead pages for a speculative round: slot ``i``
        verifying ``k_r[i]`` drafts writes positions ``pos .. pos+k_r[i]``,
        which may cross into logical pages beyond the one
        ``_ensure_decode_pages`` guarantees. Lookahead pages never preempt
        live work and never dip below the admission watermark — when the
        pool is tight the round simply runs SHALLOWER (``k_r`` shrinks to
        what the covered pages can hold; 0 degenerates to a 1-token verify,
        i.e. plain decode with an extra logit row). Returns the freshly
        allocated (page_index, page) pairs per slot so rejection rollback
        can free exactly the pages that ended up holding no kept tokens."""
        fresh: dict[int, list[tuple[int, int]]] = {}
        for i in live:
            p = self.slots[i].pos_host
            first = p // self.page_size + 1
            last = (p + k_r[i]) // self.page_size
            got = []
            for pi in range(first, last + 1):
                if self._table_np[i, pi] != 0:
                    continue
                pages = None
                if self.pool.available > self.watermark_pages:
                    pages = self.pool.alloc(1)
                if (
                    pages is None
                    and self.prefix is not None
                    and self.prefix.evict(1) > 0
                    and self.pool.available > self.watermark_pages
                ):
                    pages = self.pool.alloc(1)
                if pages is None:
                    k_r[i] = pi * self.page_size - 1 - p
                    break
                self._slot_pages[i].append(pages[0])
                self._table_np[i, pi] = pages[0]
                self._table_dirty = True
                got.append((pi, pages[0]))
            if got:
                fresh[i] = got
        return fresh

    def _rollback_spec_pages(
        self, i: int, fresh_i: list[tuple[int, int]], keep_pos: int
    ) -> None:
        """Free the round's fresh lookahead pages past the accepted span:
        after acceptance the slot keeps ``keep_pos`` written tokens, so a
        fresh page whose index is beyond the last kept token's page holds
        only rejected KV. Pre-existing pages are never touched (they hold
        committed history), so rejection storms cannot leak or double-free
        — the accounting invariant the spec tests pin."""
        last = (keep_pos - 1) // self.page_size
        for pi, page in fresh_i:
            if pi > last:
                self.pool.free([page])
                self._slot_pages[i].remove(page)
                self._table_np[i, pi] = 0
                self._table_dirty = True

    def _spec_round(self, live: list[int]) -> None:
        """One speculative iteration over the live slots: draft-propose k
        tokens per row, verify ALL rows' proposals in ONE batched
        suffix-prefill dispatch of the target, then accept a prefix of each
        row's drafts (greedy: longest argmax-matching run; sampled:
        Leviathan rejection sampling) and roll rejected KV back by pos
        truncation + lookahead-page free.

        Greedy rows emit EXACTLY the target-only decode stream: the verify
        logits at position p+j are the same forward the per-token path
        would compute after consuming the same j accepted tokens, and the
        walk stops at the first draft/argmax mismatch — so every emitted
        token is an argmax the sequential engine would have produced
        (bitwise, pinned by tests). Sampled rows draw from the target
        distribution exactly (speculative-sampling guarantee), on a
        per-request stream advanced ONE split per round."""
        kk = self.spec_tokens
        for i in live:
            slot = self.slots[i]
            # chunked admission prefills prompts whole, so decode-phase
            # slots can never be mid-prefill or resume-suppressed here
            assert not slot.pending and not slot.resumed, (
                "spec round over a mid-prefill/resumed slot"
            )
        # ---- draft re-sync: rows whose draft state does not sit exactly at
        # pos_host (fresh admissions, preemption returns, slot reuse) get a
        # full re-prefill of their written stream; rows in sync ride along
        # as length-0 no-ops
        stale = [i for i in live if self._draft_pos[i] != self.slots[i].pos_host]
        if stale:
            lb = bucket_length(max(self.slots[i].pos_host for i in stale))
            toks = np.zeros((self.num_slots, lb), np.int32)
            lens = np.zeros(self.num_slots, np.int32)
            for i in stale:
                slot = self.slots[i]
                p = slot.pos_host
                stream = list(slot.req.prompt) + slot.generated
                toks[i, :p] = stream[:p]
                lens[i] = p
            self.draft.prefill_rows(jnp.asarray(toks), jnp.asarray(lens))
            for i in stale:
                self._draft_pos[i] = self.slots[i].pos_host
        # ---- per-row depth: never draft past max_new (the +1 correction /
        # bonus token must still fit) or the slot's token capacity; the page
        # pass below may shrink depths further
        lim = min(self.cap, self.pool.capacity * self.page_size)
        k_r = {}
        for i in live:
            slot = self.slots[i]
            rem = slot.req.max_new_tokens - len(slot.generated)
            k_r[i] = max(0, min(kk, rem - 1, lim - 1 - slot.pos_host))
        fresh = self._ensure_spec_pages(live, k_r)
        # ---- round inputs (full slot width, like every engine dispatch)
        feed = np.zeros(self.num_slots, np.int32)
        greedy = np.ones(self.num_slots, bool)
        temps = np.ones(self.num_slots, np.float32)
        topks = np.zeros(self.num_slots, np.int32)
        topps = np.ones(self.num_slots, np.float32)
        samp = [i for i in live if self.slots[i].key is not None]
        for i in live:
            slot = self.slots[i]
            feed[i] = slot.next_feed
            if slot.key is not None:
                sp = slot.req.sampling
                greedy[i] = False
                temps[i] = sp.temperature
                topks[i] = sp.top_k
                topps[i] = sp.top_p
        subs = None
        if samp:
            in_samp = set(samp)
            keys = [
                self.slots[i].key if i in in_samp else self._dummy_key
                for i in range(self.num_slots)
            ]
            new_keys, subs = self._spec_split(jnp.stack(keys))
            for i in samp:
                self.slots[i].key = new_keys[i]
        keys_arr = subs if subs is not None else self._spec_dummy_keys
        # ---- draft proposals: k sequential CHEAP steps, all rows at once
        drafts_dev, logq_dev = self.draft.propose(
            jnp.asarray(feed), keys_arr, jnp.asarray(greedy),
            jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(topps),
        )
        drafts = np.asarray(drafts_dev)  # (num_slots, k)
        # ---- single-dispatch verify: row j feeds [next_feed, d_1..d_kr]
        # as a SUFFIX at starts=pos over the shared page table — one target
        # forward replaces kr+1 sequential decode dispatches
        self._sync_table()
        n = len(live)
        width = bucket_width(n, self.num_slots)
        s_len = bucket_length(max(k_r[i] for i in live) + 1)
        tokens = np.zeros((width, s_len), np.int32)
        lengths = np.zeros(width, np.int32)
        starts = np.zeros(width, np.int32)
        slot_ids = np.zeros(width, np.int32)
        for j, i in enumerate(live):
            slot = self.slots[i]
            kr = k_r[i]
            tokens[j, 0] = slot.next_feed
            tokens[j, 1:kr + 1] = drafts[i, :kr]
            lengths[j] = kr + 1
            starts[j] = slot.pos_host
            slot_ids[j] = i
        in_round = set(live)
        spare = [s for s in range(self.num_slots) if s not in in_round]
        slot_ids[n:] = spare[: width - n]
        pw = bucket_pages(
            -(-max(int(s) for s in starts) // self.page_size),
            self.table_width,
        )
        self.cache, vlog = self._spec_verify(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(lengths), jnp.asarray(slot_ids),
            jnp.asarray(starts), pw,
        )
        self.spec_rounds += 1
        self.steps += 1
        # ---- acceptance: one batched argmax transfer for greedy rows, one
        # batched rejection-sampling dispatch for sampled rows
        g_host = None
        if any(self.slots[i].key is None for i in live):
            g_host = np.asarray(
                jnp.argmax(vlog[..., : self.cfg.vocab_size], axis=-1)
            )  # (width, s_len)
        n_emit_host = emitted_host = None
        if samp:
            klive = np.zeros(width, np.int32)
            for j, i in enumerate(live):
                klive[j] = k_r[i]
            sl = jnp.asarray(slot_ids)
            n_emit_dev, emitted_dev = self._spec_accept(
                keys_arr[sl], vlog, jnp.asarray(drafts)[sl], logq_dev[sl],
                jnp.asarray(klive), jnp.asarray(temps[slot_ids]),
                jnp.asarray(topks[slot_ids]), jnp.asarray(topps[slot_ids]),
            )
            n_emit_host = np.asarray(n_emit_dev)
            emitted_host = np.asarray(emitted_dev)
        # ---- commit: append each row's accepted run, truncate target pos
        # to the kept span, free lookahead pages past it, restore the draft
        now = self._now()
        new_pos = np.zeros(self.num_slots, np.int32)
        mask = np.zeros(self.num_slots, bool)
        snap_idx = np.full(self.num_slots, kk, np.int32)
        for j, i in enumerate(live):
            slot = self.slots[i]
            kr = k_r[i]
            p = slot.pos_host
            if slot.key is None:
                g = g_host[j]
                emitted = []
                t = 0
                while True:
                    emitted.append(int(g[t]))
                    if t >= kr or int(drafts[i, t]) != int(g[t]):
                        break
                    t += 1
            else:
                ne = min(int(n_emit_host[j]), kr + 1)
                emitted = [int(x) for x in emitted_host[j, :ne]]
            if slot.first_token_time < 0:
                slot.first_token_time = now
            appended = 0
            done = False
            for tok in emitted:
                slot.generated.append(tok)
                appended += 1
                if self._done(slot, tok):
                    done = True
                    break
            self.spec_drafted += kr
            self.spec_emitted += appended
            self.spec_accepted += max(0, appended - 1)
            new_pos[i] = p + appended
            mask[i] = True
            snap_idx[i] = appended - 1
            if done:
                # _retire frees EVERY slot page (lookahead included), so
                # rollback must not run first — that would double-free
                self._retire(i, slot)
            else:
                self._rollback_spec_pages(i, fresh.get(i, []), p + appended)
                slot.pos_host = p + appended
                slot.next_feed = emitted[appended - 1]
                self._draft_pos[i] = p + appended
        self.cache = self._fix_pos(
            self.cache, jnp.asarray(new_pos), jnp.asarray(mask)
        )
        self.draft.commit(
            jnp.asarray(mask), jnp.asarray(new_pos), jnp.asarray(snap_idx)
        )
        self.occupancy.append(self.pool.in_use / max(self.pool.capacity, 1))

    def step(self, *, respect_arrivals: bool = False) -> list[RequestOutput]:
        """One engine iteration: admit → one batched decode step → retire.

        Returns the requests that finished during this iteration. With
        ``respect_arrivals`` the admission gate compares each request's
        ``arrival_time`` against the engine clock; otherwise the queue
        drains in arrival order as slots free up (virtual time).
        """
        n_done = len(self.finished)
        attention.set_decode_kernel(self.use_kernel, paged=self.paged_decode)
        # prefix-hit admission rounds (dispatched from _admit below) run
        # the Pallas suffix-prefill kernel under the same engine-wide flag
        attention.set_suffix_kernel(self.use_kernel)
        try:
            self._watchdog()
            self._admit(self._now(), respect_arrivals)
            live = [i for i, s in enumerate(self.slots) if s is not None]
            if live and self.paged_cache:
                # lazy page allocation (may preempt the youngest slot when
                # the pool runs dry — re-collect the survivors)
                self._ensure_decode_pages(live)
                live = [i for i, s in enumerate(self.slots) if s is not None]
            if live and self.draft is not None:
                # speculative round: draft k tokens per slot, verify all of
                # them in one batched target dispatch (see _spec_round)
                self._spec_round(live)
            elif live:
                self._sync_table()
                feed = np.zeros((self.num_slots, 1), np.int32)
                for i in live:
                    feed[i, 0] = self.slots[i].next_feed
                self.cache, logits = self._decode(
                    self.params, self.cache, jnp.asarray(feed)
                )
                self.steps += 1
                for i in live:
                    self.slots[i].pos_host += 1  # one token written per row
                if self.paged_cache:
                    self.occupancy.append(
                        self.pool.in_use / max(self.pool.capacity, 1)
                    )
                # one batched argmax + host transfer per step, not per slot
                # (skipped entirely when every emitting slot samples).
                # Resumed slots re-feed a stored token this step: no argmax,
                # no sampling draw — their streams must not advance.
                need_greedy = any(
                    self.slots[i].key is None and not self.slots[i].pending
                    and not self.slots[i].resumed
                    for i in live
                )
                greedy = (
                    np.asarray(jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1))
                    if need_greedy
                    else None
                )
                # sampled slots batch the same way: split every stream and
                # draw in ONE fixed-width dispatch (dummy rows for greedy /
                # mid-prefill slots), then one host transfer
                samp = [
                    i for i in live
                    if self.slots[i].key is not None
                    and not self.slots[i].pending
                    and not self.slots[i].resumed
                ]
                sampled: dict[int, int] = {}
                if samp:
                    keys, temps, ks, ps = [], [], [], []
                    for i in range(self.num_slots):
                        if i in samp:
                            sp = self.slots[i].req.sampling
                            keys.append(self.slots[i].key)
                            temps.append(sp.temperature)
                            ks.append(sp.top_k)
                            ps.append(sp.top_p)
                        else:
                            keys.append(self._dummy_key)
                            temps.append(1.0)
                            ks.append(1)
                            ps.append(1.0)
                    new_keys, toks = self._sample_rows(
                        jnp.stack(keys), logits,
                        jnp.asarray(temps, jnp.float32),
                        jnp.asarray(ks, jnp.int32),
                        jnp.asarray(ps, jnp.float32),
                    )
                    toks = np.asarray(toks)
                    for i in samp:
                        self.slots[i].key = new_keys[i]
                        sampled[i] = int(toks[i])
                now = self._now()
                for i in live:
                    slot = self.slots[i]
                    if slot.pending:  # mid-prefill: logits are teacher-forced
                        slot.next_feed = slot.pending.popleft()
                        continue
                    if slot.resumed:
                        # interleaved resume just finished re-feeding its
                        # history: the next token is already known
                        slot.resumed = False
                        slot.next_feed = slot.generated[-1]
                        continue
                    g = sampled[i] if slot.key is not None else int(greedy[i])
                    if slot.first_token_time < 0:
                        slot.first_token_time = now
                    slot.generated.append(g)
                    slot.next_feed = g
                    if self._done(slot, g):
                        self._retire(i, slot)  # freed; backfilled next admit
        finally:
            attention.set_decode_kernel(False)
            attention.set_suffix_kernel(False)
        return self.finished[n_done:]

    def run(
        self, requests=(), *, realtime: bool = False
    ) -> list[RequestOutput]:
        """Drain ``requests`` (plus anything already queued) to completion.

        ``realtime=True`` honors arrival times against the wall clock,
        sleeping while all slots are idle and the next arrival is in the
        future — the benchmark's Poisson-trace mode. ``realtime=False``
        replays the queue in arrival order at full speed (deterministic)."""
        for req in sorted(requests, key=lambda r: r.arrival_time):
            self.submit(req)
        outs: list[RequestOutput] = []
        while self.has_work:
            if realtime and self.active_slots == 0:
                nxt = self.next_arrival()
                if nxt is not None:
                    delay = nxt - self._now()
                    if delay > 0:
                        time.sleep(delay)
            outs.extend(self.step(respect_arrivals=realtime))
        return sorted(outs, key=lambda o: o.uid)


# ----------------------------------------------------------------- helpers
def make_requests(
    cfg,
    *,
    n_requests: int,
    prompt_len: int,
    gen_tokens: int,
    seed: int = 0,
    stagger: float = 0.0,
) -> list[Request]:
    """Synthetic request trace with the serve oracle's prompt distribution:
    row r of the (n_requests, prompt_len) corpus sample is request r, so the
    uid-r output is directly comparable against ``serve_batch`` row r."""
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.0)
    prompts = corpus.sample(
        jax.random.PRNGKey(seed + 1), jnp.ones(4) / 4, n_requests, prompt_len
    )["tokens"]
    prompts = np.asarray(prompts, np.int32)
    return [
        Request(
            uid=r,
            prompt=prompts[r],
            max_new_tokens=gen_tokens,
            arrival_time=r * stagger,
        )
        for r in range(n_requests)
    ]


def serve_continuous(
    arch: str,
    *,
    smoke: bool = True,
    num_slots: int = 4,
    n_requests: int = 8,
    prompt_len: int = 32,
    gen_tokens: int = 32,
    window: int = 0,
    use_kernel: bool = False,
    prefill: str = "chunked",
    batch_prefill: bool = True,
    bucket_prefill: bool = True,
    paged_decode: bool = True,
    donate_cache: bool = True,
    paged_cache: bool = True,
    page_size: int = 16,
    num_pages: int = 0,
    long_requests: bool = False,
    watermark_pages: int = 0,
    prefix_cache: bool = True,
    prefix_cache_pages: int = 0,
    kv_dtype: str = "fp",
    host_pages: int = 0,
    swap: bool = True,
    num_shards: int = 0,
    draft: str | None = None,
    spec_tokens: int = 0,
    sampling: SamplingParams | None = None,
    seed: int = 0,
    stagger: float = 0.0,
    max_wall_s: float = 0.0,
    log_fn=print,
) -> dict:
    """Build a model + engine, serve a synthetic trace, report throughput.

    The serving CLI defaults to the PAGED cache (``--no-paged-cache``
    restores per-slot contiguous rings) — output is token-identical either
    way; paged mode additionally reports pool occupancy and preemptions.
    ``num_shards > 0`` serves tensor-parallel on a ``model``-axis mesh over
    that many devices (bitwise token-identical to the unsharded engine).
    ``draft`` names a second (cheap) config for speculative decoding: it
    proposes ``spec_tokens`` tokens per slot per round and the target
    verifies them in one batched dispatch — greedy output stays bitwise
    identical to the non-speculative engine."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    draft_model = draft_params = None
    if draft is not None:
        dcfg = get_smoke_config(draft) if smoke else get_config(draft)
        draft_model = build_model(dcfg)
        # the draft seeds from the SAME stream: --draft <arch> with the
        # target's own arch gives identical params, the ~100% acceptance
        # probe configuration serve_bench --spec-probe exploits
        draft_params = draft_model.init(jax.random.PRNGKey(seed))
    engine = ServeEngine(
        model,
        params,
        num_slots=num_slots,
        max_seq=prompt_len + gen_tokens,
        window=window,
        use_kernel=use_kernel,
        prefill=prefill,
        batch_prefill=batch_prefill,
        bucket_prefill=bucket_prefill,
        paged_decode=paged_decode,
        donate_cache=donate_cache,
        mesh=make_serve_mesh(num_shards) if num_shards > 0 else None,
        paged_cache=paged_cache,
        page_size=page_size,
        num_pages=num_pages,
        long_requests=long_requests,
        watermark_pages=watermark_pages,
        prefix_cache=prefix_cache,
        prefix_cache_pages=prefix_cache_pages,
        kv_dtype=kv_dtype,
        host_pages=host_pages,
        swap=swap,
        draft_model=draft_model,
        draft_params=draft_params,
        spec_tokens=spec_tokens,
        seed=seed,
        max_wall_s=max_wall_s,
    )
    reqs = make_requests(
        cfg, n_requests=n_requests, prompt_len=prompt_len,
        gen_tokens=gen_tokens, seed=seed, stagger=stagger,
    )
    if sampling is not None and not sampling.is_greedy:
        for r in reqs:
            # distinct stream per request even under a shared CLI seed
            r.sampling = dataclasses.replace(
                sampling,
                seed=None if sampling.seed is None else sampling.seed + r.uid,
            )
    # trace prefill + decode outside the measured window so the reported
    # throughput/latency are steady-state, not jit compilation
    engine.warm([prompt_len], gen_tokens=min(2, gen_tokens), sampling=sampling)
    t0 = time.time()
    outs = engine.run(reqs, realtime=stagger > 0)
    wall = time.time() - t0
    total = sum(len(o.tokens) for o in outs)
    lat = [o.latency for o in outs] or [0.0]
    result = {
        "arch": cfg.name,
        "num_slots": num_slots,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "gen_tokens": gen_tokens,
        "window": window,
        "use_kernel": use_kernel,
        "prefill": prefill,
        "batch_prefill": engine.batch_prefill,
        "bucket_prefill": engine.bucket_prefill,
        "paged_decode": engine.paged_decode,
        "donate_cache": engine.donate_cache,
        "paged_cache": engine.paged_cache,
        "shards": engine.num_shards,
        "mesh_axes": (
            dict(engine.mesh.shape) if engine.mesh is not None else None
        ),
        "prefix_cache": engine.prefix_cache,
        "kv_dtype": engine.kv_dtype,
        "draft": None if draft_model is None else draft_model.cfg.name,
        "spec_tokens": engine.spec_tokens,
        "prefill_tokens": engine.prefill_tokens,
        "sampling": None if sampling is None else dataclasses.asdict(sampling),
        "engine_steps": engine.steps,
        "prefill_dispatches": engine.prefill_dispatches,
        "compiles": engine.compiles,
        "pool": engine.pool_stats,
        "wall_seconds": wall,
        "tokens_per_second": total / max(wall, 1e-9),
        "generated": [o.tokens for o in outs],
        "slots": [o.slot for o in outs],
        "latency_p50": float(np.percentile(lat, 50)),
        "latency_p95": float(np.percentile(lat, 95)),
    }
    pool_line = ""
    if engine.paged_cache:
        ps = result["pool"]
        pool_line = (
            f", pool occ mean {ps['occupancy_mean']:.0%} / "
            f"max {ps['occupancy_max']:.0%} over "
            f"{ps['allocatable_pages']} pages, "
            f"{ps['preemptions']} preemptions"
        )
        if engine.mesh is not None:
            pool_line += f", {ps['shards']}-shard mesh"
        if engine.prefix_cache:
            pool_line += (
                f", prefix hit {ps['prefix_hit_rate']:.0%} "
                f"({ps['prefix_hit_pages']} pages, "
                f"{ps['cow_copies']} CoW)"
            )
        if ps["kv_dtype"] != "fp":
            pool_line += f", kv {ps['kv_dtype']}"
        if ps["swap_enabled"]:
            pool_line += (
                f", swap {ps['swapped_out_pages']}↓/"
                f"{ps['swapped_in_pages']}↑ pages"
            )
        if ps["spec_enabled"]:
            pool_line += (
                f", spec k={ps['spec_tokens']} accept "
                f"{ps['spec_accept_rate']:.0%}, "
                f"{ps['spec_dispatches_per_token']:.2f} dispatch/tok"
            )
    log_fn(
        f"{cfg.name}: {n_requests} reqs × {gen_tokens} tok over "
        f"{num_slots} slots in {engine.steps} steps "
        f"+ {engine.prefill_dispatches} prefill dispatches, {wall:.2f}s "
        f"({result['tokens_per_second']:.1f} tok/s, "
        f"p50 {result['latency_p50']:.2f}s p95 {result['latency_p95']:.2f}s"
        f"{pool_line})"
    )
    return result
