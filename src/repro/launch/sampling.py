"""Per-request sampling for the serve engine: temperature / top-k / top-p.

``SamplingParams`` travels on each ``Request``; ``sample_token`` is the
jit-friendly single-row sampler the engine calls after its batched decode
step. Filters follow the standard serving order (temperature scale → top-k
rank cut → top-p nucleus cut → categorical draw); ``top_k`` and ``top_p``
are traced scalars so one compiled sampler serves every request mix without
respecialization.

Stream discipline: the engine derives one PRNG key per REQUEST (from
``SamplingParams.seed``, or the engine seed folded with the request uid),
never per slot — retiring a request and backfilling its slot can therefore
never resume or reuse the previous occupant's stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls.

    temperature: 0 means greedy (argmax; top-k/top-p ignored).
    top_k: keep the k highest-probability tokens; 0 disables the cut.
    top_p: keep the smallest prefix of the sorted distribution with
        cumulative probability >= top_p; 1.0 disables the cut.
    seed: explicit PRNG seed for this request's stream. None lets the
        engine derive a stream from its own seed + the request uid.
    """
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None

    def __post_init__(self):
        assert self.temperature >= 0.0, "temperature must be >= 0"
        assert self.top_k >= 0, "top_k must be >= 0"
        assert 0.0 < self.top_p <= 1.0, "top_p must be in (0, 1]"

    @property
    def is_greedy(self) -> bool:
        return self.temperature == 0.0


def sample_token(
    key: jax.Array,
    logits: jax.Array,
    temperature: jax.Array,
    top_k: jax.Array,
    top_p: jax.Array,
    vocab_size: int,
) -> jax.Array:
    """Draw one token id from a single row of logits.

    logits: (Vp,) fp32 (padded-vocab columns already masked to NEG_INF).
    temperature > 0 (greedy is the caller's fast path), top_k/top_p as in
    ``SamplingParams`` but traced, so a single jit covers all requests.
    """
    logits = logits[:vocab_size].astype(jnp.float32)
    logits = logits / jnp.maximum(temperature, 1e-6)
    order = jnp.argsort(-logits)  # descending
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(vocab_size))
    logits = jnp.where((top_k > 0) & (ranks >= top_k), NEG_INF, logits)
    # nucleus cut on the post-top-k distribution: keep rank i iff the mass
    # strictly before it is < top_p (the best token always survives)
    probs_sorted = jax.nn.softmax(logits[order])
    before = jnp.cumsum(probs_sorted) - probs_sorted
    keep_sorted = (before < top_p) | (top_p >= 1.0)
    logits = jnp.where(keep_sorted[ranks], logits, NEG_INF)
    return jax.random.categorical(key, logits)
