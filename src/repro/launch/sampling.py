"""Per-request sampling for the serve engine: temperature / top-k / top-p.

``SamplingParams`` travels on each ``Request``; ``sample_token`` is the
jit-friendly single-row sampler the engine calls after its batched decode
step. Filters follow the standard serving order (temperature scale → top-k
rank cut → top-p nucleus cut → categorical draw); ``top_k`` and ``top_p``
are traced scalars so one compiled sampler serves every request mix without
respecialization.

Stream discipline: the engine derives one PRNG key per REQUEST (from
``SamplingParams.seed``, or the engine seed folded with the request uid),
never per slot — retiring a request and backfilling its slot can therefore
never resume or reuse the previous occupant's stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls.

    temperature: 0 means greedy (argmax; top-k/top-p ignored).
    top_k: keep the k highest-probability tokens; 0 disables the cut.
    top_p: keep the smallest prefix of the sorted distribution with
        cumulative probability >= top_p; 1.0 disables the cut.
    seed: explicit PRNG seed for this request's stream. None lets the
        engine derive a stream from its own seed + the request uid.
    """
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None

    def __post_init__(self):
        assert self.temperature >= 0.0, "temperature must be >= 0"
        assert self.top_k >= 0, "top_k must be >= 0"
        assert 0.0 < self.top_p <= 1.0, "top_p must be in (0, 1]"

    @property
    def is_greedy(self) -> bool:
        return self.temperature == 0.0


def filter_logits(
    logits: jax.Array,
    temperature: jax.Array,
    top_k: jax.Array,
    top_p: jax.Array,
    vocab_size: int,
) -> jax.Array:
    """Temperature/top-k/top-p filtered logits for a single row.

    logits: (Vp,); returns (vocab_size,) fp32 with every filtered-out
    column at NEG_INF — ``softmax`` of the result is the distribution a
    request actually samples from. Shared by ``sample_token`` and the
    speculative-decoding acceptance sampler, which needs the SAME filtered
    target distribution the per-token path would have drawn from."""
    logits = logits[:vocab_size].astype(jnp.float32)
    logits = logits / jnp.maximum(temperature, 1e-6)
    order = jnp.argsort(-logits)  # descending
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(vocab_size))
    logits = jnp.where((top_k > 0) & (ranks >= top_k), NEG_INF, logits)
    # nucleus cut on the post-top-k distribution: keep rank i iff the mass
    # strictly before it is < top_p (the best token always survives)
    probs_sorted = jax.nn.softmax(logits[order])
    before = jnp.cumsum(probs_sorted) - probs_sorted
    keep_sorted = (before < top_p) | (top_p >= 1.0)
    return jnp.where(keep_sorted[ranks], logits, NEG_INF)


def sample_token(
    key: jax.Array,
    logits: jax.Array,
    temperature: jax.Array,
    top_k: jax.Array,
    top_p: jax.Array,
    vocab_size: int,
) -> jax.Array:
    """Draw one token id from a single row of logits.

    logits: (Vp,) fp32 (padded-vocab columns already masked to NEG_INF).
    temperature > 0 (greedy is the caller's fast path), top_k/top_p as in
    ``SamplingParams`` but traced, so a single jit covers all requests.
    """
    return jax.random.categorical(
        key, filter_logits(logits, temperature, top_k, top_p, vocab_size)
    )


def speculative_acceptance(
    key: jax.Array,
    tgt_logits: jax.Array,
    draft_tokens: jax.Array,
    draft_logq: jax.Array,
    k_live: jax.Array,
    temperature: jax.Array,
    top_k: jax.Array,
    top_p: jax.Array,
    vocab_size: int,
) -> tuple[jax.Array, jax.Array]:
    """Leviathan-style rejection sampling for ONE sampled request's round.

    tgt_logits: (K+1, Vp) target logits at absolute positions p..p+K (one
    verify dispatch); draft_tokens: (K,) proposals d_1..d_K; draft_logq:
    (K, V) the draft's FILTERED log-probs each proposal was drawn from;
    k_live: how many proposals this row actually speculated (<= K — rows
    near max_new or the page budget run shallower).

    Accept d_j while u_j < p_{j-1}(d_j) / q_j(d_j); the first rejection
    draws from the normalized residual max(p - q, 0) (falling back to p
    when the residual has no mass); a fully accepted row draws a BONUS
    token from p_K. Emitted tokens are therefore exact samples from the
    target distribution regardless of the draft — the standard
    speculative-sampling guarantee. Returns (n_emit, emitted (K+1,)):
    emitted[:n_emit] = accepted drafts + the final draw, n_emit in
    [1, k_live+1]. All draws fold the per-request stream ``key``, so a
    request's round is reproducible from (seed, uid, rounds elapsed)."""
    kk = draft_tokens.shape[0]
    flt = jax.vmap(
        lambda row: filter_logits(row, temperature, top_k, top_p, vocab_size)
    )(tgt_logits)                                    # (K+1, V)
    p = jax.nn.softmax(flt, axis=-1)                 # target dists
    q = jnp.exp(draft_logq)                          # proposal dists
    steps = jnp.arange(kk)
    p_d = jnp.take_along_axis(p[:kk], draft_tokens[:, None], axis=1)[:, 0]
    q_d = jnp.take_along_axis(q, draft_tokens[:, None], axis=1)[:, 0]
    u = jax.vmap(lambda j: jax.random.uniform(jax.random.fold_in(key, j)))(
        steps
    )
    ok = (steps < k_live) & (u * jnp.maximum(q_d, 1e-30) < p_d)
    # leading run of accepts: d_j lands iff every d_<j did too
    acc = jnp.cumprod(ok.astype(jnp.int32))
    n_acc = jnp.sum(acc)
    # rejection at step n_acc+1 (if any): residual max(p_{n_acc}-q_{n_acc}, 0)
    p_rej = p[n_acc]
    q_rej = q[jnp.minimum(n_acc, kk - 1)]
    resid = jnp.maximum(p_rej - q_rej, 0.0)
    mass = jnp.sum(resid)
    resid = jnp.where(mass > 0, resid / jnp.maximum(mass, 1e-30), p_rej)
    resid_tok = jax.random.categorical(
        jax.random.fold_in(key, kk), jnp.log(jnp.maximum(resid, 1e-30))
    )
    bonus_tok = jax.random.categorical(
        jax.random.fold_in(key, kk + 1), jnp.log(jnp.maximum(p[k_live], 1e-30))
    )
    final = jnp.where(n_acc >= k_live, bonus_tok, resid_tok)
    pos = jnp.arange(kk + 1)
    emitted = jnp.where(
        pos < n_acc,
        jnp.concatenate([draft_tokens, jnp.zeros((1,), draft_tokens.dtype)]),
        jnp.where(pos == n_acc, final.astype(draft_tokens.dtype), 0),
    )
    return n_acc + 1, emitted
