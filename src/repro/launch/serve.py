"""Serving CLI: single-batch oracle path and the continuous-batching engine.

``serve_batch`` is the sequential reference path (one fixed batch, lockstep
teacher-forced prefill + greedy decode) — it is the oracle the engine's
continuous-batching output is pinned against token-for-token. The
``--continuous`` mode dispatches to ``launch/engine.py``: slot-based
admission, interleaved/chunked prefill (batched multi-slot by default —
every request admitted in a scheduling round shares ONE prefill forward),
EOS/max-token retirement with immediate backfill, and per-request
temperature/top-k/top-p sampling (--temperature 0 = greedy). Both support
the Pallas flash-decode kernel (--use-kernel, interpret mode on CPU) and
sliding-window ring caches. Continuous mode serves from the shared PAGED
KV pool by default (--page-size/--num-pages tune it, --no-paged-cache
restores per-slot contiguous rings): sequences are bounded by pool pages
instead of a per-slot max_seq, and an undersized pool oversubscribes
memory with watermark admission + youngest-slot preemption. On top of the
pool, SHARED-PREFIX caching is default-on (--no-prefix-cache disables,
--prefix-cache-pages caps the index): retired prompts' full pages are
indexed in a radix trie and later requests with a common prefix alias the
same physical pages, prefilling only their uncached suffix — same tokens,
a fraction of the prefill FLOPs. Slots default to ring-equivalent logical
width; --long-requests widens every slot's page table to the whole pool.
--kv-dtype int8 stores pool pages quantized (per-token-slot per-kv-head
fp32 scales, dequantized inside the attend kernels) for ~4x the resident
sequences per HBM byte; --host-pages N adds a host-RAM tier under the
pool — preempted slots swap pages out and restore them with one copy
instead of recomputing, and evicted prefix pages demote/promote through
the same tier (--no-swap keeps only the prefix half).
Continuous mode also serves TENSOR-PARALLEL (--mesh N): attention heads and
the KV pool's kv-head slices split over an N-device ``model`` mesh through
``shard_map``, bitwise token-identical to the single-device engine; on CPU
pair it with --num-devices N (host-device override, set before jax inits).
--draft ARCH --spec-tokens K turns on speculative decoding: a cheap draft
model proposes K lookahead tokens per slot per round and the target
verifies all of them in ONE batched suffix-prefill dispatch — up to K+1
tokens emitted per target forward, greedy output bitwise identical to the
plain engine.
With --replicas N the trace is served through the fault-tolerant router
(``launch/router.py``): prefix-affinity + occupancy placement over N
engine replicas, SLO-aware preemption, and token-exact failover — inject
failures with --fault kill:R@S / stall:R@S / slow:R@S@SEC to watch
in-flight requests migrate without changing a single output token.

    # oracle (single fixed batch)
    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --batch 4 --prompt-len 32 --gen 32

    # continuous batching (slot pool + request queue)
    PYTHONPATH=src python -m repro.launch.serve --continuous \
        --arch stablelm-1.6b --slots 4 --requests 8 --stagger 0.05

    # tensor-parallel serving on a 2-shard CPU mesh
    PYTHONPATH=src python -m repro.launch.serve --continuous \
        --arch stablelm-1.6b --mesh 2 --num-devices 2
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _apply_num_devices_flag() -> None:
    """Honor ``--num-devices N`` BEFORE the jax import below — jax locks the
    host device count at first init (the constraint dryrun.py documents), so
    argparse in main() would see it too late. Argparse still owns the flag's
    help text and value; this peek only mirrors it into XLA_FLAGS."""
    argv = sys.argv[1:]
    n = 0
    for i, a in enumerate(argv):
        if a == "--num-devices" and i + 1 < len(argv):
            try:
                n = int(argv[i + 1])
            except ValueError:
                return  # argparse will report the bad value
        elif a.startswith("--num-devices="):
            try:
                n = int(a.split("=", 1)[1])
            except ValueError:
                return
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 0 and "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()


_apply_num_devices_flag()

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticCorpus
from repro.models import build_model
from repro.models import attention


def serve_batch(
    arch: str,
    *,
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen_tokens: int = 32,
    window: int = 0,
    use_kernel: bool = False,
    greedy: bool = True,
    seed: int = 0,
    log_fn=print,
) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.0)
    prompts = corpus.sample(
        jax.random.PRNGKey(seed + 1), jnp.ones(4) / 4, batch, prompt_len
    )["tokens"]

    attention.set_decode_kernel(use_kernel)
    try:
        max_seq = prompt_len + gen_tokens
        t0 = time.time()
        if cfg.arch_type == "audio":
            audio = jax.random.normal(
                jax.random.PRNGKey(seed + 2),
                (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype,
            )
            cache = model.init_cache(
                params, {"tokens": prompts, "audio_embeds": audio}, max_seq,
                window=window,
            )
            # teacher-force the prompt through decode (whisper has no prefill)
            dec = jax.jit(lambda p, c, t: model.decode(p, c, t, window=window))
            logits = None
            for i in range(prompt_len):
                cache, logits = dec(params, cache, prompts[:, i : i + 1])
        else:
            # build cache sized for the full generation, then teacher-force
            cache = model.init_cache(params, {"tokens": prompts}, max_seq, window=window)
            dec = jax.jit(lambda p, c, t: model.decode(p, c, t, window=window))
            logits = None
            for i in range(prompt_len):
                cache, logits = dec(params, cache, prompts[:, i : i + 1])
        t_prefill = time.time() - t0

        generated = []
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
        t0 = time.time()
        for _ in range(gen_tokens):
            generated.append(tok)
            cache, logits = dec(params, cache, tok)
            tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
        t_gen = time.time() - t0
    finally:
        attention.set_decode_kernel(False)

    gen = jnp.concatenate(generated, axis=1)
    result = {
        "arch": cfg.name,
        "batch": batch,
        "prompt_len": prompt_len,
        "gen_tokens": gen_tokens,
        "window": window,
        "use_kernel": use_kernel,
        "prefill_seconds": t_prefill,
        "decode_seconds": t_gen,
        "tokens_per_second": batch * gen_tokens / max(t_gen, 1e-9),
        "generated": np.asarray(gen).tolist(),
    }
    log_fn(
        f"{cfg.name}: prefill {prompt_len} tok in {t_prefill:.2f}s; "
        f"generated {gen_tokens} tok/seq × {batch} seqs in {t_gen:.2f}s "
        f"({result['tokens_per_second']:.1f} tok/s)"
    )
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window span (0 = full attention)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas flash-decode kernel (interpret mode on CPU)")
    ap.add_argument("--seed", type=int, default=0)
    # oracle mode
    ap.add_argument("--batch", type=int, default=4,
                    help="[oracle] fixed lockstep batch size")
    # continuous mode
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine instead of the oracle")
    ap.add_argument("--slots", type=int, default=4,
                    help="[continuous] KV-cache slot pool size")
    ap.add_argument("--requests", type=int, default=8,
                    help="[continuous] number of queued requests")
    ap.add_argument("--prefill", choices=("chunked", "interleaved"),
                    default="chunked", help="[continuous] prompt admission mode")
    ap.add_argument("--no-batch-prefill", dest="batch_prefill",
                    action="store_false",
                    help="[continuous] one prefill dispatch per request "
                    "instead of one per admission round")
    ap.add_argument("--no-bucket-prefill", dest="bucket_prefill",
                    action="store_false",
                    help="[continuous] disable shape-bucketed admission "
                    "rounds (compile one prefill per distinct round shape)")
    ap.add_argument("--no-paged-decode", dest="paged_decode",
                    action="store_false",
                    help="[continuous] with --use-kernel, use the unpaged "
                    "flash-decode kernel (full-ring attention per slot)")
    ap.add_argument("--no-donate-cache", dest="donate_cache",
                    action="store_false",
                    help="[continuous] functionally copy the KV cache "
                    "through each step instead of donating it in place")
    ap.add_argument("--no-paged-cache", dest="paged_cache",
                    action="store_false",
                    help="[continuous] per-slot contiguous ring KV caches "
                    "instead of the shared paged pool + page tables "
                    "(restores the prompt+gen <= max_seq admission guard)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="[continuous] tokens per physical KV page "
                    "(paged cache)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="[continuous] total physical pages incl. the "
                    "reserved scratch page (0 = ring-equivalent capacity); "
                    "undersize to oversubscribe memory — decode OOM "
                    "preempts the youngest slot")
    ap.add_argument("--watermark-pages", type=int, default=0,
                    help="[continuous] free pages admission must leave in "
                    "reserve while other slots are live (paged cache; "
                    "0 = pack the pool and rely on preemption)")
    ap.add_argument("--long-requests", action="store_true",
                    help="[continuous] give every slot whole-pool logical "
                    "width (table entries for all allocatable pages) "
                    "instead of the ring-equivalent default — serves "
                    "requests longer than num_slots would split, at "
                    "num_slots× the per-step jnp gather cost")
    # default=None distinguishes "user explicitly asked" (--prefix-cache,
    # validated below — an impossible config is an error, not a silent
    # no-op) from the advertised default-on (None → enabled when the
    # config supports it, with the engine logging why when it can't)
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false", default=None,
                    help="[continuous] disable shared-prefix KV reuse "
                    "(paged cache): every request prefills its full "
                    "prompt instead of mapping cached prefix pages and "
                    "prefilling only the uncached suffix")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true",
                    help="[continuous] require shared-prefix KV reuse "
                    "(default on with the paged cache when the config "
                    "supports it; explicit use errors out on a config "
                    "that can never honor it)")
    ap.add_argument("--prefix-cache-pages", type=int, default=0,
                    help="[continuous] cap on pool pages the prefix index "
                    "may pin (0 = the pool's allocatable capacity); "
                    "entries are LRU-evicted under pool pressure")
    ap.add_argument("--kv-dtype", choices=("fp", "int8"), default="fp",
                    help="[continuous] KV pool storage dtype (paged cache): "
                    "int8 stores pages quantized with per-token-slot per-"
                    "kv-head fp32 scales and dequantizes inside the attend "
                    "— ~4x the resident sequences per HBM byte vs fp32 "
                    "pools at near-identical output quality")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="[continuous] host-RAM page budget for the tiered "
                    "KV cache (paged cache; 0 = off): preempted slots swap "
                    "their pages to host and restore with one copy instead "
                    "of recomputing, and LRU-evicted prefix pages demote/"
                    "promote through the same tier")
    ap.add_argument("--no-swap", dest="swap", action="store_false",
                    help="[continuous] with --host-pages, keep prefix "
                    "demote/promote but resume preemptions by recompute "
                    "instead of swap-in")
    ap.add_argument("--draft", default=None, metavar="ARCH",
                    help="[continuous] speculative decoding: config name of "
                    "the cheap DRAFT model that proposes --spec-tokens "
                    "lookahead tokens per slot per round, verified by the "
                    "target in one batched dispatch; greedy output stays "
                    "bitwise identical to the non-speculative engine")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="[continuous] draft lookahead depth k per round "
                    "(requires --draft; an accepted round emits up to k+1 "
                    "tokens for one target dispatch)")
    ap.add_argument("--stagger", type=float, default=0.0,
                    help="[continuous] inter-arrival spacing in seconds")
    ap.add_argument("--replicas", type=int, default=1,
                    help="[continuous] serve through a fault-tolerant "
                    "router over this many engine replicas (prefix-"
                    "affinity + occupancy routing, token-exact failover); "
                    "1 = single engine, no router")
    ap.add_argument("--fault", action="append", default=None,
                    metavar="KIND:R@S",
                    help="[router] inject a fault: kill:R@S / stall:R@S / "
                    "slow:R@S@SEC (replica R at its own step S); "
                    "repeatable — specs compose one FaultPlan")
    ap.add_argument("--max-wall-s", type=float, default=0.0,
                    help="[continuous] per-request wall-clock watchdog: "
                    "retire a slot that exceeds this with a structured "
                    "timeout result (0 = off)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="[continuous] serve tensor-parallel over this many "
                    "model-axis shards (0 = single device); n_heads and "
                    "n_kv_heads must divide by it; output is bitwise "
                    "token-identical to the unsharded engine")
    ap.add_argument("--num-devices", type=int, default=0,
                    help="force this many host platform devices "
                    "(--xla_force_host_platform_device_count, applied "
                    "before jax initializes — CPU mesh simulation)")
    # sampling (0 temperature = greedy; per-request streams derive from
    # --seed + uid so every request samples independently)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="[continuous] sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="[continuous] keep the k most likely tokens (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="[continuous] nucleus sampling mass (1.0 = off)")
    args = ap.parse_args(argv)
    if args.mesh > 0:
        if not args.continuous:
            ap.error("--mesh requires --continuous (tensor-parallel serving "
                     "is an engine path)")
        if len(jax.devices()) < args.mesh:
            ap.error(
                f"--mesh {args.mesh} needs {args.mesh} devices, found "
                f"{len(jax.devices())}; pass --num-devices {args.mesh} "
                "(CPU host-device override) or run on a larger host"
            )
    if args.replicas > 1 and not args.continuous:
        ap.error("--replicas requires --continuous (the router fronts "
                 "continuous-batching engine replicas)")
    if args.replicas > 1 and args.mesh > 0:
        ap.error("--replicas with --mesh is not supported yet: the router "
                 "builds single-device replicas (data-parallel across "
                 "replicas, not tensor-parallel within one)")
    if args.fault and args.replicas <= 1:
        ap.error("--fault requires --replicas > 1 (fault injection is a "
                 "router harness; a single engine has nowhere to fail "
                 "over to)")
    if args.temperature <= 0 and (args.top_k > 0 or args.top_p < 1.0):
        ap.error("--top-k/--top-p require --temperature > 0 "
                 "(temperature 0 is greedy decoding)")
    if args.temperature > 0 and not args.continuous:
        ap.error("sampling flags require --continuous "
                 "(the serve_batch oracle is greedy by construction)")
    if args.prefix_cache:  # explicit --prefix-cache: fail fast, not silent
        blockers = []
        if not args.continuous:
            blockers.append("batch mode (use --continuous)")
        if not args.paged_cache:
            blockers.append(
                "--no-paged-cache (prefix sharing rides the page table)"
            )
        if args.window > 0:
            blockers.append(
                f"--window {args.window} (sliding-window ring wraps; "
                "prefix pages would be overwritten)"
            )
        if args.prefill == "interleaved":
            blockers.append(
                "--prefill interleaved (suffix rounds need chunked "
                "batched admission)"
            )
        if blockers:
            ap.error(
                "--prefix-cache cannot be honored by this config: "
                + "; ".join(blockers)
            )
    # same fail-fast contract as --prefix-cache: a flag the engine would
    # have to silently ignore is a config error, not a degraded run
    if args.kv_dtype != "fp":
        blockers = []
        if not args.continuous:
            blockers.append("batch mode (use --continuous)")
        if not args.paged_cache:
            blockers.append(
                "--no-paged-cache (int8 KV quantizes POOL pages; the "
                "contiguous ring cache stays fp)"
            )
        if args.replicas > 1:
            blockers.append(
                "--replicas (router replicas build fp pools; int8 "
                "replica pools are not wired yet)"
            )
        if blockers:
            ap.error(
                f"--kv-dtype {args.kv_dtype} cannot be honored by this "
                "config: " + "; ".join(blockers)
            )
    if args.host_pages > 0:
        blockers = []
        if not args.continuous:
            blockers.append("batch mode (use --continuous)")
        if not args.paged_cache:
            blockers.append(
                "--no-paged-cache (the host tier backs the page pool)"
            )
        if args.mesh > 0:
            blockers.append(
                f"--mesh {args.mesh} (KV pool is sharded; the host tier "
                "assumes a single-device pool)"
            )
        if args.replicas > 1:
            blockers.append(
                "--replicas (router replicas manage their own pools; "
                "per-replica host tiers are not wired yet)"
            )
        if blockers:
            ap.error(
                f"--host-pages {args.host_pages} cannot be honored by "
                "this config: " + "; ".join(blockers)
            )
    if args.draft is not None or args.spec_tokens > 0:
        blockers = []
        if args.draft is None:
            blockers.append("--spec-tokens without --draft (the lookahead "
                            "depth needs a draft model to propose it)")
        if args.spec_tokens <= 0:
            blockers.append("--draft without --spec-tokens >= 1 (a draft "
                            "with no lookahead depth proposes nothing)")
        if not args.continuous:
            blockers.append("batch mode (use --continuous)")
        if not args.paged_cache:
            blockers.append(
                "--no-paged-cache (k-token verify rides the suffix-"
                "prefill path over the page table)"
            )
        if args.prefill == "interleaved":
            blockers.append(
                "--prefill interleaved (the verify dispatch needs chunked "
                "batched admission)"
            )
        if args.window > 0:
            blockers.append(
                f"--window {args.window} (verify positions assume the "
                "full-context page layout)"
            )
        if args.mesh > 0:
            blockers.append(
                f"--mesh {args.mesh} (the draft runs single-device; "
                "sharded verify is not wired yet)"
            )
        if args.replicas > 1:
            blockers.append(
                "--replicas (router replicas do not build draft models yet)"
            )
        if blockers:
            ap.error(
                "speculative decoding cannot be honored by this config: "
                + "; ".join(blockers)
            )
    if args.continuous:
        from repro.launch.engine import serve_continuous
        from repro.launch.sampling import SamplingParams

        sampling = None
        if args.temperature > 0:
            sampling = SamplingParams(
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, seed=args.seed,
            )
        if args.replicas > 1:
            from repro.launch.router import (
                parse_fault_spec, serve_router_continuous,
            )

            return serve_router_continuous(
                args.arch, smoke=args.smoke, replicas=args.replicas,
                num_slots=args.slots, n_requests=args.requests,
                prompt_len=args.prompt_len, gen_tokens=args.gen,
                window=args.window, use_kernel=args.use_kernel,
                paged_cache=args.paged_cache, page_size=args.page_size,
                num_pages=args.num_pages,
                watermark_pages=args.watermark_pages,
                prefix_cache=args.prefix_cache is not False,
                sampling=sampling,
                fault_plan=(
                    parse_fault_spec(args.fault) if args.fault else None
                ),
                seed=args.seed, stagger=args.stagger,
                max_wall_s=args.max_wall_s,
            )
        return serve_continuous(
            args.arch, smoke=args.smoke, num_slots=args.slots,
            n_requests=args.requests, prompt_len=args.prompt_len,
            gen_tokens=args.gen, window=args.window,
            use_kernel=args.use_kernel, prefill=args.prefill,
            batch_prefill=args.batch_prefill,
            bucket_prefill=args.bucket_prefill,
            paged_decode=args.paged_decode,
            donate_cache=args.donate_cache,
            paged_cache=args.paged_cache,
            page_size=args.page_size,
            num_pages=args.num_pages,
            long_requests=args.long_requests,
            watermark_pages=args.watermark_pages,
            prefix_cache=args.prefix_cache is not False,  # None = default on
            prefix_cache_pages=args.prefix_cache_pages,
            kv_dtype=args.kv_dtype,
            host_pages=args.host_pages,
            swap=args.swap,
            num_shards=args.mesh,
            draft=args.draft,
            spec_tokens=args.spec_tokens,
            sampling=sampling,
            seed=args.seed, stagger=args.stagger,
            max_wall_s=args.max_wall_s,
        )
    return serve_batch(
        args.arch, smoke=args.smoke, batch=args.batch,
        prompt_len=args.prompt_len, gen_tokens=args.gen,
        window=args.window, use_kernel=args.use_kernel, seed=args.seed,
    )


if __name__ == "__main__":
    main()
