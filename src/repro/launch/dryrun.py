import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count at first init. This also means this module must not be
# imported by code that wants real single-device CPU semantics.

DOC = """Multi-pod dry-run: AOT lower + compile every (architecture × input-shape ×
mesh) combination and extract the roofline terms.

No arrays are ever allocated: inputs are ShapeDtypeStructs, outputs are the
compiled executable's memory/cost analyses plus the collective traffic
parsed from its HLO. This is the proof that the distribution config is
coherent — a sharding mismatch, a compile-time OOM, or an unsupported
collective fails here.

Usage:
    python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --multi-pod both \
        --out experiments/dryrun.jsonl
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, get_shape
from repro.configs.base import FederatedConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.launch import mesh as meshlib
from repro.launch import specs as speclib
from repro.launch.steps import (
    decode_window_for,
    make_decode_step,
    make_federated_step,
    make_prefill_step,
    make_train_step,
)
from repro.models import build_model
from repro.models.sharding import DEFAULT_RULES, ShardingRules, use_rules
from repro.utils import hlo as hlolib

SDS = jax.ShapeDtypeStruct


def _ns(mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _rules_for(mesh, kind: str = "training", cfg=None) -> ShardingRules:
    rules = dict(DEFAULT_RULES)
    if cfg is not None and cfg.pure_dp:
        # no tensor parallelism: every model-axis mapping goes away and the
        # batch dimension claims both intra-pod axes.
        rules = {k: None for k in rules}
        dp = ("data", "model")
        if "pod" in mesh.axis_names and kind in ("prefill", "decode"):
            dp = ("pod", "data", "model")
        rules["batch"] = dp
        return ShardingRules(mesh, rules)
    if "pod" in mesh.axis_names:
        rules["cache_seq"] = ("pod", "data")
        if kind in ("prefill", "decode"):
            # Serving has no federated (divergent-replica) pod semantics: the
            # pod axis is just more data parallelism. Shard batch over
            # (pod, data) to MATCH cache_pspec — a bare "data" here makes
            # every in-step constraint contradict the cache in_shardings and
            # XLA "involuntarily rematerializes" (cross-pod all-gathers the
            # full KV cache, ~1.7 TB/dev on stablelm-12b decode_32k).
            # constrain()'s dedup then drops overlapping axes from cache_seq
            # when batch claims them (and vice versa for batch=1 long_500k).
            rules["batch"] = ("pod", "data")
    return ShardingRules(mesh, rules)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (training) / 2·N·D (forward-only), N = active params."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * speclib.text_len(cfg, shape)
    if shape.kind == "training":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# --------------------------------------------------------------- lower paths
def _effective_cfg(cfg, shape, mesh, *, federated: bool = False):
    """pure_dp needs the (per-pod) batch to cover BOTH intra-pod axes; when it
    cannot (e.g. 128-per-cloud over 16x16), fall back to the TP rule set
    rather than letting the model axis idle."""
    if not cfg.pure_dp:
        return cfg
    n_pods = meshlib.axis_size(mesh, "pod") if federated else 1
    dp = meshlib.axis_size(mesh, "data") * meshlib.axis_size(mesh, "model")
    per_pod = shape.global_batch // (n_pods or 1)
    if shape.kind != "training" and "pod" in mesh.axis_names and not federated:
        dp *= meshlib.axis_size(mesh, "pod")  # serving: pod is extra DP
    if per_pod % dp == 0 or per_pod == 1:     # batch=1 long-ctx: rules no-op
        return cfg
    return dataclasses.replace(cfg, pure_dp=False)


def lower_train(cfg, shape, mesh, microbatches):
    cfg = _effective_cfg(cfg, shape, mesh)
    model = build_model(cfg)
    params_s, opt_s = speclib.state_specs(model)
    batch_s = speclib.train_batch_specs(cfg, shape)

    p_pspec = meshlib.params_pspec_tree(params_s, cfg, mesh)
    o_pspec = meshlib.opt_pspec_tree(opt_s, p_pspec, mesh)
    b_pspec = meshlib.batch_pspec(batch_s, mesh, pure_dp=cfg.pure_dp)

    train_cfg = TrainConfig(seq_len=shape.seq_len, global_batch=shape.global_batch)
    step = make_train_step(
        model, train_cfg, microbatches, grad_shardings=_ns(mesh, p_pspec)
    )
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, p_pspec), _ns(mesh, o_pspec), _ns(mesh, b_pspec)),
        out_shardings=(
            _ns(mesh, p_pspec),
            _ns(mesh, o_pspec),
            NamedSharding(mesh, P()),
        ),
        donate_argnums=(0, 1),   # params/opt update in place (real deployment)
    )
    with use_rules(_rules_for(mesh, cfg=cfg)):
        return jitted.lower(params_s, opt_s, batch_s)


def lower_federated_train(cfg, shape, mesh, microbatches, fed_cfg=None):
    cfg = _effective_cfg(cfg, shape, mesh, federated=True)
    n_pods = meshlib.axis_size(mesh, "pod")
    model = build_model(cfg)
    fed_cfg = fed_cfg or FederatedConfig(
        n_clouds=n_pods, local_steps=4, aggregation="fedavg", compression="none"
    )
    train_cfg = TrainConfig(seq_len=shape.seq_len, global_batch=shape.global_batch)
    params_only = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_pspec = meshlib.params_pspec_tree(params_only, cfg, mesh)
    pod_p = meshlib.params_pspec_tree(params_only, cfg, mesh, prefix=("pod",))
    trainer, fed_step = make_federated_step(
        model, fed_cfg, train_cfg, microbatches,
        grad_shardings=_ns(mesh, p_pspec), mesh=mesh,
    )

    state_s = jax.eval_shape(trainer.init_state, jax.random.PRNGKey(0))

    state_pspec: dict[str, Any] = {
        "clouds": {
            "params": pod_p,
            "opt": {"m": pod_p, "v": pod_p, "count": P("pod")},
        },
        "global": {
            "params": p_pspec,
            "outer": jax.tree_util.tree_map(lambda _: P(), state_s["global"]["outer"]),
        },
        "sample_counts": P("pod"),
        "loss_accum": P("pod"),
        "step": P(),
        "rng": P(),
    }
    if "ef" in state_s:
        state_pspec["ef"] = pod_p
    batch_s = speclib.train_batch_specs(cfg, shape, n_pods=n_pods)
    b_pspec = meshlib.batch_pspec(batch_s, mesh, pod_stacked=True, pure_dp=cfg.pure_dp)

    jitted = jax.jit(
        fed_step,
        in_shardings=(_ns(mesh, state_pspec), _ns(mesh, b_pspec)),
        out_shardings=(_ns(mesh, state_pspec), NamedSharding(mesh, P())),
        donate_argnums=(0,),     # federated state updates in place
    )
    with use_rules(_rules_for(mesh, cfg=cfg)):
        return jitted.lower(state_s, batch_s)


def lower_prefill(cfg, shape, mesh):
    cfg = _effective_cfg(cfg, shape, mesh)
    model = build_model(cfg)
    params_s, _ = speclib.state_specs(model)
    batch_s = speclib.train_batch_specs(cfg, shape)
    batch_s.pop("labels")
    p_pspec = meshlib.params_pspec_tree(params_s, cfg, mesh)
    b_pspec = meshlib.batch_pspec(batch_s, mesh, pure_dp=cfg.pure_dp)

    step = make_prefill_step(model, shape)
    cache_s = jax.eval_shape(step, params_s, batch_s)[0]
    c_pspec = meshlib.cache_pspec(cache_s, cfg, mesh, shape.global_batch)
    logits_pspec = P(None, "model")

    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, p_pspec), _ns(mesh, b_pspec)),
        out_shardings=(_ns(mesh, c_pspec), NamedSharding(mesh, logits_pspec)),
    )
    with use_rules(_rules_for(mesh, "prefill", cfg=cfg)):
        return jitted.lower(params_s, batch_s)


def lower_decode(cfg, shape, mesh):
    cfg = _effective_cfg(cfg, shape, mesh)
    model = build_model(cfg)
    params_s, _ = speclib.state_specs(model)
    window = decode_window_for(cfg, shape)
    cache_s = speclib.cache_specs(model, cfg, shape, window)
    tokens_s = speclib.decode_token_specs(shape)

    p_pspec = meshlib.params_pspec_tree(params_s, cfg, mesh)
    c_pspec = meshlib.cache_pspec(cache_s, cfg, mesh, shape.global_batch)
    t_pspec = meshlib.batch_pspec({"tokens": tokens_s}, mesh, pure_dp=cfg.pure_dp)["tokens"]

    step = make_decode_step(model, window)
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, p_pspec), _ns(mesh, c_pspec), NamedSharding(mesh, t_pspec)),
        out_shardings=(_ns(mesh, c_pspec), NamedSharding(mesh, P(None, "model"))),
        donate_argnums=(1,),     # KV cache updates in place
    )
    with use_rules(_rules_for(mesh, "decode", cfg=cfg)):
        return jitted.lower(params_s, cache_s, tokens_s)


# ------------------------------------------------------------------ analysis
def analyse(lowered, compiled, mesh, cfg, shape, *, seconds: float) -> dict:
    n_dev = mesh.devices.size
    # devices per pod — cross-pod classification must follow the actual mesh
    # (the production pod is 256 chips, but tests run tiny meshes)
    pod_size = (
        n_dev // meshlib.axis_size(mesh, "pod")
        if "pod" in mesh.axis_names else 0
    )

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    try:
        hlo_text = compiled.as_text()
    except Exception:
        hlo_text = lowered.as_text()
    # trip-count-aware accounting (XLA's cost_analysis counts while bodies
    # once — see utils/hlo.py)
    hcost = hlolib.analyze(hlo_text, pod_size=pod_size)
    flops = max(hcost.flops, xla_flops)
    bytes_accessed = max(hcost.hbm_bytes, xla_bytes)
    coll = hcost

    mem = compiled.memory_analysis()
    mem_rec = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }

    mf = model_flops(cfg, shape)
    compute_term = flops / meshlib.PEAK_FLOPS
    memory_term = bytes_accessed / meshlib.HBM_BW
    ici_bytes = coll.link_bytes(cross_pod=False)
    dcn_bytes = coll.link_bytes(cross_pod=True)
    collective_term = ici_bytes / meshlib.ICI_BW + dcn_bytes / meshlib.DCN_BW

    terms = {
        "compute_s": compute_term,
        "memory_s": memory_term,
        "collective_s": collective_term,
        "ici_link_bytes": ici_bytes,
        "dcn_link_bytes": dcn_bytes,
        "n_collectives": coll.n_collectives(),
        "collectives_by_kind": coll.by_kind(),
        "xla_reported_flops": xla_flops,
        "xla_reported_bytes": xla_bytes,
    }
    dominant = max(
        ("compute", compute_term), ("memory", memory_term), ("collective", collective_term),
        key=lambda kv: kv[1],
    )[0]
    return {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / flops if flops else 0.0,
        "memory": mem_rec,
        "roofline": terms,
        "dominant": dominant,
        "compile_seconds": seconds,
        "devices": n_dev,
    }


def dryrun_pair(arch: str, shape_name: str, *, multi_pod: bool, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    data_ax = meshlib.axis_size(mesh, "data")
    n_pods = meshlib.axis_size(mesh, "pod")
    mb = speclib.microbatch_policy(cfg, shape, n_pods=n_pods, data_axis=data_ax)

    t0 = time.time()
    with mesh:
        if shape.kind == "training":
            if multi_pod:
                lowered = lower_federated_train(cfg, shape, mesh, mb)
            else:
                lowered = lower_train(cfg, shape, mesh, mb)
        elif shape.kind == "prefill":
            lowered = lower_prefill(cfg, shape, mesh)
        else:
            lowered = lower_decode(cfg, shape, mesh)
        compiled = lowered.compile()
    dt = time.time() - t0

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "microbatches": mb,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    rec.update(analyse(lowered, compiled, mesh, cfg, shape, seconds=dt))
    if verbose:
        print(compiled.memory_analysis())
        r = rec["roofline"]
        print(
            f"[{arch} × {shape_name} × {rec['mesh']}] mb={mb} "
            f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
            f"collective={r['collective_s']*1e3:.2f}ms dominant={rec['dominant']} "
            f"useful={rec['useful_flops_ratio']:.2f} compile={dt:.0f}s"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"], default="off")
    ap.add_argument("--out", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]

    done = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape_name, mesh_name) in done:
                    continue
                try:
                    rec = dryrun_pair(arch, shape_name, multi_pod=mp)
                except Exception as e:
                    traceback.print_exc()
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "error": f"{type(e).__name__}: {e}",
                    }
                    failures.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print(f"  {f_['arch']} × {f_['shape']} × {f_['mesh']}: {f_['error'][:120]}")
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
