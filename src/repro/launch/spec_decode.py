"""Draft-model backends for paged speculative decoding.

The engine's speculative round is draft-propose → single-dispatch verify →
accept/rollback (see ``ServeEngine._spec_round``). This module owns the
DRAFT side: a second, cheap model that runs k sequential decode steps per
round so the expensive target model can verify all k proposals in ONE
batched suffix-prefill dispatch. Two state layouts:

* ``TransformerDraft`` — the draft is a KV-cache architecture: it gets its
  own small per-slot contiguous ring (capacity ``cap + k + 1``, sized so a
  request at the engine's token limit still has k lookahead rows; no paging
  — draft KV is tiny). Rollback after a rejection is a masked pos
  truncation: the ring rows past the accepted point simply become invisible
  to the validity mask and are overwritten next round.
* ``XlstmDraft`` — the draft is recurrent (``arch_type == "ssm"``, e.g.
  ``xlstm_125m``): state cannot be truncated by position, so the propose
  scan stacks a state SNAPSHOT after every step and rollback gathers, per
  row, the snapshot just after the last accepted token
  (``xlstm.gather_snapshots``).

Both backends run FULL ``num_slots`` width every round — dead rows carry
length-0 / masked work — so each jit compiles for one width and the
engine's compile-count gating story is unchanged. The propose scan samples
with the same ``filter_logits`` chain the target's sampler uses, collecting
per-step filtered log-probs q (the acceptance test needs q(d) for the
Leviathan ratio); greedy rows take argmax and their q lanes are garbage by
construction (never read). Consumption invariant: after ``propose`` the
draft has consumed k+1 tokens past its row position (k proposals plus one
trailing step feeding the last draft, output discarded), so a fully
accepted row — k accepts + bonus token — rolls FORWARD to ``pos + k + 1``
without an extra dispatch; ``commit`` then truncates every row to its
accepted length.

Per-row PRNG discipline: the engine passes one subkey per row per round;
propose folds (sub, 1) then the step index, the engine's acceptance jit
folds (sub, 2) — disjoint streams, so draft draws never correlate with the
acceptance uniforms (which would break the rejection-sampling guarantee).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sampling import filter_logits
from repro.models import xlstm


def _propose_step(logits, t, keys, greedy, temps, topks, topps, vocab):
    """One propose step's token draw + filtered log-probs, all rows.

    Greedy rows take argmax of the RAW logits (bitwise the target engine's
    greedy draw on the same logits); sampled rows draw categorical from the
    filtered distribution with key fold (sub, 1, t). Greedy rows' filter
    runs at temperature 1.0 purely to keep their (unread) q lanes finite.
    """
    t_eff = jnp.where(greedy, 1.0, temps)
    flt = jax.vmap(
        lambda l, tt, tk, tp: filter_logits(l, tt, tk, tp, vocab)
    )(logits, t_eff, topks, topps)
    d_g = jnp.argmax(logits[:, :vocab], axis=-1)
    kt = jax.vmap(lambda k: jax.random.fold_in(jax.random.fold_in(k, 1), t))(
        keys
    )
    d_s = jax.vmap(jax.random.categorical)(kt, flt)
    d = jnp.where(greedy, d_g, d_s).astype(jnp.int32)
    return d, jax.nn.log_softmax(flt, axis=-1)


class TransformerDraft:
    """Ring-cache draft backend (KV architectures)."""

    kind = "ring"

    def __init__(
        self, model, params, *, num_slots, cap, spec_tokens, compiles,
        donate=True,
    ):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.spec_tokens = spec_tokens
        self.cap = cap + spec_tokens + 1
        self.cache = model.init_slot_cache(params, num_slots, self.cap)
        self._slots = jnp.arange(num_slots, dtype=jnp.int32)
        vocab = model.cfg.vocab_size
        kk = spec_tokens
        dn = (1,) if donate else ()

        def _prefill_fn(p, c, toks, lens, slots):
            compiles["draft_prefill"] += 1
            return model.prefill_slots(p, c, toks, lens, slots)

        self._prefill = jax.jit(_prefill_fn, donate_argnums=dn)

        def _propose_fn(p, c, feed, keys, greedy, temps, topks, topps):
            compiles["draft_propose"] += 1

            def step(carry, t):
                c, cur = carry
                c, logits = model.decode(p, c, cur[:, None])
                d, lq = _propose_step(
                    logits, t, keys, greedy, temps, topks, topps, vocab
                )
                return (c, d), (d, lq)

            (c, last), (ds, lq) = jax.lax.scan(
                step, (c, feed), jnp.arange(kk)
            )
            # trailing consumption of the last draft: a fully accepted row
            # needs the draft to have seen all k proposals next round
            c, _ = model.decode(p, c, last[:, None])
            return c, ds.swapaxes(0, 1), lq.swapaxes(0, 1)

        self._propose = jax.jit(_propose_fn, donate_argnums=dn)

        def _commit_fn(c, new_pos, mask):
            return {**c, "pos": jnp.where(mask, new_pos, c["pos"])}

        self._commit = jax.jit(
            _commit_fn, donate_argnums=(0,) if donate else ()
        )

    def prefill_rows(self, tokens, lengths) -> None:
        """Re-sync rows with ``lengths > 0`` from scratch: row r's first
        ``lengths[r]`` tokens overwrite its ring from slot 0 and its pos
        resets to the true length; length-0 rows are untouched no-ops."""
        self.cache, _ = self._prefill(
            self.params, self.cache, tokens, lengths, self._slots
        )

    def propose(self, feed, keys, greedy, temps, topks, topps):
        """k draft tokens for every row. Returns (drafts (B,k) device,
        logq (B,k,V) device); the cache advances k+1 positions."""
        self.cache, drafts, logq = self._propose(
            self.params, self.cache, feed, keys, greedy, temps, topks, topps
        )
        return drafts, logq

    def commit(self, mask, new_pos, snap_idx) -> None:
        """Truncate rows in ``mask`` to their accepted position (covers
        both rollback and the fully-accepted forward case)."""
        del snap_idx
        self.cache = self._commit(self.cache, new_pos, mask)


class XlstmDraft:
    """Recurrent-state draft backend (``arch_type == "ssm"``)."""

    kind = "recurrent"

    def __init__(
        self, model, params, *, num_slots, cap, spec_tokens, compiles,
        donate=True,
    ):
        del cap  # recurrent state is O(1) in sequence length
        cfg = model.cfg
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.spec_tokens = spec_tokens
        self.cache = xlstm.init_decode_cache(cfg, num_slots, 1)
        self._snaps = None
        vocab = cfg.vocab_size
        kk = spec_tokens
        dn = (1,) if donate else ()
        empty = xlstm.init_decode_cache(cfg, num_slots, 1)

        def _prefill_fn(p, c, toks, lens):
            compiles["draft_prefill"] += 1
            # reset refreshed rows to the empty state, then teacher-force
            # the padded prompts; each row stops advancing at its own length
            c = xlstm.select_rows(lens > 0, empty, c)

            def step(c, xs):
                tok_t, t = xs
                c2, _ = xlstm.decode_step(cfg, p, c, tok_t[:, None])
                return xlstm.select_rows(t < lens, c2, c), None

            c, _ = jax.lax.scan(
                step, c, (toks.T, jnp.arange(toks.shape[1]))
            )
            return c

        self._prefill = jax.jit(_prefill_fn, donate_argnums=dn)

        def _propose_fn(p, c, feed, keys, greedy, temps, topks, topps):
            compiles["draft_propose"] += 1

            def step(carry, t):
                c, cur = carry
                c, logits = xlstm.decode_step(cfg, p, c, cur[:, None])
                d, lq = _propose_step(
                    logits, t, keys, greedy, temps, topks, topps, vocab
                )
                snap = {"periods": c["periods"], "rest": c["rest"]}
                return (c, d), (d, lq, snap)

            (c, last), (ds, lq, snaps) = jax.lax.scan(
                step, (c, feed), jnp.arange(kk)
            )
            c, _ = xlstm.decode_step(cfg, p, c, last[:, None])
            final = {"periods": c["periods"], "rest": c["rest"]}
            # snapshot s = state after consuming s+1 round tokens,
            # s in [0, k]: rollback target for n_emit = s+1
            snaps = jax.tree_util.tree_map(
                lambda s, f: jnp.concatenate([s, f[None]], axis=0),
                snaps, final,
            )
            return c, ds.swapaxes(0, 1), lq.swapaxes(0, 1), snaps

        self._propose = jax.jit(_propose_fn, donate_argnums=dn)

        def _commit_fn(snaps, idx):
            return xlstm.gather_snapshots(snaps, jnp.clip(idx, 0, kk))

        self._commit = jax.jit(
            _commit_fn, donate_argnums=(0,) if donate else ()
        )

    def prefill_rows(self, tokens, lengths) -> None:
        self.cache = self._prefill(self.params, self.cache, tokens, lengths)

    def propose(self, feed, keys, greedy, temps, topks, topps):
        self.cache, drafts, logq, self._snaps = self._propose(
            self.params, self.cache, feed, keys, greedy, temps, topks, topps
        )
        return drafts, logq

    def commit(self, mask, new_pos, snap_idx) -> None:
        """Restore every row from its accepted-point snapshot. Rows outside
        ``mask`` (no live slot this round) take an arbitrary valid snapshot
        — poison state a future ``prefill_rows`` reset fully overwrites."""
        del mask, new_pos
        assert self._snaps is not None, "commit without a propose round"
        self.cache = self._commit(self._snaps, snap_idx)
        self._snaps = None


def make_draft_backend(
    model, params, *, num_slots, cap, spec_tokens, compiles, donate=True,
):
    """Pick the draft state layout for a model: ring cache where the arch
    has the slot-cache API, recurrent snapshots for ssm archs."""
    if model.init_slot_cache is not None and model.prefill_slots is not None:
        cls = TransformerDraft
    elif model.cfg.arch_type == "ssm":
        cls = XlstmDraft
    else:
        raise ValueError(
            f"draft arch {model.cfg.name!r} ({model.cfg.arch_type}) has "
            "neither a slot-cache API nor recurrent decode state"
        )
    return cls(
        model, params, num_slots=num_slots, cap=cap,
        spec_tokens=spec_tokens, compiles=compiles, donate=donate,
    )
