"""Radix prefix index over the shared paged KV pool (prefix sharing).

The serve engine's page tables decouple a slot's logical token positions
from physical KV storage; this module adds the cross-request half of that
decoupling: a radix/trie index that keys FULL physical pages by the chain
of page-sized token chunks leading to them, so a new request whose prompt
starts with an already-served prefix maps those logical pages straight
onto the SAME physical pages instead of recomputing them.

Design
------
* One trie node per cached full page. A node's identity is the hash chain
  of token chunks from the root — implemented as nested dicts keyed by the
  exact ``page_size``-token tuple, which is a collision-proof hash chain
  (Python dict hashing on the chunk, scoped per parent). Partial tail
  pages are never indexed: only pages whose every token slot holds prompt
  KV are safe to alias.
* The index OWNS one pool reference per node (``PagePool.share`` at
  insert). A slot mapping a hit takes its own reference, so eviction of an
  index entry can never yank a page out from under a live request — the
  page simply leaves the index and dies when its last slot reference
  drops.
* Eviction is LRU over LEAVES: an interior node is pinned by its
  descendants (evicting it would orphan their hash chains). ``match`` and
  ``insert`` touch every node they traverse, so hot prefixes stay
  resident. ``evict(need)`` frees leaves until ``need`` pages actually
  reached the pool free list (a leaf whose page a live slot still shares
  leaves the index without freeing memory) or the index is empty — the
  engine calls it from watermark admission and decode-OOM before falling
  back to preemption, which is what lets a cache-hot pool degrade
  gracefully to the no-sharing engine instead of thrashing.
* ``max_pages`` caps the index footprint (``--prefix-cache-pages``);
  inserts beyond it evict LRU leaves first and simply stop publishing if
  nothing is evictable.

The index is pure host-side bookkeeping — it never touches device memory.
All device effects (table entries, COW page copies) live in the engine.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class _Node:
    chunk: tuple          # the page_size token ids this page holds
    page: int             # physical page id (index holds one pool ref)
    parent: "_Node | None"
    children: dict = dataclasses.field(default_factory=dict)
    last_used: int = 0


class PrefixCache:
    """Trie of published full pages over a ``PagePool``.

    Parameters
    ----------
    pool : the engine's ``PagePool`` (supplies ``page_size`` and holds the
        refcounts backing every cached page).
    max_pages : cap on cached pages; 0 means the pool's allocatable
        capacity (the index can never pin more than the pool holds).
    demote_fn : optional ``(prefix_tokens, page) -> None`` hook, called for
        a node leaving the index under LRU/pressure eviction (NOT on
        ``clear``) BEFORE its pool ref drops — the engine copies the page's
        content to the host tier there. ``prefix_tokens`` is the full token
        prefix the page caches (root chunk chain included).
    promote_fn : optional ``(prefix_tokens) -> int | None`` hook consulted
        when ``match`` walks off the indexed trie: a returned page id is a
        FRESHLY allocated pool page holding the demoted content (rc=1, the
        ref becomes the index's — mirror of ``insert``'s share), and the
        walk re-adopts it as a node and keeps matching. None = genuine miss.
    """

    def __init__(self, pool, max_pages: int = 0, *, demote_fn=None,
                 promote_fn=None):
        self.pool = pool
        self.page_size = pool.page_size
        self.max_pages = max_pages if max_pages > 0 else pool.capacity
        self.demote_fn = demote_fn
        self.promote_fn = promote_fn
        self._root = _Node(chunk=(), page=-1, parent=None)
        self._clock = itertools.count(1)
        self.size = 0  # pages currently indexed
        # cumulative counters (engine resets via reset_stats)
        self.hit_pages = 0
        self.lookups = 0
        self.inserted_pages = 0
        self.evicted_pages = 0

    # ------------------------------------------------------------- helpers
    def _chunks(self, tokens) -> Iterator[tuple]:
        toks = np.asarray(tokens).reshape(-1).tolist()
        for i in range(0, len(toks) - self.page_size + 1, self.page_size):
            yield tuple(toks[i : i + self.page_size])

    def _touch(self, node: _Node) -> None:
        node.last_used = next(self._clock)

    def _leaves(self) -> list[_Node]:
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def _prefix_tokens(self, node: _Node) -> tuple:
        """Full token prefix cached by ``node``: the chunk chain from the
        root, flattened — the host-tier key for demoted content."""
        chunks = []
        while node is not self._root:
            chunks.append(node.chunk)
            node = node.parent
        return tuple(t for chunk in reversed(chunks) for t in chunk)

    def _evict_node(self, node: _Node, *, demote: bool = True) -> None:
        assert not node.children, "only leaves are evictable"
        if demote and self.demote_fn is not None:
            self.demote_fn(self._prefix_tokens(node), node.page)
        del node.parent.children[node.chunk]
        self.pool.free([node.page])  # page dies iff no slot still shares it
        self.size -= 1
        self.evicted_pages += 1

    def _evict_lru_leaf(self, protect: set[int]) -> bool:
        victims = [n for n in self._leaves() if id(n) not in protect]
        if not victims:
            return False
        self._evict_node(min(victims, key=lambda n: n.last_used))
        return True

    # ----------------------------------------------------------------- api
    def probe(self, tokens) -> int:
        """READ-ONLY hit prediction: how many leading full pages of
        ``tokens`` are indexed. Unlike ``match`` it neither touches the
        LRU clock nor counts a lookup nor returns page ids — it exists so
        a multi-replica router can score cache affinity for a prompt on
        every replica without perturbing any replica's eviction order or
        hit-rate accounting."""
        node, pages = self._root, 0
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            pages += 1
            node = child
        return pages

    def match(self, tokens) -> list[int]:
        """Longest indexed prefix of ``tokens`` in full pages: physical
        page ids, in logical order. Touches the matched path (LRU).

        When the walk falls off the trie and a ``promote_fn`` is wired,
        the demoted tier gets one shot per chunk: a promoted page re-enters
        the index as a fresh node (its rc=1 ref becomes the index's) and
        the match keeps extending — LRU-evicting around the CURRENT path
        if the index is at its page cap, never through it."""
        self.lookups += 1
        node, pages = self._root, []
        toks = np.asarray(tokens).reshape(-1).tolist()
        path: set[int] = set()
        depth = 0
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None and self.promote_fn is not None:
                prefix = tuple(toks[: (depth + 1) * self.page_size])
                page = self.promote_fn(prefix)
                if page is not None:
                    ok = True
                    while self.size >= self.max_pages and ok:
                        ok = self._evict_lru_leaf(path)
                    if not ok:
                        # cap reached and every leaf is on the current
                        # path: drop the restored page (it's a cache)
                        self.pool.free([page])
                    else:
                        child = _Node(chunk=chunk, page=page, parent=node)
                        node.children[chunk] = child
                        self.size += 1
                        self.inserted_pages += 1
            if child is None:
                break
            self._touch(child)
            path.add(id(child))
            pages.append(child.page)
            node = child
            depth += 1
        self.hit_pages += len(pages)
        return pages

    def insert(self, tokens, pages: list[int]) -> int:
        """Publish ``tokens``'s full pages (page j holds tokens
        ``[j*page_size, (j+1)*page_size)``) into the index, taking one pool
        reference per NEWLY indexed page. Chunks already indexed keep their
        existing physical page (dedup — the caller's copy dies with the
        caller's refs). Returns the number of pages newly published."""
        node, added, path = self._root, 0, set()
        for chunk, page in zip(self._chunks(tokens), pages):
            child = node.children.get(chunk)
            if child is None:
                while self.size >= self.max_pages:
                    if not self._evict_lru_leaf(path):
                        return added  # index full of pinned/fresh pages
                self.pool.share(page)
                child = _Node(chunk=chunk, page=page, parent=node)
                node.children[chunk] = child
                self.size += 1
                added += 1
                self.inserted_pages += 1
            self._touch(child)
            path.add(id(child))
            node = child
        return added

    def evict(self, need: int) -> int:
        """Evict LRU leaves until ``need`` pages actually returned to the
        pool's free list, or the index is empty. Returns pages freed (an
        evicted page still shared by a live slot frees nothing yet).

        One trie walk total: the leaf set goes into a heap and parents are
        pushed as their last child dies, so a multi-page pressure event
        costs O(N + evicted·log N), not one full walk per page."""
        freed0 = self.pool.available
        heap = [(n.last_used, id(n), n) for n in self._leaves()]
        heapq.heapify(heap)
        while heap and self.pool.available - freed0 < need:
            _, _, node = heap[0]
            heapq.heappop(heap)
            parent = node.parent
            self._evict_node(node)
            if parent is not self._root and not parent.children:
                heapq.heappush(heap, (parent.last_used, id(parent), parent))
        return self.pool.available - freed0

    def clear(self) -> None:
        """Drop every entry (one pool ref each). Counters survive; the
        engine resets those separately. A reset is not memory pressure, so
        nothing demotes to the host tier."""
        for leaf in self._leaves():
            node = leaf
            while node is not self._root and not node.children:
                parent = node.parent
                self._evict_node(node, demote=False)
                node = parent

    def reset_stats(self) -> None:
        self.hit_pages = 0
        self.lookups = 0
        self.inserted_pages = 0
        self.evicted_pages = 0
