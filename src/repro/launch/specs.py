"""ShapeDtypeStruct input specs for every (architecture × input-shape) pair.

Nothing here allocates: specs stand in for real arrays so the dry-run can
``jax.jit(...).lower(**specs).compile()`` the full-size configs on a CPU
host. Modality carve-outs: VLM/audio specs include the precomputed
patch/frame embeddings from the stubbed frontends (vision tokens count
against the sequence budget, so text length = seq_len − vision_seq)."""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import ModelAPI

Pytree = Any

SDS = jax.ShapeDtypeStruct

ACTIVATION_BUDGET = 4e9  # target bytes of saved residuals per device


def text_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.arch_type == "vlm" and shape.kind == "training":
        return shape.seq_len - cfg.vision_seq
    return shape.seq_len


def train_batch_specs(
    cfg: ModelConfig, shape: ShapeConfig, *, n_pods: int = 1
) -> dict:
    """Batch specs. n_pods>1 → leading cloud axis (federated stacking)."""
    b = shape.global_batch
    s = text_len(cfg, shape)
    dt = jnp.dtype(cfg.dtype)

    def shaped(*dims, dtype=jnp.int32):
        if n_pods > 1:
            assert dims[0] % n_pods == 0, (dims, n_pods)
            dims = (n_pods, dims[0] // n_pods) + dims[1:]
        return SDS(dims, dtype)

    batch = {"tokens": shaped(b, s), "labels": shaped(b, s)}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = shaped(b, cfg.vision_seq, cfg.d_model, dtype=dt)
    if cfg.arch_type == "audio":
        batch["audio_embeds"] = shaped(b, cfg.encoder_seq, cfg.d_model, dtype=dt)
    return batch


def decode_token_specs(shape: ShapeConfig) -> SDS:
    return SDS((shape.global_batch, 1), jnp.int32)


def state_specs(model: ModelAPI, key=None) -> tuple[Pytree, Pytree]:
    """(params, adamw-state) ShapeDtypeStructs via eval_shape."""
    from repro.optim.adamw import adamw_init

    key = key if key is not None else jax.random.PRNGKey(0)
    params = jax.eval_shape(model.init, key)
    opt = jax.eval_shape(adamw_init, params)
    return params, opt


def cache_specs(
    model: ModelAPI, cfg: ModelConfig, shape: ShapeConfig, window: int
) -> Pytree:
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = train_batch_specs(cfg, shape)
    # decode batches need only tokens/audio_embeds shapes
    dec_batch = {"tokens": SDS((shape.global_batch, 1), jnp.int32)}
    if cfg.arch_type == "audio":
        dec_batch["audio_embeds"] = batch["audio_embeds"]

    def mk(params, b):
        return model.init_cache(params, b, shape.seq_len, window=window)

    return jax.eval_shape(mk, params, dec_batch)


def slot_cache_specs(
    model: ModelAPI, num_slots: int, max_seq: int, window: int = 0
) -> Pytree:
    """ShapeDtypeStructs for the continuous-batching engine's per-slot cache
    (per-row positions, shape (num_slots,)) — lets the dry-run size/lower the
    engine decode step without allocating."""
    if model.init_slot_cache is None:
        raise ValueError(f"{model.cfg.name}: no slot-cache API for this arch")
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def mk(params):
        return model.init_slot_cache(params, num_slots, max_seq, window=window)

    return jax.eval_shape(mk, params)


def paged_cache_specs(
    model: ModelAPI,
    num_slots: int,
    num_pages: int,
    page_size: int,
    table_width: int,
    window: int = 0,
    kv_dtype: str = "fp",
) -> Pytree:
    """ShapeDtypeStructs for the engine's SHARED paged KV pool + per-slot
    page tables — total KV bytes scale with ``num_pages``, not
    ``num_slots × max_seq``, which is the memory claim the dry-run sizes.
    ``kv_dtype="int8"`` sizes the quantized pool: int8 pages plus fp32
    per-token-slot per-kv-head scale planes (1/head_dim the page bytes)."""
    if model.init_paged_cache is None:
        raise ValueError(f"{model.cfg.name}: no paged-cache API for this arch")
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def mk(params):
        return model.init_paged_cache(
            params, num_slots, num_pages, page_size, table_width,
            window=window, kv_dtype=kv_dtype,
        )

    return jax.eval_shape(mk, params)


def draft_cache_specs(
    model: ModelAPI, num_slots: int, cap: int, spec_tokens: int
) -> Pytree:
    """ShapeDtypeStructs for a speculative-decoding DRAFT backend's state:
    the small per-slot ring a KV draft carries (capacity cap + k + 1 — the
    engine token limit plus k lookahead rows plus the trailing consumption
    step), or the O(1) recurrent state of an ssm draft. Sizes the memory a
    ``--draft`` flag adds on top of the target's pool."""
    from repro.models import xlstm

    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if model.init_slot_cache is not None:
        def mk(params):
            return model.init_slot_cache(
                params, num_slots, cap + spec_tokens + 1
            )

        return jax.eval_shape(mk, params)
    if model.cfg.arch_type == "ssm":
        return jax.eval_shape(
            lambda: xlstm.init_decode_cache(model.cfg, num_slots, 1)
        )
    raise ValueError(
        f"{model.cfg.name}: no draft state layout for this arch"
    )


def layers_for_memory(cfg: ModelConfig) -> int:
    n = cfg.n_layers
    if cfg.arch_type == "audio":
        n += cfg.encoder_layers
    return n


def microbatch_policy(
    cfg: ModelConfig, shape: ShapeConfig, *, n_pods: int = 1, data_axis: int = 16
) -> int:
    """Grad-accumulation chunks so saved residuals ≲ ACTIVATION_BUDGET/device.

    Saved live set under scan+remat ≈ L · B_local · S · D · 2 bytes (the
    per-layer residual carries); microbatching divides B_local."""
    if shape.kind != "training":
        return 1
    b_local = shape.global_batch // (n_pods * data_axis)
    if b_local == 0:
        return 1
    s = shape.seq_len
    saved = layers_for_memory(cfg) * b_local * s * cfg.d_model * 2
    k = max(1, math.ceil(saved / ACTIVATION_BUDGET))
    while b_local % k != 0:
        k += 1
    return min(k, b_local)
