"""Fault-tolerant multi-replica serve router: prefix-affinity routing,
SLO-aware scheduling, and token-exact failover.

``ServeRouter`` fronts N in-process ``ServeEngine`` replicas — the
serving-side incarnation of the paper's cross-cloud scheduling problem,
where any participating cloud can slow down, saturate, or drop out
mid-round. The router owns four behaviors, each mirroring a federated
robustness requirement:

* **Placement** (``submit`` → ``_place_pending``): requests route to the
  replica whose radix prefix index already holds the longest prefix of the
  prompt (cache-affinity — ``ServeEngine.prefix_probe`` walks the trie's
  page-chunk keys read-only, so hit prediction costs a few dict lookups,
  no prefill, and no LRU perturbation). With no predicted hit anywhere,
  the least-occupied replica wins (``pool_stats`` occupancy). A request no
  replica could EVER serve is rejected up front with a structured
  ``AdmissionError`` reporting the best-fit shortfall — the smallest
  margin by which any replica's pool falls short, not the first pool's.
* **Backpressure**: when every healthy replica is saturated (slots full
  AND its admission queue at the router's cap), placement holds the
  request in the router's own queue and retries with bounded backoff
  (``retries`` counts attempts; realtime runs sleep ``backoff_s`` ×
  attempt). After ``max_retries`` the request is force-placed on the
  least-occupied replica rather than erroring — saturation degrades to
  queueing, never to failure.
* **Fault tolerance**: a ``FaultPlan`` injects kill / stall / slow faults
  at deterministic per-replica step counts (the in-process stand-in for a
  cloud dropping out). The router's step loop health-checks every round:
  a KILL surfaces as ``ReplicaFault`` and is detected immediately; a
  STALL is detected by progress tracking (a replica with work whose
  observable state doesn't change for ``stall_patience`` consecutive
  rounds is declared hung — the router never reads the fault plan to
  decide health, only to inject). Either way the replica is marked dead
  and its ENTIRE in-flight population — live slots and queue — migrates
  through ``export_inflight``/``import_inflight``: requests with
  generated tokens re-enter a healthy replica via the preemption-resume
  re-prefill path, so the merged output streams are TOKEN-IDENTICAL
  (greedy and sampled) to a fault-free run. A SLOW replica is left alone:
  occupancy-based placement naturally shifts new work away from it.
* **SLO enforcement** rides the engine: per-request ``priority`` orders
  preemption (lowest-priority-then-youngest), ``deadline_s`` sheds
  expired queued requests with structured errors, and ``max_wall_s``
  watchdog-retires slots that stop advancing. ``router_stats`` aggregates
  per-replica occupancy, migrations, sheds, timeouts, and retries.

The replicas share one ``model``/``params`` (and the engine-level sampling
seed), so a request's PRNG stream — keyed by (seed, uid), advanced one
``jax.random.split`` per emitted token — is identical wherever it runs.
That, plus scheduling-invariance of the engine's per-row math, is why
failover can promise bitwise identity rather than "approximately resumes".

    PYTHONPATH=src python -m repro.launch.serve --continuous \
        --arch stablelm-1.6b --replicas 2 --fault kill:1@8
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.engine import (
    AdmissionError,
    Request,
    RequestOutput,
    ServeEngine,
    make_requests,
)
from repro.launch.sampling import SamplingParams
from repro.models import build_model


class ReplicaFault(RuntimeError):
    """Injected replica failure, surfaced at a router step boundary — the
    in-process stand-in for a cross-cloud worker process dying."""

    def __init__(self, replica: int, kind: str):
        super().__init__(f"replica {replica}: injected {kind}")
        self.replica = replica
        self.kind = kind


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault-injection schedule, keyed by each replica's own
    attempted-step counter (so a plan is reproducible regardless of how
    rounds interleave across replicas).

    ``kill[r] = k``: replica r's step k (and every later one) raises
    ``ReplicaFault`` — the process is gone. Permanent.
    ``stall[r] = k``: from step k the replica silently does nothing — the
    hung-process case the router must DETECT (no exception to catch).
    Permanent until the router gives up on it.
    ``slow[r] = (k, seconds)``: from step k every step first sleeps —
    the straggler case. Never fatal.

    Kill wins over stall wins over slow when one replica carries several.
    """

    kill: dict[int, int] = dataclasses.field(default_factory=dict)
    stall: dict[int, int] = dataclasses.field(default_factory=dict)
    slow: dict[int, tuple[int, float]] = dataclasses.field(
        default_factory=dict
    )

    def action(self, replica: int, step: int) -> tuple[str, float] | None:
        k = self.kill.get(replica)
        if k is not None and step >= k:
            return ("kill", 0.0)
        s = self.stall.get(replica)
        if s is not None and step >= s:
            return ("stall", 0.0)
        sl = self.slow.get(replica)
        if sl is not None and step >= sl[0]:
            return ("slow", sl[1])
        return None


def parse_fault_spec(specs) -> FaultPlan:
    """CLI fault grammar: ``kill:R@S`` / ``stall:R@S`` / ``slow:R@S@SEC``
    (replica R, per-replica step S). Several specs compose one plan."""
    plan = FaultPlan()
    for spec in specs or ():
        try:
            kind, rest = spec.split(":", 1)
            parts = rest.split("@")
            rid, step = int(parts[0]), int(parts[1])
            if kind == "kill":
                plan.kill[rid] = step
            elif kind == "stall":
                plan.stall[rid] = step
            elif kind == "slow":
                plan.slow[rid] = (step, float(parts[2]))
            else:
                raise ValueError(kind)
        except (ValueError, IndexError) as e:
            raise ValueError(
                f"bad fault spec {spec!r} (want kill:R@S, stall:R@S or "
                f"slow:R@S@SEC): {e}"
            ) from None
    return plan


class ServeRouter:
    """Router over N in-process ``ServeEngine`` replicas.

    Parameters
    ----------
    model, params : shared by every replica (identical params are what
        make failover token-exact). Ignored when ``engines`` is given.
    replicas : number of homogeneous replicas to build from
        ``engine_kw``.
    engines : pre-built replica list instead — may be HETEROGENEOUS
        (different pool sizes, meshes). Placement and the best-fit
        shortfall report handle mixed capacities.
    fault_plan : optional ``FaultPlan`` injected at step boundaries.
    stall_patience : consecutive no-progress rounds (on a replica with
        work) before the router declares it hung and migrates. Progress is
        judged from observable engine state only — finished/steps/queue
        counters and slot positions — never from the fault plan.
    max_retries : placement attempts while every candidate is saturated
        before force-placing on the least-occupied replica.
    backoff_s : realtime-mode sleep per failed placement attempt (scaled
        by the attempt count). Virtual-time runs skip the sleep — stepping
        the replicas IS the backoff.
    max_queue : per-replica queued-request cap that defines "saturated"
        (0 = 2 × that replica's ``num_slots``).
    engine_kw : forwarded to every built ``ServeEngine`` (num_slots,
        paged_cache, page_size, seed, max_wall_s, ...).
    """

    def __init__(
        self,
        model=None,
        params=None,
        *,
        replicas: int = 2,
        engines: list[ServeEngine] | None = None,
        fault_plan: FaultPlan | None = None,
        stall_patience: int = 3,
        max_retries: int = 8,
        backoff_s: float = 0.01,
        max_queue: int = 0,
        time_fn: Callable[[], float] | None = None,
        **engine_kw,
    ):
        if engines is not None:
            self.engines = list(engines)
        else:
            if model is None or params is None:
                raise ValueError("need model+params or pre-built engines")
            self.engines = [
                ServeEngine(model, params, time_fn=time_fn, **engine_kw)
                for _ in range(replicas)
            ]
        if not self.engines:
            raise ValueError("router needs at least one replica")
        n = len(self.engines)
        self.fault_plan = fault_plan
        self.stall_patience = stall_patience
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.max_queue = max_queue
        self._time_fn = time_fn or time.monotonic
        self._t0 = self._time_fn()
        self._realtime = False

        self.healthy = [True] * n
        self.fail_reason: list[str | None] = [None] * n
        self._steps = [0] * n          # attempted steps — the fault clock
        self._sig: list[tuple | None] = [None] * n
        self._no_progress = [0] * n

        self.pending: collections.deque[Request] = collections.deque()
        self._attempts: dict[int, int] = {}   # uid -> placement attempts
        self.finished: list[RequestOutput] = []
        self.shed: list[AdmissionError] = []  # router-level sheds only

        self.migrations = 0            # replica-death events that moved work
        self.migrated_requests = 0
        self.retries = 0
        self.forced_placements = 0
        self.affinity_routed = 0
        self.balance_routed = 0
        self.replica_requests = [0] * n

    # ------------------------------------------------------------- plumbing
    def _now(self) -> float:
        return self._time_fn() - self._t0

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or any(
            e.has_work for e, h in zip(self.engines, self.healthy) if h
        )

    def occupancy(self, rid: int) -> float:
        """Replica load fraction: paged-pool fill, or live-slot fraction
        for ring replicas (which have no pool)."""
        e = self.engines[rid]
        if e.paged_cache:
            return e.pool.in_use / max(e.pool.capacity, 1)
        return e.active_slots / max(e.num_slots, 1)

    def _queue_cap(self, rid: int) -> int:
        return self.max_queue or 2 * self.engines[rid].num_slots

    def _saturated(self, rid: int) -> bool:
        """A replica is saturated when its total uncompleted load — live
        slots plus queued admissions — fills the slots AND the queue cap.
        Counting load (not stepped state) keeps one burst from dumping
        every request on a replica that merely hasn't stepped yet."""
        e = self.engines[rid]
        return (
            e.active_slots + len(e.waiting)
            >= e.num_slots + self._queue_cap(rid)
        )

    def warm(self, prompt_lens, **kw) -> None:
        """Warm every replica's jit caches, then restart all engine clocks
        at ONE instant — sequential warming would otherwise skew the
        replicas' relative clocks (deadlines and latency metrics compare
        across replicas)."""
        for e in self.engines:
            e.warm(prompt_lens, **kw)
        for e in self.engines:
            e.reset_clock()
        self._t0 = self._time_fn()

    # ------------------------------------------------------------ admission
    def submit(self, req: Request) -> None:
        """Accept a request, or reject it with the BEST-FIT shortfall when
        no replica could ever serve it. Unlike a single engine's
        ``submit`` — which rejects against one pool — the router probes
        every replica (including heterogeneous ones with larger pools)
        before giving up, and the error names the closest fit."""
        shorts = [e.capacity_shortfall(req) for e in self.engines]
        if min(shorts) > 0:
            best = int(np.argmin(shorts))
            raise AdmissionError(
                req.uid, "exceeds_pool",
                f"request {req.uid}: prompt {len(req.prompt)} + gen "
                f"{req.max_new_tokens} exceeds every replica's capacity; "
                f"best fit is replica {best}, short {shorts[best]} tokens "
                f"(per-replica shortfalls: {shorts})",
            )
        self.pending.append(req)

    def _choose_replica(self, req: Request, candidates: list[int]) -> int:
        """Affinity first: the candidate whose prefix index predicts the
        deepest hit for this prompt (read-only probe). No predicted hit
        anywhere → least occupied, ties to the least-routed replica."""
        hits = [
            (self.engines[rid].prefix_probe(req.prompt), rid)
            for rid in candidates
        ]
        best_hit = max(h for h, _ in hits)
        if best_hit > 0:
            rid = max(hits, key=lambda t: (t[0], -self.occupancy(t[1])))[1]
            self.affinity_routed += 1
            return rid
        self.balance_routed += 1
        return min(
            candidates,
            key=lambda rid: (
                self.occupancy(rid),
                self.replica_requests[rid],
                rid,
            ),
        )

    def _place_pending(self) -> None:
        """Move router-queued requests onto replicas, FIFO. Stops at the
        first request it cannot place this round (later arrivals must not
        jump an earlier one under backpressure)."""
        now = self._now()
        while self.pending:
            req = self.pending[0]
            if self._realtime and req.arrival_time > now:
                break
            capable = [
                rid
                for rid, e in enumerate(self.engines)
                if self.healthy[rid] and e.capacity_shortfall(req) == 0
            ]
            if not capable:
                # every replica that could hold it is dead; erroring the
                # whole run would drop the healthy replicas' work, so the
                # request is shed with a structured record instead
                self.pending.popleft()
                self.shed.append(AdmissionError(
                    req.uid, "no_healthy_replica",
                    f"request {req.uid}: every replica with capacity for "
                    "it has failed",
                ))
                continue
            free = [rid for rid in capable if not self._saturated(rid)]
            if not free:
                attempts = self._attempts.get(req.uid, 0) + 1
                self._attempts[req.uid] = attempts
                self.retries += 1
                if attempts <= self.max_retries:
                    if self._realtime and self.backoff_s > 0:
                        time.sleep(self.backoff_s * attempts)
                    break  # hold the queue; replicas drain, we retry
                free = capable  # bounded retry exhausted: force-place
                self.forced_placements += 1
            rid = self._choose_replica(req, free)
            self.pending.popleft()
            self.engines[rid].submit(req)
            self.replica_requests[rid] += 1

    # --------------------------------------------------------- health/fault
    def _progress_sig(self, e: ServeEngine) -> tuple:
        """Observable engine state a healthy step must change: counters
        plus per-slot write positions. Deliberately excludes anything the
        fault plan knows — stall detection has to be honest."""
        return (
            len(e.finished), e.steps, e.prefill_dispatches,
            len(e.waiting), e.shed_requests, e.timeouts, e.preemptions,
            tuple(s.pos_host if s is not None else -1 for s in e.slots),
        )

    def _note_progress(self, rid: int) -> None:
        e = self.engines[rid]
        sig = self._progress_sig(e)
        if not e.has_work:
            self._no_progress[rid] = 0
        elif self._realtime and e.active_slots == 0 and (
            (nxt := e.next_arrival()) is not None and nxt > self._now()
        ):
            self._no_progress[rid] = 0  # idle awaiting a future arrival
        elif sig == self._sig[rid]:
            self._no_progress[rid] += 1
            if self._no_progress[rid] >= self.stall_patience:
                self._mark_dead(rid, "stalled (no progress)")
        else:
            self._no_progress[rid] = 0
        self._sig[rid] = sig

    def _mark_dead(self, rid: int, why: str) -> None:
        """Retire a replica and migrate its whole in-flight population to
        the survivors. Host-side resume state is all that crosses; KV is
        re-derived by resume re-prefill on the target, which keeps the
        merged streams token-identical."""
        self.healthy[rid] = False
        self.fail_reason[rid] = why
        items = self.engines[rid].export_inflight()
        if not items:
            return
        if not any(self.healthy):
            raise RuntimeError(
                f"replica {rid} failed ({why}) with {len(items)} requests "
                "in flight and no healthy replica remains"
            )
        self.migrations += 1
        self.migrated_requests += len(items)
        # group per chosen target, order preserved (import prepends the
        # whole group at the target's queue head)
        per_target: dict[int, list] = {}
        for req, resume in items:
            capable = [
                r for r, e in enumerate(self.engines)
                if self.healthy[r] and e.capacity_shortfall(req) == 0
            ]
            if not capable:
                self.shed.append(AdmissionError(
                    req.uid, "no_healthy_replica",
                    f"request {req.uid}: migrated off replica {rid} but no "
                    "healthy replica has capacity for it",
                ))
                continue
            # saturation is ignored here: migrated work is the oldest in
            # the system and queues at the head wherever it lands
            t = self._choose_replica(req, capable)
            per_target.setdefault(t, []).append((req, resume))
            self.replica_requests[t] += 1
        for t, group in per_target.items():
            self.engines[t].import_inflight(group)

    def _step_replicas(self) -> list[RequestOutput]:
        """One router round: step every healthy replica that has work,
        injecting scheduled faults at the boundary, and health-check each.
        Returns the requests that finished this round."""
        done: list[RequestOutput] = []
        for rid, e in enumerate(self.engines):
            if not self.healthy[rid] or not e.has_work:
                continue
            act = (
                self.fault_plan.action(rid, self._steps[rid])
                if self.fault_plan is not None
                else None
            )
            self._steps[rid] += 1
            try:
                if act is not None and act[0] == "kill":
                    raise ReplicaFault(rid, "kill")
                if act is not None and act[0] == "stall":
                    self._note_progress(rid)  # nothing ran: sig frozen
                    continue
                if act is not None and act[0] == "slow":
                    time.sleep(act[1])
                done.extend(e.step(respect_arrivals=self._realtime))
            except ReplicaFault as f:
                self._mark_dead(rid, f"killed (injected at step "
                                     f"{self._steps[rid] - 1}): {f}")
                continue
            self._note_progress(rid)
        return done

    # ------------------------------------------------------------------ run
    def step(self) -> list[RequestOutput]:
        """One scheduling round: place pending requests, step replicas,
        health-check. Composable for callers driving their own loop."""
        self._place_pending()
        outs = self._step_replicas()
        self.finished.extend(outs)
        return outs

    def run(
        self, requests=(), *, realtime: bool = False
    ) -> list[RequestOutput]:
        """Drain ``requests`` (plus anything pending) to completion across
        the replica fleet. Completed outputs merge across replicas and
        migrations; shed requests (deadline, no-healthy-replica) appear in
        ``shed_errors``, never here."""
        for req in sorted(requests, key=lambda r: r.arrival_time):
            self.submit(req)
        self._realtime = realtime
        while self.has_work:
            if not any(self.healthy):
                raise RuntimeError("every replica has failed")
            if realtime and all(
                e.active_slots == 0
                for e, h in zip(self.engines, self.healthy) if h
            ):
                nxts = [
                    t for e, h in zip(self.engines, self.healthy)
                    if h
                    for t in [e.next_arrival()] if t is not None
                ]
                if not self.pending and nxts:
                    delay = min(nxts) - self._now()
                    if delay > 0:
                        time.sleep(delay)
            self.step()
        return sorted(self.finished, key=lambda o: o.uid)

    # ------------------------------------------------------------- metrics
    @property
    def shed_errors(self) -> list[AdmissionError]:
        """Every structured shed across the system: router-level (no
        healthy replica) plus each replica's deadline sheds."""
        out = list(self.shed)
        for e in self.engines:
            out.extend(e.shed)
        return out

    @property
    def router_stats(self) -> dict:
        return {
            "replicas": len(self.engines),
            "healthy": list(self.healthy),
            "fail_reasons": list(self.fail_reason),
            "occupancy": [
                self.occupancy(rid) for rid in range(len(self.engines))
            ],
            "active_slots": [e.active_slots for e in self.engines],
            "queued": [len(e.waiting) for e in self.engines],
            "replica_requests": list(self.replica_requests),
            "replica_steps": list(self._steps),
            "migrations": self.migrations,
            "migrated_requests": self.migrated_requests,
            "shed_requests": len(self.shed)
            + sum(e.shed_requests for e in self.engines),
            "timeouts": sum(e.timeouts for e in self.engines),
            "preemptions": sum(e.preemptions for e in self.engines),
            "retries": self.retries,
            "forced_placements": self.forced_placements,
            "affinity_routed": self.affinity_routed,
            "balance_routed": self.balance_routed,
        }


# ----------------------------------------------------------------- serving
def serve_router_continuous(
    arch: str,
    *,
    smoke: bool = True,
    replicas: int = 2,
    num_slots: int = 4,
    n_requests: int = 8,
    prompt_len: int = 32,
    gen_tokens: int = 32,
    window: int = 0,
    use_kernel: bool = False,
    paged_cache: bool = True,
    page_size: int = 16,
    num_pages: int = 0,
    watermark_pages: int = 0,
    prefix_cache: bool = True,
    sampling: SamplingParams | None = None,
    fault_plan: FaultPlan | None = None,
    seed: int = 0,
    stagger: float = 0.0,
    max_wall_s: float = 0.0,
    log_fn=print,
) -> dict:
    """Build ONE model + N engine replicas behind a ``ServeRouter``, serve
    a synthetic trace (optionally under an injected fault plan), report
    merged throughput and the router's robustness counters."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    router = ServeRouter(
        model,
        params,
        replicas=replicas,
        fault_plan=fault_plan,
        num_slots=num_slots,
        max_seq=prompt_len + gen_tokens,
        window=window,
        use_kernel=use_kernel,
        paged_cache=paged_cache,
        page_size=page_size,
        num_pages=num_pages,
        watermark_pages=watermark_pages,
        prefix_cache=prefix_cache,
        seed=seed,
        max_wall_s=max_wall_s,
    )
    reqs = make_requests(
        cfg, n_requests=n_requests, prompt_len=prompt_len,
        gen_tokens=gen_tokens, seed=seed, stagger=stagger,
    )
    if sampling is not None and not sampling.is_greedy:
        for r in reqs:
            r.sampling = dataclasses.replace(
                sampling,
                seed=None if sampling.seed is None else sampling.seed + r.uid,
            )
    router.warm(
        [prompt_len], gen_tokens=min(2, gen_tokens), sampling=sampling
    )
    t0 = time.time()
    outs = router.run(reqs, realtime=stagger > 0)
    wall = time.time() - t0
    total = sum(len(o.tokens) for o in outs)
    lat = [o.latency for o in outs] or [0.0]
    rs = router.router_stats
    result = {
        "arch": cfg.name,
        "replicas": replicas,
        "num_slots": num_slots,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "gen_tokens": gen_tokens,
        "sampling": None if sampling is None else dataclasses.asdict(sampling),
        "wall_seconds": wall,
        "tokens_per_second": total / max(wall, 1e-9),
        "latency_p50": float(np.percentile(lat, 50)),
        "latency_p95": float(np.percentile(lat, 95)),
        "completed": len(outs),
        "shed": [(e.uid, e.reason) for e in router.shed_errors],
        "router": rs,
        "generated": [o.tokens for o in outs],
    }
    log_fn(
        f"{cfg.name}: {len(outs)}/{n_requests} reqs over {replicas} replicas"
        f" × {num_slots} slots in {wall:.2f}s "
        f"({result['tokens_per_second']:.1f} tok/s); "
        f"healthy={rs['healthy']}, occ="
        f"{['%.0f%%' % (100 * o) for o in rs['occupancy']]}, "
        f"{rs['migrations']} migrations ({rs['migrated_requests']} reqs), "
        f"{rs['shed_requests']} shed, {rs['retries']} retries, "
        f"affinity {rs['affinity_routed']} / balance {rs['balance_routed']}"
    )
    return result
