"""Pytree checkpointing: flat .npz payload + JSON treedef manifest.

Sharding-aware in the sense that arrays are fully gathered to host before
save (fine at the scales this container runs; a real multi-host deployment
would swap in per-shard files keyed by the same manifest). Keeps the last
``keep`` checkpoints; restore validates structure and dtypes against the
target pytree.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def save(self, step: int, tree) -> str:
        path = self._path(step)
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten_with_paths(tree)
        arrays = {}
        manifest = {}
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            arr = np.asarray(jax.device_get(leaf))
            name = f"a{i}"
            # bf16 has no numpy dtype: view as uint16 and record the real dtype
            if arr.dtype.name == "bfloat16":
                manifest[key] = {"name": name, "dtype": "bfloat16"}
                arr = arr.view(np.uint16)
            else:
                manifest[key] = {"name": name, "dtype": arr.dtype.name}
            arrays[name] = arr
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f, indent=1)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self._gc()
        return path

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target):
        """Restore into the structure of ``target`` (shapes must match)."""
        import jax.numpy as jnp

        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        for p, leaf in flat_t:
            key = "/".join(str(x) for x in p)
            if key not in manifest:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            entry = manifest[key]
            arr = data[entry["name"]]
            if entry["dtype"] == "bfloat16":
                arr = jnp.asarray(arr).view(jnp.bfloat16)
            else:
                arr = jnp.asarray(arr)
            if arr.shape != leaf.shape:
                raise ValueError(
                    f"shape mismatch for {key!r}: ckpt {arr.shape} vs target {leaf.shape}"
                )
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
