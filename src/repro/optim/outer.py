"""Outer (server-side) optimizer applied to the aggregated cross-cloud delta.

The paper's formulas 1/2/4 apply the aggregated model directly
(outer SGD with lr=1). A Nesterov outer optimizer on the aggregated
pseudo-gradient (w_global − w_agg) is the DiLoCo-style beyond-paper
improvement benchmarked in §Perf/§Claims.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.utils.tree import tree_map

Pytree = Any


def outer_init(cfg: FederatedConfig, params: Pytree) -> dict:
    if cfg.outer_optimizer == "nesterov":
        return {"momentum": tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    return {}


def outer_update(
    cfg: FederatedConfig,
    global_params: Pytree,
    aggregated: Pytree,
    state: dict,
) -> tuple[Pytree, dict]:
    """Move ``global_params`` toward ``aggregated`` under the outer rule."""
    if cfg.outer_optimizer == "none":
        return aggregated, state
    # pseudo-gradient: direction from aggregate back to current global
    delta = tree_map(
        lambda g, a: g.astype(jnp.float32) - a.astype(jnp.float32),
        global_params, aggregated,
    )
    if cfg.outer_optimizer == "sgd":
        new = tree_map(
            lambda g, d: (g.astype(jnp.float32) - cfg.outer_lr * d).astype(g.dtype),
            global_params, delta,
        )
        return new, state
    if cfg.outer_optimizer == "nesterov":
        mom = tree_map(
            lambda m, d: cfg.outer_momentum * m + d, state["momentum"], delta
        )
        new = tree_map(
            lambda g, m, d: (
                g.astype(jnp.float32)
                - cfg.outer_lr * (cfg.outer_momentum * m + d)
            ).astype(g.dtype),
            global_params, mom, delta,
        )
        return new, {"momentum": mom}
    raise ValueError(f"unknown outer optimizer {cfg.outer_optimizer!r}")
