"""AdamW (decoupled weight decay) in pure JAX — the inner, per-cloud optimizer.

State layout mirrors the parameter pytree: ``{"m": tree, "v": tree,
"count": i32}``. Moments are fp32 regardless of the parameter dtype (bf16
params with fp32 state is the production norm). Under FSDP the state simply
inherits the parameter sharding (ZeRO-1).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.utils.tree import tree_map, tree_sq_norm

Pytree = Any


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    total = jnp.maximum(cfg.steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / total, 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * cos


def adamw_init(params: Pytree) -> dict:
    return {
        "m": tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    norm = jnp.sqrt(tree_sq_norm(grads))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    cfg: TrainConfig,
    grads: Pytree,
    state: dict,
    params: Pytree,
    lr: jax.Array | float | None = None,
) -> tuple[Pytree, dict]:
    count = state["count"] + 1
    if lr is None:
        lr = lr_schedule(cfg, count)
    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.beta1, cfg.beta2
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1**c
    bc2 = 1.0 - b2**c

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (step + decay)
        return p_new.astype(p.dtype), m_new, v_new

    is_tup = lambda x: isinstance(x, tuple)
    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=is_tup)
    new_m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=is_tup)
    new_v = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=is_tup)
    return new_params, {"m": new_m, "v": new_v, "count": count}
