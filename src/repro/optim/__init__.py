from repro.optim.adamw import adamw_init, adamw_update, lr_schedule
from repro.optim.outer import outer_init, outer_update

__all__ = ["adamw_init", "adamw_update", "lr_schedule", "outer_init", "outer_update"]
