from repro.core.aggregation import (
    async_update,
    dynamic_weights,
    fedavg_weights,
    gradient_aggregate,
    weighted_average,
)
from repro.core.federated import FederatedTrainer

__all__ = [
    "FederatedTrainer",
    "async_update",
    "dynamic_weights",
    "fedavg_weights",
    "gradient_aggregate",
    "weighted_average",
]
