"""Cross-cloud transport cost models — the paper's §3.2 protocol comparison.

XLA cannot speak gRPC or QUIC, so the paper's "which transport for
cross-cloud sync?" question is answered with an analytic per-transfer model
(DESIGN.md §2.3) applied to the *measured* sync payload (from the
compression accounting and/or the compiled HLO's cross-pod collective
bytes).

Model per transfer of B bytes over a link (latency ℓ, bandwidth W, loss p):

    t = handshake + ℓ·ceil(streams_serialized) + B / (W·η) + stall(p, B)

* TCP/gRPC: HTTP/2 over TCP — 1 connection handshake amortized, but
  head-of-line blocking couples all multiplexed streams to one loss event:
  stall ≈ p · (B/MSS) · RTO_penalty across the whole connection.
* QUIC: 0-RTT resumption, per-stream loss isolation: only the lossy
  stream's share of bytes stalls.
* Multiplexing (the paper's "multiplexing techniques"): n_streams parallel
  tensor streams fill the pipe during slow-start, modeled as bandwidth
  efficiency η(n_streams).

Constants are the usual WAN planning numbers; the benchmark reports
*relative* protocol behaviour (the paper's Table 1 row), not absolute WAN
truth."""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Link:
    """A cross-cloud WAN link."""
    latency_s: float = 0.03          # one-way
    bandwidth: float = 1.25e9        # bytes/s (10 Gbit/s leased line)
    loss_rate: float = 1e-4          # packet loss probability
    mss: int = 1400                  # bytes per packet


@dataclasses.dataclass(frozen=True)
class Protocol:
    name: str
    handshake_rtts: float            # connection setup round trips
    hol_blocking: bool               # loss stalls the whole connection?
    slow_start_eff: float            # bandwidth efficiency for one stream
    multiplex_gain: float            # how much extra streams recover

    def efficiency(self, n_streams: int) -> float:
        eff = self.slow_start_eff + self.multiplex_gain * (
            1.0 - math.exp(-(n_streams - 1) / 4.0)
        )
        return min(eff, 0.98)

    def transfer_time(
        self, nbytes: float, link: Link, n_streams: int = 4, reuse_conn: bool = True
    ) -> float:
        rtt = 2 * link.latency_s
        setup = 0.0 if reuse_conn else self.handshake_rtts * rtt
        wire = nbytes / (link.bandwidth * self.efficiency(n_streams))
        packets = nbytes / link.mss
        expected_losses = link.loss_rate * packets
        if self.hol_blocking:
            # every loss stalls all streams for ~1 RTT (retransmit turnaround)
            stall = expected_losses * rtt
        else:
            # loss isolated to one of n streams; only its share stalls
            stall = expected_losses * rtt / max(n_streams, 1)
        return setup + link.latency_s + wire + stall


TCP = Protocol("tcp", handshake_rtts=1.5, hol_blocking=True, slow_start_eff=0.60, multiplex_gain=0.0)
GRPC = Protocol("grpc", handshake_rtts=2.5, hol_blocking=True, slow_start_eff=0.65, multiplex_gain=0.25)
QUIC = Protocol("quic", handshake_rtts=0.0, hol_blocking=False, slow_start_eff=0.70, multiplex_gain=0.25)

PROTOCOLS = {p.name: p for p in (TCP, GRPC, QUIC)}


def sync_wall_time(
    nbytes_per_cloud: float,
    n_clouds: int,
    protocol: Protocol,
    link: Link,
    n_streams: int = 4,
    topology: str = "star",
) -> float:
    """One aggregation round's communication time.

    star: every cloud up+down to an aggregation point (parallel uplinks,
    bounded by the slowest); ring: 2(n−1)/n payload per hop, n−1 hops."""
    if topology == "star":
        up = protocol.transfer_time(nbytes_per_cloud, link, n_streams)
        down = protocol.transfer_time(nbytes_per_cloud, link, n_streams)
        return up + down
    if topology == "ring":
        chunk = nbytes_per_cloud / max(n_clouds, 1)
        hop = protocol.transfer_time(chunk, link, n_streams)
        return 2 * (n_clouds - 1) * hop
    raise ValueError(topology)
