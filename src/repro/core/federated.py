"""FederatedTrainer — the paper's cross-cloud training loop, end to end.

One representation, two execution modes:

* **Simulation (CPU, tests/benchmarks)**: per-cloud state is stacked on a
  leading ``n_clouds`` axis; local steps run under ``jax.vmap``.
* **SPMD (production mesh)**: the same stacked state with the leading axis
  sharded over the ``pod`` mesh axis, local steps vmapped with
  ``spmd_axis_name="pod"``. Axis-0 reductions in the aggregators become
  cross-pod all-reduces — the cross-cloud traffic the paper optimizes.

Per the paper:
  §3.2 local-update schedule: H local steps between sync rounds.
  §3.2 compression: deltas pass the Compressor channel (+ error feedback).
  §3.3 aggregation: fedavg | dynamic | gradient | async (formulas 1-4).
  §3.1 security: DP clip+noise; secure aggregation (masking) optional.

The sync round is under ``lax.cond`` so the whole step jits once; both
branches appear in lowered HLO, which is what lets the dry-run roofline
count the cross-pod collective bytes."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig, TrainConfig
from repro.core import aggregation as agg
from repro.core import privacy
from repro.core.compression import Compressor
from repro.models.model import ModelAPI
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.outer import outer_init, outer_update
from repro.utils.tree import tree_map, tree_sub, tree_zeros_like

Pytree = Any


def _broadcast_clouds(tree: Pytree, n: int) -> Pytree:
    return tree_map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


@dataclasses.dataclass
class FederatedTrainer:
    model: ModelAPI
    fed: FederatedConfig
    train: TrainConfig
    spmd_axis: str | None = None     # "pod" on the production mesh
    microbatches: int = 1            # grad-accumulation chunks per local step
    grad_shardings: Any = None       # NamedSharding tree (unstacked params):
                                     # pins the grad accumulator (ZeRO-2);
                                     # also supplies the per-leaf intra-pod
                                     # specs for the int8-wire sync
    mesh: Any = None                 # physical mesh (needed by the shard_map
                                     # int8-wire sync path)

    def __post_init__(self):
        self.compressor = Compressor(
            self.fed.compression, self.fed.topk_ratio,
            spmd=self.spmd_axis is not None,
        )
        if self.fed.aggregation not in agg.AGGREGATORS:
            raise ValueError(f"unknown aggregation {self.fed.aggregation!r}")

    # ------------------------------------------------------------------ init
    def init_state(self, key: jax.Array) -> dict:
        c = self.fed.n_clouds
        params = self.model.init(key)
        counts = self.fed.cloud_sample_counts or tuple([1] * c)
        state = {
            "clouds": {
                "params": _broadcast_clouds(params, c),
                "opt": _broadcast_clouds(adamw_init(params), c),
            },
            "global": {"params": params, "outer": outer_init(self.fed, params)},
            "sample_counts": jnp.asarray(counts, jnp.float32),
            "loss_accum": jnp.zeros((c,), jnp.float32),
            "step": jnp.zeros((), jnp.int32),
            "rng": jax.random.fold_in(key, 0xFED),
        }
        if self._use_error_feedback():
            ef32 = tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            state["ef"] = _broadcast_clouds(ef32, c)
        return state

    def _use_error_feedback(self) -> bool:
        return self.fed.compression != "none" and self.fed.error_feedback

    # ------------------------------------------------------------ local step
    def _local_step(self, params, opt, batch):
        from repro.utils.grad import microbatched_value_and_grad

        model_batch = {k: v for k, v in batch.items() if k != "domain"}
        (loss, metrics), grads = microbatched_value_and_grad(
            self.model.loss, params, model_batch, self.microbatches,
            grad_shardings=self.grad_shardings,
        )
        params, opt = adamw_update(self.train, grads, opt, params)
        return params, opt, grads, metrics

    def _vmapped_local(self):
        kwargs = {}
        if self.spmd_axis is not None:
            kwargs["spmd_axis_name"] = self.spmd_axis
        return jax.vmap(self._local_step, **kwargs)

    # ----------------------------------------------------- transmitted delta
    def _channel(self, stacked_delta: Pytree, ef: Pytree | None):
        """Compression channel + error feedback + DP clipping, per cloud."""
        if ef is not None:
            stacked_delta = tree_map(
                lambda d, e: d + e.astype(d.dtype), stacked_delta, ef
            )
        if self.fed.dp_clip > 0:
            def clip_one(delta):
                clipped, _ = privacy.clip_update(delta, self.fed.dp_clip)
                return clipped
            stacked_delta = jax.vmap(clip_one)(stacked_delta)
        if self.fed.compression != "none":
            transmitted = jax.vmap(self.compressor.roundtrip)(stacked_delta)
            new_ef = tree_sub(stacked_delta, transmitted) if ef is not None else None
        else:
            transmitted, new_ef = stacked_delta, ef
        return transmitted, new_ef

    # ------------------------------------------------------------ sync round
    def _sync(self, state: dict, arrived: jax.Array, alphas: jax.Array) -> dict:
        fed = self.fed
        c = fed.n_clouds
        g = state["global"]["params"]
        stacked = state["clouds"]["params"]
        delta = tree_map(
            lambda p, gp: p.astype(jnp.float32) - gp.astype(jnp.float32)[None],
            stacked, g,
        )
        transmitted, new_ef = self._channel(delta, state.get("ef"))

        mean_losses = state["loss_accum"] / jnp.maximum(fed.local_steps, 1)
        if fed.aggregation == "dynamic":
            weights = agg.dynamic_weights(mean_losses, fed.dynamic_temp)
        else:
            weights = agg.fedavg_weights(state["sample_counts"])

        rng, noise_key = jax.random.split(state["rng"])

        if fed.aggregation == "async":
            # reconstructed per-cloud params after the lossy channel
            recon = tree_map(
                lambda gp, d: gp.astype(jnp.float32)[None] + d, g, transmitted
            )
            new_global = agg.masked_async_update(g, recon, alphas, arrived)
            # only arrived clouds pull the fresh global model
            def pull(p, ng):
                cond = arrived.reshape((-1,) + (1,) * (p.ndim - 1))
                return jnp.where(cond, jnp.broadcast_to(ng[None], p.shape).astype(p.dtype), p)
            new_stacked = tree_map(pull, stacked, new_global)
            outer_state = state["global"]["outer"]
        else:
            if fed.wire_int8 and self.spmd_axis is not None:
                # beyond-paper: int8 payload over the DCN inside the program
                specs = None
                if self.grad_shardings is not None:
                    specs = jax.tree_util.tree_map(
                        lambda ns: ns.spec, self.grad_shardings
                    )
                agg_delta = agg.int8_wire_weighted_average(
                    transmitted, weights, pod_axis=self.spmd_axis,
                    mesh=self.mesh, shard_specs=specs,
                )
            else:
                agg_delta = agg.weighted_average(transmitted, weights)
            if fed.dp_clip > 0 and fed.dp_noise_mult > 0:
                std = privacy.dp_noise_stddev(fed.dp_clip, fed.dp_noise_mult, c)
                agg_delta = privacy.add_gaussian_noise(agg_delta, noise_key, std)
            aggregated = tree_map(
                lambda gp, d: (gp.astype(jnp.float32) + d.astype(jnp.float32)).astype(gp.dtype),
                g, agg_delta,
            )
            new_global, outer_state = outer_update(
                fed, g, aggregated, state["global"]["outer"]
            )
            new_stacked = _broadcast_clouds(new_global, c)

        new_state = dict(state)
        new_state["clouds"] = dict(state["clouds"], params=new_stacked)
        new_state["global"] = {"params": new_global, "outer": outer_state}
        new_state["loss_accum"] = jnp.zeros_like(state["loss_accum"])
        new_state["rng"] = rng
        if new_ef is not None:
            new_state["ef"] = new_ef
        return new_state

    # ------------------------------------------------------------ train step
    def train_step(
        self,
        state: dict,
        batch_stack: dict,
        arrived: jax.Array | None = None,
        alphas: jax.Array | None = None,
    ) -> tuple[dict, dict]:
        """One global step: local updates everywhere (+ sync every H steps).

        batch_stack leaves: (n_clouds, B, ...). For async mode pass the
        scheduler's (arrived, alphas) row for this round."""
        fed = self.fed
        c = fed.n_clouds
        if arrived is None:
            arrived = jnp.ones((c,), bool)
        if alphas is None:
            alphas = jnp.full((c,), fed.async_alpha, jnp.float32)

        if fed.aggregation == "gradient":
            return self._gradient_step(state, batch_stack)

        params, opt, _, metrics = self._vmapped_local()(
            state["clouds"]["params"], state["clouds"]["opt"], batch_stack
        )
        state = dict(state)
        state["clouds"] = {"params": params, "opt": opt}
        state["loss_accum"] = state["loss_accum"] + metrics["loss"]
        step = state["step"] + 1
        state["step"] = step

        do_sync = (step % jnp.maximum(fed.local_steps, 1)) == 0
        state = jax.lax.cond(
            do_sync,
            lambda s: self._sync(s, arrived, alphas),
            lambda s: s,
            state,
        )
        out_metrics = {
            "loss": jnp.mean(metrics["loss"]),
            "accuracy": jnp.mean(metrics["accuracy"]),
            "per_cloud_loss": metrics["loss"],
            "synced": do_sync.astype(jnp.float32),
        }
        return state, out_metrics

    # ------------------------------------------------- gradient aggregation
    def _gradient_step(self, state: dict, batch_stack: dict) -> tuple[dict, dict]:
        """Formula 3: aggregate ∇w_i every step, single global optimizer."""
        fed = self.fed

        def grads_only(params, batch):
            from repro.utils.grad import microbatched_value_and_grad

            model_batch = {k: v for k, v in batch.items() if k != "domain"}
            (loss, metrics), grads = microbatched_value_and_grad(
                self.model.loss, params, model_batch, self.microbatches,
                grad_shardings=self.grad_shardings,
            )
            return grads, metrics

        kwargs = {"spmd_axis_name": self.spmd_axis} if self.spmd_axis else {}
        stacked_grads, metrics = jax.vmap(grads_only, **kwargs)(
            state["clouds"]["params"], batch_stack
        )
        transmitted, new_ef = self._channel(
            tree_map(lambda gr: gr.astype(jnp.float32), stacked_grads),
            state.get("ef"),
        )
        weights = agg.fedavg_weights(state["sample_counts"])
        agg_grad = agg.gradient_aggregate(None, transmitted, weights)
        if fed.dp_clip > 0 and fed.dp_noise_mult > 0:
            rng, noise_key = jax.random.split(state["rng"])
            std = privacy.dp_noise_stddev(fed.dp_clip, fed.dp_noise_mult, fed.n_clouds)
            agg_grad = privacy.add_gaussian_noise(agg_grad, noise_key, std)
        else:
            rng = state["rng"]

        # single global optimizer step; opt state slot 0 is canonical
        opt0 = tree_map(lambda x: x[0], state["clouds"]["opt"])
        g = state["global"]["params"]
        new_global, new_opt0 = adamw_update(self.train, agg_grad, opt0, g)

        c = fed.n_clouds
        new_state = dict(state)
        new_state["clouds"] = {
            "params": _broadcast_clouds(new_global, c),
            "opt": _broadcast_clouds(new_opt0, c),
        }
        new_state["global"] = dict(state["global"], params=new_global)
        new_state["step"] = state["step"] + 1
        new_state["rng"] = rng
        if new_ef is not None:
            new_state["ef"] = new_ef
        out_metrics = {
            "loss": jnp.mean(metrics["loss"]),
            "accuracy": jnp.mean(metrics["accuracy"]),
            "per_cloud_loss": metrics["loss"],
            "synced": jnp.ones(()),
        }
        return new_state, out_metrics

    # --------------------------------------------------------- wire accounting
    def sync_bytes_per_cloud(self, params: Pytree) -> int:
        """Uplink bytes one cloud transmits per sync round."""
        return self.compressor.bytes_per_sync(params)

    def syncs_per_step(self) -> float:
        if self.fed.aggregation == "gradient":
            return 1.0
        return 1.0 / max(self.fed.local_steps, 1)


def _b(tree: Pytree, n: int) -> Pytree:
    return tree_map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)
