"""Heterogeneity & asynchrony scheduling — supports §3.2/§3.3 async mode.

Clouds have different accelerators and different network distances, so their
local rounds complete at different wall times. The scheduler simulates
arrival order and staleness for the asynchronous aggregator (formula 4) and
produces the (arrived, alpha) masks the jitted SPMD step consumes.

Staleness discount: α_i(s) = α₀ / (1 + s)  where s = number of global
versions that elapsed since cloud i last synchronized (the standard
staleness-aware async-FL rule)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CloudSpec:
    name: str
    speed: float = 1.0        # relative local-step throughput
    link_latency_s: float = 0.05
    link_bandwidth: float = 1e9  # bytes/sec to the aggregation point


@dataclasses.dataclass
class AsyncEvent:
    time: float
    cloud: int
    staleness: int
    alpha: float


def simulate_async_schedule(
    clouds: list[CloudSpec],
    local_steps: int,
    n_rounds: int,
    base_alpha: float = 0.5,
    step_time: float = 1.0,
    sync_bytes: float = 0.0,
) -> list[AsyncEvent]:
    """Event-ordered async aggregation trace.

    Each cloud loops: H local steps (H·step_time/speed) + uplink transfer,
    then immediately merges into the global model. Staleness = how many
    merges happened since that cloud last pulled the global model."""
    c = len(clouds)
    next_done = np.zeros(c)
    version_at_pull = np.zeros(c, dtype=int)
    for i, spec in enumerate(clouds):
        compute = local_steps * step_time / spec.speed
        xfer = spec.link_latency_s + sync_bytes / spec.link_bandwidth
        next_done[i] = compute + xfer
    events: list[AsyncEvent] = []
    version = 0
    while len(events) < n_rounds:
        i = int(np.argmin(next_done))
        t = next_done[i]
        staleness = version - version_at_pull[i]
        alpha = base_alpha / (1.0 + staleness)
        events.append(AsyncEvent(time=t, cloud=i, staleness=int(staleness), alpha=alpha))
        version += 1
        version_at_pull[i] = version
        spec = clouds[i]
        compute = local_steps * step_time / spec.speed
        xfer = spec.link_latency_s + sync_bytes / spec.link_bandwidth
        next_done[i] = t + compute + xfer
    return events


def events_to_round_masks(
    events: list[AsyncEvent], n_clouds: int, rounds: int
) -> tuple[np.ndarray, np.ndarray]:
    """Bucket the event trace into per-round (arrived, alpha) arrays for the
    jitted masked_async_update. Round k applies events[k]."""
    arrived = np.zeros((rounds, n_clouds), bool)
    alphas = np.zeros((rounds, n_clouds), np.float32)
    for k, ev in enumerate(events[:rounds]):
        arrived[k, ev.cloud] = True
        alphas[k, ev.cloud] = ev.alpha
    return arrived, alphas


def sync_round_time(
    clouds: list[CloudSpec],
    local_steps: int,
    step_time: float,
    sync_bytes: float,
) -> float:
    """Synchronous-mode round latency: slowest compute + slowest transfer."""
    compute = max(local_steps * step_time / c.speed for c in clouds)
    xfer = max(c.link_latency_s + sync_bytes / c.link_bandwidth for c in clouds)
    return compute + xfer
