"""Cross-cloud payload compression — the paper's §3.2.

Two composable codecs, applied to the per-cloud *update* (delta or gradient)
before it crosses the pod axis:

* ``topk``  — block-local magnitude sparsification (keep-ratio ρ per
  (block,)-chunk) with error feedback handled by the federated trainer.
  TPU adaptation: selection is per 256-element block, aligned to (8,128)
  VMEM tiles, instead of a GPU-style global sort (see DESIGN.md §2.4).
* ``int8``  — per-block symmetric int8 quantization (scale = max|x|/127).

``roundtrip`` is the lossy channel simulation (compress→decompress) used
inside the jitted sync step; ``bytes_per_sync`` is the analytic wire size
consumed by the protocol cost model and the Table-2 benchmark. The Pallas
kernels in ``repro.kernels`` implement the same math for the TPU hot path;
tests pin kernel == this reference.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_map

Pytree = Any

BLOCK = 256

METHODS = ("none", "topk", "int8", "topk+int8")


def _to_blocks(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    flat = x.astype(jnp.float32).ravel()
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(-1, block), n


def _from_blocks(blocks: jax.Array, n: int, shape, dtype) -> jax.Array:
    return blocks.ravel()[:n].reshape(shape).astype(dtype)


def topk_block_sparsify(x: jax.Array, ratio: float, block: int = BLOCK) -> jax.Array:
    """Keep the ⌈ρ·block⌉ largest-magnitude entries of each block.

    Threshold semantics (``|x| ≥ t_k`` where t_k is the k-th largest
    magnitude): ties at the threshold are kept, which is what the sort-free
    TPU kernel computes — on continuous-valued gradients the two semantics
    coincide."""
    blocks, n = _to_blocks(x, block)
    k = max(1, int(round(ratio * block)))
    mag = jnp.abs(blocks)
    kth = jax.lax.top_k(mag, k)[0][:, -1:]            # (nb, 1)
    out = jnp.where(mag >= kth, blocks, 0.0)
    return _from_blocks(out, n, x.shape, x.dtype)


def topk_threshold_sparsify(x: jax.Array, ratio: float, iters: int = 16) -> jax.Array:
    """Global (per-leaf) magnitude top-k via bisection threshold select.

    The SPMD path. ``lax.top_k`` lowers to a sort, whose operand XLA SPMD
    replicates across the whole mesh (it cannot partition sorts) — on the
    federated sync that all-gathered entire 470 GB delta trees across pods.
    ``ravel()`` similarly re-linearizes a sharded tensor (all-gather).
    Bisection needs only elementwise compares and scalar count reductions,
    both of which shard perfectly — and global selection is exactly the
    paper's original formulation (block-local selection is the Pallas-kernel
    adaptation for the per-device hot path)."""
    xf = x.astype(jnp.float32)
    mag = jnp.abs(xf)
    k = jnp.asarray(max(1.0, round(ratio * x.size)), jnp.float32)
    lo = jnp.zeros((), jnp.float32)
    hi = jnp.max(mag)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        too_many = jnp.sum((mag >= mid).astype(jnp.float32)) > k
        return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # keep ≥ lo: count(≥lo) ≥ k — ties and the last bisection gap err toward
    # keeping slightly more than k, the right direction for a lossy channel.
    return jnp.where(mag >= lo, xf, 0.0).astype(x.dtype)


def int8_roundtrip_rowwise(x: jax.Array) -> jax.Array:
    """Per-(last-dim)-row symmetric int8 — the SPMD path (no ravel/reshape,
    so parameter shardings pass straight through; the row max is a small
    partial reduction)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(x.dtype)


def int8_quantize_blocks(x: jax.Array, block: int = BLOCK):
    blocks, n = _to_blocks(x, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def int8_roundtrip(x: jax.Array, block: int = BLOCK) -> jax.Array:
    q, scale, n = int8_quantize_blocks(x, block)
    deq = q.astype(jnp.float32) * scale
    return _from_blocks(deq, n, x.shape, x.dtype)


@dataclasses.dataclass(frozen=True)
class Compressor:
    method: str = "none"
    topk_ratio: float = 0.01
    block: int = BLOCK
    spmd: bool = False    # sharded-mesh variants: threshold-select top-k,
                          # row-wise int8 (no sort, no ravel — see above)

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"unknown compression {self.method!r}; known {METHODS}")

    def roundtrip_leaf(self, x: jax.Array) -> jax.Array:
        if self.method == "none" or x.ndim == 0:
            return x
        y = x
        if "topk" in self.method:
            if self.spmd:
                y = topk_threshold_sparsify(y, self.topk_ratio)
            else:
                y = topk_block_sparsify(y, self.topk_ratio, self.block)
        if "int8" in self.method:
            y = int8_roundtrip_rowwise(y) if self.spmd else int8_roundtrip(y, self.block)
        return y

    def roundtrip(self, tree: Pytree) -> Pytree:
        """The lossy channel: what the receiving side reconstructs."""
        return tree_map(self.roundtrip_leaf, tree)

    # ----------------------------------------------------- wire accounting
    def bytes_per_leaf(self, shape, dtype) -> int:
        n = int(np.prod(shape)) if shape else 1
        nb = -(-n // self.block)
        raw = n * jnp.dtype(dtype).itemsize
        if self.method == "none":
            return int(raw)
        if self.method == "topk":
            k = max(1, int(round(self.topk_ratio * self.block)))
            # per kept entry: bf16 value + u8 in-block index; + u16 block bitmap len
            return int(nb * k * (2 + 1) + nb * 2)
        if self.method == "int8":
            return int(n * 1 + nb * 4)  # q values + fp32 scale per block
        if self.method == "topk+int8":
            k = max(1, int(round(self.topk_ratio * self.block)))
            return int(nb * k * (1 + 1) + nb * (4 + 2))
        raise AssertionError

    def bytes_per_sync(self, tree: Pytree) -> int:
        """Uplink bytes for one cloud's update under this codec."""
        total = 0
        for leaf in jax.tree_util.tree_leaves(tree):
            total += self.bytes_per_leaf(leaf.shape, leaf.dtype)
        return total

    def compression_ratio(self, tree: Pytree) -> float:
        raw = sum(
            int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
            for l in jax.tree_util.tree_leaves(tree)
        )
        return raw / max(self.bytes_per_sync(tree), 1)
