"""Data-security layer — the paper's §3.1 "Ensure Data Security".

Two mechanisms, composable with every aggregator:

* **Differential privacy (DP-FedAvg)**: each cloud's update is clipped to
  global-L2 norm ≤ C before transmission; Gaussian noise N(0, (σC)²/C_clouds)
  is added to the *aggregate* (server-side noise under the honest-server
  model; per-cloud noise for the local model is a one-line change). The
  fused clip+noise hot path is the `dp_clip` Pallas kernel.

* **Secure aggregation** (the paper's "homomorphic encryption" requirement,
  adapted — see DESIGN.md §2.5): Bonawitz-style pairwise additive masking in
  fixed-point int32 arithmetic. Cloud i adds Σ_{j>i} PRF(i,j) − Σ_{j<i}
  PRF(j,i); masks cancel *exactly* in the modular sum, so the server learns
  only Σ_i update_i. Wraparound int32 arithmetic gives bit-exact
  cancellation (floats would leak rounding residue).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_map, tree_sq_norm, tree_split_keys

Pytree = Any

FIXED_POINT_SCALE = 2.0**16


# ------------------------------------------------------------------ DP-SGD
def clip_update(update: Pytree, clip_norm: float) -> tuple[Pytree, jax.Array]:
    """Scale the whole update so its global L2 norm is ≤ clip_norm."""
    norm = jnp.sqrt(tree_sq_norm(update))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-9))
    return tree_map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), update), norm


def add_gaussian_noise(
    tree: Pytree, key: jax.Array, stddev: float | jax.Array
) -> Pytree:
    keys = tree_split_keys(key, tree)
    return tree_map(
        lambda x, k: (
            x.astype(jnp.float32)
            + stddev * jax.random.normal(k, x.shape, jnp.float32)
        ).astype(x.dtype),
        tree,
        keys,
    )


def dp_noise_stddev(clip_norm: float, noise_mult: float, n_clouds: int) -> float:
    """Std-dev of the noise added to the *average* of n clipped updates."""
    return noise_mult * clip_norm / max(n_clouds, 1)


# ------------------------------------------------------- secure aggregation
def _pair_key(round_idx, i: int, j: int) -> jax.Array:
    base = jax.random.PRNGKey(0x5EC0)
    k = jax.random.fold_in(base, round_idx)
    k = jax.random.fold_in(k, i * 100_003 + j)
    return k


def _mask_like_int(tree: Pytree, key: jax.Array) -> Pytree:
    keys = tree_split_keys(key, tree)
    return tree_map(
        lambda x, k: jax.random.randint(
            k, x.shape, jnp.iinfo(jnp.int32).min, jnp.iinfo(jnp.int32).max, jnp.int32
        ),
        tree,
        keys,
    )


def to_fixed(tree: Pytree) -> Pytree:
    return tree_map(
        lambda x: jnp.round(x.astype(jnp.float32) * FIXED_POINT_SCALE).astype(jnp.int32),
        tree,
    )


def from_fixed(tree: Pytree, dtype) -> Pytree:
    return tree_map(
        lambda x: (x.astype(jnp.float32) / FIXED_POINT_SCALE).astype(dtype), tree
    )


def mask_update(
    update_fixed: Pytree, cloud_idx: int, n_clouds: int, round_idx
) -> Pytree:
    """Additive pairwise masks in wraparound int32: what cloud i transmits."""
    masked = update_fixed
    for j in range(n_clouds):
        if j == cloud_idx:
            continue
        lo, hi = min(cloud_idx, j), max(cloud_idx, j)
        mask = _mask_like_int(update_fixed, _pair_key(round_idx, lo, hi))
        sign = 1 if cloud_idx < j else -1
        masked = tree_map(
            lambda m, x, s=sign: (m + s * x).astype(jnp.int32), masked, mask
        )
    return masked


def secure_sum(masked_updates: list[Pytree]) -> Pytree:
    """Σ_i masked_i — masks cancel exactly; returns fixed-point sum."""
    out = masked_updates[0]
    for m in masked_updates[1:]:
        out = tree_map(lambda a, b: (a + b).astype(jnp.int32), out, m)
    return out


def secure_aggregate(updates: list[Pytree], round_idx, dtype=jnp.float32) -> Pytree:
    """End-to-end: fixed-point lift → mask → sum → unmask-by-cancellation."""
    n = len(updates)
    masked = [
        mask_update(to_fixed(u), i, n, round_idx) for i, u in enumerate(updates)
    ]
    return from_fixed(secure_sum(masked), dtype)
