"""Model aggregation algorithms — the paper's §3.3, formulas 1-4.

All functions operate on *stacked* cloud pytrees: every leaf carries a
leading ``n_clouds`` axis. This single representation serves both execution
modes: on CPU it is a plain batched array; on the production mesh the
leading axis is sharded over ``pod`` and the axis-0 reductions below lower
to all-reduce/all-gather collectives over the cross-cloud links — exactly
the traffic the paper's techniques aim to shrink.

    formula 1 (FedAvg):      w = Σ_i (n_i / n) · w_i
    formula 2 (dynamic):     α_i = exp(−L_i) / Σ_j exp(−L_j)
    formula 3 (gradient):    w ← w − η Σ_i (n_i / n) · ∇w_i
    formula 4 (async):       w ← w + α_i (w_i − w)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_map

Pytree = Any

AGGREGATORS = ("fedavg", "dynamic", "gradient", "async")


def fedavg_weights(sample_counts: jax.Array) -> jax.Array:
    """Formula 1 weights: n_i / n. sample_counts: (C,)."""
    n = sample_counts.astype(jnp.float32)
    return n / jnp.maximum(jnp.sum(n), 1.0)


def dynamic_weights(losses: jax.Array, temp: float = 1.0) -> jax.Array:
    """Formula 2: α_i = softmax(−L_i / τ). losses: (C,)."""
    return jax.nn.softmax(-losses.astype(jnp.float32) / temp)


def weighted_average(stacked: Pytree, weights: jax.Array) -> Pytree:
    """Σ_i weights_i · leaf_i over the leading cloud axis (fp32 accumulate)."""

    def avg(x):
        w = weights.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(jnp.float32) * w, axis=0).astype(x.dtype)

    return tree_map(avg, stacked)


def gradient_aggregate(
    params: Pytree, stacked_grads: Pytree, weights: jax.Array
) -> Pytree:
    """Formula 3's aggregation half: ĝ = Σ_i (n_i/n) ∇w_i.

    The global update ``w ← w − η ĝ`` is then applied by the inner optimizer
    (plain SGD reproduces the formula exactly; AdamW is the production
    variant — §Claims reports both)."""
    del params  # signature kept symmetric with the other aggregators
    return weighted_average(stacked_grads, weights)


def async_update(
    global_params: Pytree,
    cloud_params: Pytree,
    alpha: jax.Array | float,
) -> Pytree:
    """Formula 4: w ← w + α (w_i − w) for one arriving cloud update."""

    def upd(w, wi):
        wf = w.astype(jnp.float32)
        return (wf + alpha * (wi.astype(jnp.float32) - wf)).astype(w.dtype)

    return tree_map(upd, global_params, cloud_params)


def masked_async_update(
    global_params: Pytree,
    stacked_params: Pytree,
    alphas: jax.Array,
    arrived: jax.Array,
) -> Pytree:
    """Batched formula 4 for the SPMD path: apply all clouds whose update
    arrived this round (``arrived``: (C,) bool), each with its staleness-
    discounted α_i. Sequential-arrival semantics are approximated by the
    simultaneous sum  w += Σ_i arrived_i · α_i (w_i − w)  with
    Σ arrived_i·α_i ≤ 1 enforced by the scheduler."""
    a = (alphas * arrived.astype(jnp.float32)).astype(jnp.float32)

    def upd(w, wi):
        wf = w.astype(jnp.float32)
        contrib = jnp.sum(
            a.reshape((-1,) + (1,) * (wi.ndim - 1))
            * (wi.astype(jnp.float32) - wf[None]),
            axis=0,
        )
        return (wf + contrib).astype(w.dtype)

    return tree_map(upd, global_params, stacked_params)


# ------------------------------------------------- int8-on-the-wire (beyond-paper)
def int8_wire_weighted_average(stacked: Pytree, weights: jax.Array,
                               pod_axis: str = "pod", mesh=None,
                               shard_specs: Pytree | None = None) -> Pytree:
    """Weighted average across clouds with the cross-pod payload carried as
    int8 INSIDE the XLA program (beyond-paper §Perf optimization).

    The pjit formulation of formula 1 lowers to a dense fp32 all-reduce over
    the pod axis — the full master-precision delta crosses the (slow, paid)
    DCN link even though the sync only needs ~8-bit fidelity (error feedback
    absorbs the residual). This runs the combine under a FULLY-MANUAL
    ``shard_map``: every device quantizes its local shard per-(last-dim)-row
    to int8, all-gathers only the int8 shard + fp32 row scales across its
    pod-peer, and dequantizes/combines locally. 4× fewer DCN bytes than the
    fp32 all-reduce (8× vs. its 2× round trip), visible as ``s8`` gathers in
    the compiled HLO rather than only in the analytic wire model.

    Fully-manual matters: with auto intra-pod axes, the per-row max inside
    the body reduces over a sharded dimension, and the partitioner falls
    back to replicating the whole fp32 delta per device (measured 75 GB/dev
    cross-pod). Manual specs keep every op shard-local by construction.

    shard_specs: pytree of PartitionSpec for the UNSTACKED leaves (the
    intra-pod placement); required together with ``mesh``."""
    P = jax.sharding.PartitionSpec
    assert mesh is not None and shard_specs is not None, (
        "int8_wire_weighted_average needs mesh + per-leaf shard specs"
    )
    n_pods = dict(mesh.shape)[pod_axis]

    def leaf_fn(x, w):
        # x: this device's local shard of (1, ...) — one cloud's slice
        c = w.shape[0]
        if x.ndim <= 1 or x.size * n_pods <= 8192:
            xg = jax.lax.all_gather(x, pod_axis, axis=0, tiled=True)
            wr = w.reshape((c,) + (1,) * (xg.ndim - 1))
            return jnp.sum(wr * xg.astype(jnp.float32), axis=0)
        xf = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, pod_axis, axis=0, tiled=True)   # int8 wire
        sg = jax.lax.all_gather(scale, pod_axis, axis=0, tiled=True)
        deq = qg.astype(jnp.float32) * sg
        wr = w.reshape((c,) + (1,) * (deq.ndim - 1))
        return jnp.sum(wr * deq, axis=0)

    def fn(tree, w):
        return tree_map(lambda x: leaf_fn(x, w), tree)

    in_specs = (
        tree_map(lambda s: P(pod_axis, *s), shard_specs),
        P(),
    )
    out_specs = tree_map(lambda s: P(*s), shard_specs)
    if hasattr(jax, "shard_map"):  # jax >= 0.7 public API
        mapped = jax.shard_map(
            fn,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
    else:  # jax 0.4.x: experimental module, check_rep spelling
        from jax.experimental.shard_map import shard_map as _shard_map

        mapped = _shard_map(
            fn,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=False,
        )
    return mapped(stacked, weights)
