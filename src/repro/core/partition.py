"""Data partitioning & distribution — the paper's §3.1.

The partitioner decides how many samples (and how large a per-step batch
share) each cloud processes. Strategies:

* ``fixed``    — equal shards regardless of cloud capability (Table 1 row).
* ``weighted`` — shards ∝ nominal throughput (provisioned capability).
* ``dynamic``  — the paper's §3.1 cycle ("Adjust Granularity → Balance Load
  → Monitor & Adjust"): shards rebalanced each round from *observed*
  throughput with EMA smoothing, bounded step size, and a minimum shard so
  no cloud starves.

Granularity: shard sizes are quantized to ``granule`` samples — the paper's
"data partition granularity" knob. Coarse granules cut redistribution
traffic; fine granules balance better. The partitioning benchmark sweeps it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PartitionState:
    shares: np.ndarray          # (C,) fraction of the global batch per cloud
    ema_throughput: np.ndarray  # (C,) samples/sec estimate
    moved_samples: int = 0      # cumulative redistribution traffic (samples)


@dataclasses.dataclass(frozen=True)
class Partitioner:
    strategy: str = "dynamic"          # fixed | weighted | dynamic
    n_clouds: int = 3
    granule: int = 1                   # samples per indivisible shard unit
    ema: float = 0.5
    max_step: float = 0.25             # max relative share change per round
    min_share: float = 0.05

    def init(self, nominal_throughput=None) -> PartitionState:
        c = self.n_clouds
        if self.strategy == "weighted" and nominal_throughput is not None:
            t = np.asarray(nominal_throughput, np.float64)
            shares = t / t.sum()
        else:
            shares = np.full((c,), 1.0 / c)
        ema = (
            np.asarray(nominal_throughput, np.float64)
            if nominal_throughput is not None
            else np.ones((c,))
        )
        return PartitionState(shares=shares, ema_throughput=ema)

    def quantize(self, state: PartitionState, global_batch: int) -> np.ndarray:
        """Integer per-cloud batch sizes respecting granularity + min share."""
        g = max(self.granule, 1)
        units = global_batch // g
        raw = state.shares * units
        sizes = np.floor(raw).astype(int)
        # distribute the remainder to largest fractional parts
        rem = units - sizes.sum()
        order = np.argsort(-(raw - sizes))
        sizes[order[:rem]] += 1
        sizes = np.maximum(sizes, 1)
        # renormalize if the min-clamp overflowed the budget
        while sizes.sum() > units:
            sizes[np.argmax(sizes)] -= 1
        return sizes * g

    def observe(
        self, state: PartitionState, samples_done: np.ndarray, step_times: np.ndarray
    ) -> PartitionState:
        """Feed back one round of measurements; rebalance if dynamic."""
        thr = np.asarray(samples_done, np.float64) / np.maximum(step_times, 1e-9)
        ema = self.ema * state.ema_throughput + (1 - self.ema) * thr
        if self.strategy != "dynamic":
            return PartitionState(state.shares, ema, state.moved_samples)
        target = ema / ema.sum()
        delta = np.clip(
            target - state.shares,
            -self.max_step * state.shares,
            self.max_step * np.maximum(state.shares, self.min_share),
        )
        shares = state.shares + delta
        shares = np.maximum(shares, self.min_share)
        shares = shares / shares.sum()
        moved = state.moved_samples + int(
            np.abs(shares - state.shares).sum() * 10_000
        )
        return PartitionState(shares, ema, moved)

    @staticmethod
    def round_time(batch_sizes: np.ndarray, throughput: np.ndarray) -> float:
        """Synchronous round latency = the straggler's time."""
        return float(np.max(batch_sizes / np.maximum(throughput, 1e-9)))

    @staticmethod
    def utilization(batch_sizes: np.ndarray, throughput: np.ndarray) -> float:
        """Mean busy-fraction across clouds within a synchronous round."""
        times = batch_sizes / np.maximum(throughput, 1e-9)
        return float(np.mean(times / times.max()))
