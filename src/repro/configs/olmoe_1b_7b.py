"""OLMoE 1B-7B — sparse MoE, 64 experts top-8. [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig

ARCH_ID = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50_304,
        n_experts=64,
        experts_per_token=8,
        rope_theta=10_000.0,
        act="silu",
        fsdp=False,
        source="[arXiv:2409.02060]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        n_experts=4,
        experts_per_token=2,
        act="silu",
        remat=False,
        source="[arXiv:2409.02060]",
    )
