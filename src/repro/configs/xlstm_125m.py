"""xLSTM-125M — sLSTM + mLSTM blocks (every 4th block sLSTM). [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig

ARCH_ID = "xlstm-125m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,                   # xLSTM blocks carry their own projections
        vocab_size=50_304,
        slstm_every=4,            # blocks 3, 7, 11 are sLSTM; rest mLSTM
        act="gelu",
        fsdp=False,
        # 125M params / 16-way TP = sliver matmuls (768x96) whose gather/
        # reduce traffic dominates the roofline (~120 GB/dev/step measured).
        # Pure DP replicates the 250 MB of params and runs batch over both
        # axes: the only collective left is one grad all-reduce (~1 GB/dev).
        pure_dp=True,
        source="[arXiv:2405.04517]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="ssm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        slstm_every=2,            # one mLSTM + one sLSTM block
        act="gelu",
        remat=False,
        source="[arXiv:2405.04517]",
    )
