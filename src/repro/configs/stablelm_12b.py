"""StableLM-2 12B — dense decoder. [hf:stabilityai/stablelm-2-1_6b family]"""
from repro.configs.base import ModelConfig

ARCH_ID = "stablelm-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=13824,
        vocab_size=100_352,
        head_dim=160,
        rope_theta=10_000.0,
        act="silu",
        fsdp=True,
        source="[hf:stabilityai/stablelm-2-1_6b]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=352,
        vocab_size=512,
        head_dim=32,
        act="silu",
        remat=False,
        source="[hf:stabilityai/stablelm-2-1_6b]",
    )
