"""Config dataclasses: model architecture, input shapes, training/federated.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exposing
``config()`` (the exact assigned full-size config, exercised only through the
AOT dry-run) and ``smoke_config()`` (a reduced same-family variant that runs
a real forward/train step on CPU in the test suite).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

ArchType = Literal["dense", "moe", "vlm", "hybrid", "ssm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 → d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- attention ---
    rope_theta: float = 10_000.0
    window: int = 0                    # 0 = full causal attention (training)
    decode_window: int = 8192          # SWA ring-buffer window for long-ctx decode
    # --- hybrid (recurrentgemma): repeating block pattern ---
    block_pattern: Sequence[str] = ()  # e.g. ("rglru", "rglru", "attn")
    lru_width: int = 0
    conv_width: int = 4
    local_attn_window: int = 2048
    # --- ssm (xlstm) ---
    slstm_every: int = 0               # every k-th block is sLSTM (0 = none)
    # --- audio (whisper) / vlm (pixtral) modality frontend stubs ---
    encoder_layers: int = 0            # whisper encoder depth
    encoder_seq: int = 0               # whisper: 1500 mel frames (post-conv)
    vision_seq: int = 0                # pixtral: number of patch embeddings
    # --- numerics / misc ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    act: str = "silu"                  # mlp activation family: silu→SwiGLU, gelu→GeGLU/MLP
    # --- distribution hints ---
    fsdp: bool = False                 # shard params/opt-state over the data axis too
    pure_dp: bool = False              # no tensor parallelism: replicate params,
                                       # shard batch over (data, model). Right for
                                       # small models (e.g. 125M SSM) where TP
                                       # shards are sliver-thin and collective-bound.
    remat: bool = True                 # activation checkpointing per layer
    source: str = ""                   # citation bracket from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_enc_dec(self) -> bool:
        return self.arch_type == "audio"

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        hd = self.resolved_head_dim
        d = self.d_model
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.arch_type == "moe":
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        elif self.arch_type == "ssm":
            ffn = 0  # xlstm blocks count their own projections below
        else:
            ffn = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        if self.arch_type == "hybrid":
            # recurrent blocks replace attention with conv + RG-LRU projections
            pat = list(self.block_pattern) or ["rglru", "rglru", "attn"]
            n_rec = sum(
                1 for i in range(self.n_layers) if pat[i % len(pat)] != "attn"
            )
            n_att = self.n_layers - n_rec
            w = self.lru_width or d
            rec = 2 * d * w + w * d + self.conv_width * w + 3 * w + 2 * d
            ffn_l = 3 * d * self.d_ff + 2 * d
            return (
                n_att * (attn + ffn_l + 2 * d)
                + n_rec * (rec + ffn_l)
                + self.vocab_size * d
                + d
            )
        if self.arch_type == "ssm":
            # xLSTM block: up-proj 2d, qkv+gates from inner dim, down-proj
            inner = 2 * d
            per_layer = (
                d * 2 * inner           # up projection (main + gate)
                + 3 * inner * inner // 2  # q,k,v on half-width heads (approx)
                + inner * d             # down projection
                + 4 * inner             # gate biases / skip
                + 2 * d
            )
        total = self.n_layers * per_layer
        if self.is_enc_dec:
            # decoder layers additionally carry cross-attention
            total += self.n_layers * attn
            total += self.encoder_layers * (attn + ffn + 2 * d)
            total += self.encoder_seq * d  # encoder learned positions
            total += 448 * d               # decoder learned positions
        emb = self.vocab_size * d
        unemb = 0 if self.tie_embeddings else self.vocab_size * d
        return total + emb + unemb + d

    def active_param_count(self) -> int:
        """Active params per token (= param_count for non-MoE)."""
        if self.arch_type != "moe":
            return self.param_count()
        d = self.d_model
        dense_ffn = self.n_experts * 3 * d * self.d_ff
        active_ffn = self.experts_per_token * 3 * d * self.d_ff
        return self.param_count() - self.n_layers * (dense_ffn - active_ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["training", "prefill", "decode"]


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "training"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    """The paper's knobs (§3.1-§3.3)."""
    n_clouds: int = 3
    local_steps: int = 4                  # H local steps between sync rounds (§3.2)
    aggregation: str = "fedavg"           # fedavg | dynamic | gradient | async
    # dynamic weighting temperature for softmax(-L_i/τ) (formula 2; τ=1 in paper)
    dynamic_temp: float = 1.0
    async_alpha: float = 0.5              # α in formula 4
    # sample counts per cloud (n_i in formula 1); None → uniform
    cloud_sample_counts: tuple[int, ...] | None = None
    # --- §3.2 communication optimization ---
    compression: str = "none"             # none | topk | int8 | topk+int8
    topk_ratio: float = 0.01              # keep-fraction for top-k sparsification
    error_feedback: bool = True
    # beyond-paper: carry the cross-pod sync payload as int8 INSIDE the XLA
    # program (shard_map all-gather of quantized deltas + local dequant/
    # combine) instead of a dense fp32 all-reduce — 8× fewer DCN bytes,
    # visible in the dry-run HLO rather than only in the wire-cost model.
    wire_int8: bool = False
    # --- privacy (§3.1 "Ensure Data Security") ---
    dp_clip: float = 0.0                  # 0 disables DP
    dp_noise_mult: float = 0.0
    secure_agg: bool = False              # additive-mask secure aggregation
    # --- outer optimizer applied to the aggregated delta (beyond-paper) ---
    outer_optimizer: str = "none"         # none | sgd | nesterov
    outer_lr: float = 1.0
    outer_momentum: float = 0.9


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 256
    global_batch: int = 8
    steps: int = 100
    lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    log_every: int = 10
    eval_every: int = 0
    checkpoint_every: int = 0
    checkpoint_dir: str = ""


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    data: int = 1
    model: int = 1
    pods: int = 1

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods
