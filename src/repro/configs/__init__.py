"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    INPUT_SHAPES,
    FederatedConfig,
    MeshConfig,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
)

_ARCH_MODULES = {
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "whisper-medium": "repro.configs.whisper_medium",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).smoke_config()


def get_shape(shape_id: str) -> ShapeConfig:
    return INPUT_SHAPES[shape_id]


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "FederatedConfig",
    "MeshConfig",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "get_config",
    "get_smoke_config",
    "get_shape",
]
