"""Mistral-Nemo 12B — dense, 128k context. [hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.configs.base import ModelConfig

ARCH_ID = "mistral-nemo-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=131_072,
        head_dim=128,
        rope_theta=1_000_000.0,   # 128k-context rope base
        act="silu",
        fsdp=True,
        source="[hf:mistralai/Mistral-Nemo-Base-2407]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=352,
        vocab_size=512,
        head_dim=32,
        rope_theta=1_000_000.0,
        act="silu",
        remat=False,
        source="[hf:mistralai/Mistral-Nemo-Base-2407]",
    )
