"""RecurrentGemma-2B — Griffin: RG-LRU recurrent blocks + local attention,
repeating (recurrent, recurrent, local-attn). [arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "recurrentgemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,             # MQA in the local-attention blocks
        d_ff=7680,
        vocab_size=256_000,
        head_dim=256,
        block_pattern=("rglru", "rglru", "attn"),
        lru_width=2560,
        conv_width=4,
        local_attn_window=2048,
        act="gelu",               # GeGLU MLP per Griffin
        fsdp=False,
        source="[arXiv:2402.19427]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="hybrid",
        n_layers=3,               # one full (rglru, rglru, attn) pattern
        d_model=128,
        n_heads=4,
        n_kv_heads=1,
        d_ff=384,
        vocab_size=512,
        head_dim=32,
        block_pattern=("rglru", "rglru", "attn"),
        lru_width=128,
        conv_width=4,
        local_attn_window=64,
        act="gelu",
        remat=False,
        source="[arXiv:2402.19427]",
    )
