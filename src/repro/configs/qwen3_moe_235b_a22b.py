"""Qwen3-MoE 235B-A22B — 128 experts top-8, 94 layers. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,
        vocab_size=151_936,
        head_dim=128,
        n_experts=128,
        experts_per_token=8,
        rope_theta=1_000_000.0,
        act="silu",
        fsdp=True,               # 470 GB bf16 params: 2D (model x data) sharding required
        source="[hf:Qwen/Qwen3-30B-A3B]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        head_dim=32,
        n_experts=4,
        experts_per_token=2,
        act="silu",
        remat=False,
        source="[hf:Qwen/Qwen3-30B-A3B]",
    )
