"""Phi-4-mini 3.8B — dense, RoPE + SwiGLU + GQA. [arXiv:2412.08905]"""
from repro.configs.base import ModelConfig

ARCH_ID = "phi4-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=200_064,
        rope_theta=10_000.0,
        act="silu",
        tie_embeddings=True,
        fsdp=False,
        source="[arXiv:2412.08905]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="dense",
        n_layers=2,
        d_model=120,
        n_heads=4,
        n_kv_heads=2,
        d_ff=320,
        vocab_size=512,
        act="silu",
        tie_embeddings=True,
        remat=False,
        source="[arXiv:2412.08905]",
    )
