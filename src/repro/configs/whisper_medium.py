"""Whisper-medium — encoder-decoder; conv/mel frontend is a STUB (the
assignment's carve-out): ``input_specs`` provides precomputed 1500-frame
embeddings. [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "whisper-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="audio",
        n_layers=24,              # decoder depth
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=51_865,
        encoder_layers=24,
        encoder_seq=1500,
        act="gelu",               # whisper uses plain GELU MLPs + LayerNorm
        tie_embeddings=True,
        fsdp=False,
        source="[arXiv:2212.04356]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="audio",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        encoder_layers=2,
        encoder_seq=48,
        act="gelu",
        tie_embeddings=True,
        remat=False,
        source="[arXiv:2212.04356]",
    )
