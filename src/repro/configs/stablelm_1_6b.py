"""StableLM-2 1.6B — dense decoder. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig

ARCH_ID = "stablelm-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100_352,
        rope_theta=10_000.0,
        act="silu",
        fsdp=False,
        source="[hf:stabilityai/stablelm-2-1_6b]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=352,
        vocab_size=512,
        act="silu",
        remat=False,
        source="[hf:stabilityai/stablelm-2-1_6b]",
    )
