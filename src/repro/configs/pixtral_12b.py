"""Pixtral-12B — VLM: mistral-nemo-style decoder consuming stub patch
embeddings from a (stubbed) pixtral-ViT frontend. [hf:mistralai/Pixtral-12B-2409]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "pixtral-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=131_072,
        head_dim=128,
        rope_theta=1_000_000.0,
        vision_seq=256,          # stub: one 1024x1024 image → 256 merged patch embeds
        act="silu",
        fsdp=True,
        source="[hf:mistralai/Pixtral-12B-2409]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=352,
        vocab_size=512,
        head_dim=32,
        vision_seq=16,
        act="silu",
        remat=False,
        source="[hf:mistralai/Pixtral-12B-2409]",
    )
