from repro.utils import tree as tree
from repro.utils import hlo as hlo
