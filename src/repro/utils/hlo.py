"""Trip-count-aware cost model over scheduled HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which silently
drops ~n_layers× of the work in a scan-over-layers model. This module
re-derives the roofline inputs from the compiled module's text, where XLA
records ``known_trip_count`` on every counted loop:

* **FLOPs** — every ``dot``/``convolution`` is 2·out_elems·K, accumulated
  recursively through while bodies (×trip count), conditionals (branches
  summed — our sync round lives in a cond branch), and fusion bodies.
* **HBM bytes** — on TPU, every top-level instruction boundary in a
  scheduled computation is an HBM buffer (fusions internalize their
  intermediates in VMEM). Bytes = Σ (operand + output sizes) over scheduled
  instructions, skipping no-copy ops (tuple/get-tuple-element/bitcast/
  parameter/constant), recursively with trip multipliers.
* **Collectives** — all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute ops with their replica groups, multiplied by enclosing
  trip counts, classified cross-pod vs intra-pod by whether any replica
  group spans a pod boundary (device_id // pod_size differs). Ring-algorithm
  per-device link-byte accounting.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

_DTYPE_BYTES = {
    "pred": 1,
    "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+[a-z0-9]*|pred)\[([0-9,]*)\]")

NO_COPY_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "rng-bit-generator",
    # dtype converts fuse into their consumers on TPU. The CPU backend
    # legalizes bf16 by materializing convert-to-f32/convert-back pairs
    # around whole buffers (e.g. an entire KV cache) — traffic that does not
    # exist on the target hardware, so it must not count toward the roofline.
    "convert",
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _shapes_bytes(text: str) -> int:
    return sum(
        _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
        for dt, dims in _SHAPE_RE.findall(text)
    )


def _parse_iota_groups(spec: str):
    m = re.match(r"\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?", spec.strip())
    if not m:
        return None
    group_dims = [int(x) for x in m.group(1).split(",")]
    iota_dims = [int(x) for x in m.group(2).split(",")]
    flat = np.arange(int(np.prod(iota_dims))).reshape(iota_dims)
    if m.group(3):
        flat = flat.transpose([int(x) for x in m.group(3).split(",")])
    flat = flat.reshape(-1)
    ngroups = group_dims[0]
    gsize = int(np.prod(group_dims[1:]))
    return [flat[i * gsize : (i + 1) * gsize].tolist() for i in range(ngroups)]


def _parse_replica_groups(attrs: str):
    m = re.search(r"replica_groups=\{(.*?)\}\}", attrs)
    if m:
        groups = []
        for grp in re.findall(r"\{([0-9,\s]*)\}", m.group(1) + "}"):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids:
                groups.append(ids)
        if groups:
            return groups
    m = re.search(
        r"replica_groups=(\[[0-9,]+\]<=\[[0-9,]+\](?:T\([0-9,]+\))?)", attrs
    )
    if m:
        return _parse_iota_groups(m.group(1))
    m = re.search(r"replica_groups=\{\}", attrs)
    return None


@dataclasses.dataclass
class Instr:
    opcode: str
    result_bytes: int
    operand_bytes: int
    flops: float = 0.0
    called: tuple = ()            # computation names (while body, cond branches, fusion)
    trip: int = 1                 # known_trip_count for while
    replica_groups: Any = None
    line: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list


_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")
_PARAM_DECL = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[a-z0-9\[\],]+)(?:\{[0-9,]*\})?)")
_OPCODE = re.compile(r"^(.*?)\s([a-z][a-z0-9\-]*)\(")


def _dot_flops(result_type: str, operand_str: str, attrs: str) -> float:
    out_elems = sum(_shape_elems(d) for _, d in _SHAPE_RE.findall(result_type))
    shapes = _SHAPE_RE.findall(operand_str)
    if not shapes:
        return 0.0
    lhs_dims = [int(x) for x in shapes[0][1].split(",")] if shapes[0][1] else []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
    k = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                k *= lhs_dims[di]
    return 2.0 * out_elems * k


def _conv_flops(result_type: str, operand_str: str) -> float:
    out_elems = sum(_shape_elems(d) for _, d in _SHAPE_RE.findall(result_type))
    shapes = _SHAPE_RE.findall(operand_str)
    if len(shapes) < 2:
        return 0.0
    kernel_elems = _shape_elems(shapes[1][1])
    kernel_dims = [int(x) for x in shapes[1][1].split(",")] if shapes[1][1] else [1]
    out_features = kernel_dims[-1] if kernel_dims else 1
    return 2.0 * out_elems * (kernel_elems / max(out_features, 1))


def parse_module(text: str) -> dict:
    """Parse scheduled HLO text. Operands print as bare %names, so each
    computation builds a name→type symbol table (parameters from the header,
    results from prior instructions) to recover operand shapes."""
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    symtab: dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            s = line.strip()
            if s.endswith("{") and (s.startswith("%") or s.startswith("ENTRY")):
                m = _COMP_NAME.match(s)
                if m:
                    current = Computation(m.group(1), [])
                    symtab = {}
                    # parameters: "(%name: type, name: type, ...) -> ..."
                    header = s[m.end(1):]
                    arrow = header.find("->")
                    header = header[:arrow] if arrow >= 0 else header
                    for pname, ptype in _PARAM_DECL.findall(header):
                        symtab[pname] = ptype
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        iname, rest = m.group(1), m.group(2)
        om = _OPCODE.match(rest)
        if not om:
            continue
        result_type, opcode = om.group(1), om.group(2)
        symtab[iname] = result_type
        paren = rest.find("(", om.end(2))
        depth, end = 0, paren
        for i in range(paren, len(rest)):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[paren + 1 : end]
        attrs = rest[end + 1 :]
        # resolve operand shapes through the symbol table
        op_types = [
            symtab.get(nm, "") for nm in _OPERAND_NAME.findall(operand_str)
        ]
        operand_types_str = " ".join(op_types) if op_types else operand_str

        instr = Instr(
            opcode=opcode,
            result_bytes=_shapes_bytes(result_type),
            operand_bytes=_shapes_bytes(operand_types_str),
            line=line.strip()[:160],
        )
        # TPU-faithful traffic for windowed ops: dynamic-update-slice writes
        # in place (traffic = the updated slice, read+write), dynamic-slice
        # reads only the sliced region — not the whole operand buffer.
        if opcode == "dynamic-update-slice":
            upd = _shapes_bytes(op_types[1]) if len(op_types) > 1 else instr.result_bytes
            instr.operand_bytes = upd
            instr.result_bytes = upd
        elif opcode == "dynamic-slice":
            instr.operand_bytes = instr.result_bytes
        if opcode == "dot":
            instr.flops = _dot_flops(result_type, operand_types_str, attrs)
        elif opcode == "convolution":
            instr.flops = _conv_flops(result_type, operand_types_str)
        elif opcode == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", attrs)
            bm = re.search(r"body=%?([\w.\-]+)", attrs)
            instr.called = tuple(x for x in (bm and bm.group(1),) if x)
            tm = re.search(r'known_trip_count["\s:{]+n["\s:]+"?(\d+)', attrs)
            instr.trip = int(tm.group(1)) if tm else 1
        elif opcode == "conditional":
            brs = re.findall(r"(?:branch_computations=\{([^}]*)\}|(?:true|false)_computation=%?([\w.\-]+))", attrs)
            names: list[str] = []
            for grp, single in brs:
                if grp:
                    names += [x.strip().lstrip("%") for x in grp.split(",")]
                if single:
                    names.append(single)
            instr.called = tuple(names)
        elif opcode in ("fusion", "call", "async-start"):
            cm = re.search(r"(?:calls|called_computation)=%?([\w.\-]+)", attrs)
            if cm:
                instr.called = (cm.group(1),)
        base = opcode.replace("-start", "")
        if base in COLLECTIVE_OPS and not opcode.endswith("-done"):
            instr.replica_groups = _parse_replica_groups(attrs)
            instr.opcode = base if opcode.endswith("-start") else opcode
            instr.called = ()   # don't double count async bodies
        current.instrs.append(instr)
    return comps


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    operand_bytes: int
    output_bytes: int
    group_size: int
    num_groups: int
    cross_pod: bool
    count: float                  # multiplicity from enclosing loops
    line: str

    @property
    def link_bytes_per_device(self) -> float:
        g = max(self.group_size, 1)
        frac = (g - 1) / g
        if self.kind == "all-gather":
            per = frac * self.output_bytes
        elif self.kind == "all-reduce":
            per = 2.0 * frac * self.operand_bytes
        elif self.kind in ("reduce-scatter", "all-to-all"):
            per = frac * self.operand_bytes
        else:  # collective-permute
            per = float(self.operand_bytes)
        return per * self.count


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collectives: list

    def link_bytes(self, cross_pod: bool | None = None) -> float:
        return sum(
            c.link_bytes_per_device
            for c in self.collectives
            if cross_pod is None or c.cross_pod == cross_pod
        )

    def by_kind(self) -> dict:
        out: dict[str, float] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0.0) + c.link_bytes_per_device
        return out

    def n_collectives(self) -> float:
        return sum(c.count for c in self.collectives)


def _groups_cross_pod(groups, pod_size: int) -> bool:
    if not groups or pod_size <= 0:
        return False
    for grp in groups:
        if len({d // pod_size for d in grp}) > 1:
            return True
    return False


def analyze(text: str, pod_size: int = 0, entry: str | None = None) -> HloCost:
    comps = parse_module(text)
    if entry is None:
        # entry computation: the one containing "main" or the last ENTRY-parsed
        cands = [n for n in comps if "main" in n]
        entry = cands[0] if cands else max(comps, key=lambda n: len(comps[n].instrs))

    memo: dict[tuple[str, bool], tuple[float, float, list]] = {}

    def walk(name: str, count_bytes: bool, depth: int = 0):
        """Returns (flops, bytes, collectives with count=1 basis)."""
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return (0.0, 0.0, [])
        flops = 0.0
        nbytes = 0.0
        colls: list[CollectiveOp] = []
        for ins in comp.instrs:
            if ins.opcode in COLLECTIVE_OPS:
                groups = ins.replica_groups
                gsize = len(groups[0]) if groups else 1
                ngroups = len(groups) if groups else 1
                ob = ins.operand_bytes or ins.result_bytes
                colls.append(
                    CollectiveOp(
                        kind=ins.opcode,
                        operand_bytes=ob,
                        output_bytes=ins.result_bytes or ob,
                        group_size=gsize,
                        num_groups=ngroups,
                        cross_pod=_groups_cross_pod(groups, pod_size),
                        count=1.0,
                        line=ins.line,
                    )
                )
                nbytes += ins.operand_bytes + ins.result_bytes if count_bytes else 0
                continue
            if ins.opcode == "while":
                for sub in ins.called:
                    f, b, c = walk(sub, count_bytes, depth + 1)
                    flops += f * ins.trip
                    nbytes += b * ins.trip
                    for cc in c:
                        colls.append(dataclasses.replace(cc, count=cc.count * ins.trip))
                continue
            if ins.opcode == "conditional":
                for sub in ins.called:
                    f, b, c = walk(sub, count_bytes, depth + 1)
                    flops += f
                    nbytes += b
                    colls.extend(c)
                continue
            if ins.opcode in ("fusion", "call", "async-start"):
                body_bytes = 0.0
                for sub in ins.called:
                    f, bb, c = walk(sub, True, depth + 1)
                    flops += f
                    body_bytes += bb
                    colls.extend(c)
                if count_bytes and ins.opcode == "fusion":
                    # HBM traffic of a fusion is its boundary (operands read +
                    # outputs written) — except when the body shows the
                    # boundary is inflated: pure-convert fusions (CPU bf16
                    # legalization; free on TPU) and in-place dynamic-update
                    # fusions (TPU aliases the buffer; traffic = the updated
                    # window, not the whole operand). min() picks the
                    # TPU-faithful reading in both cases.
                    nbytes += min(ins.operand_bytes + ins.result_bytes, body_bytes)
                # call/async boundaries are free
                continue
            flops += ins.flops
            if count_bytes and ins.opcode not in NO_COPY_OPS:
                nbytes += ins.operand_bytes + ins.result_bytes
        memo[key] = (flops, nbytes, colls)
        return memo[key]

    flops, nbytes, colls = walk(entry, True)
    return HloCost(flops=flops, hbm_bytes=nbytes, collectives=colls)


# --------------------------------------------------------- legacy interface
def parse_collectives(hlo_text: str, pod_size: int = 0):
    """Back-compat shim: collective summary over the whole module with trip
    multipliers."""
    cost = analyze(hlo_text, pod_size=pod_size)

    class _Summary:
        def __init__(self, cost):
            self._cost = cost
            self.ops = cost.collectives

        def total_link_bytes_per_device(self, cross_pod=None):
            return self._cost.link_bytes(cross_pod)

        def count(self, kind=None):
            return sum(
                c.count for c in self.ops if kind is None or c.kind == kind
            )

        def by_kind(self):
            return self._cost.by_kind()

    return _Summary(cost)
