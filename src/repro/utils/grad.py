"""Microbatched gradient accumulation.

Large-arch train steps can't hold a full per-device batch of rematerialized
activations (94 layers × B·S·D), so the batch is split into k microbatches
scanned sequentially, accumulating grads in fp32. Loss/metrics are
microbatch means; the result is numerically the same token-mean gradient."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


def microbatched_value_and_grad(
    loss_fn: Callable, params: Pytree, batch: Pytree, microbatches: int,
    grad_shardings: Pytree | None = None,
):
    """loss_fn(params, batch) -> (loss, metrics dict). Returns
    ((loss, metrics), grads) with grads in fp32.

    grad_shardings: optional NamedSharding tree matching params. Pinning the
    fp32 accumulator to the parameter sharding makes SPMD reduce-scatter
    each microbatch's gradient into the shards instead of all-reducing the
    full fp32 tensor every microbatch (ZeRO-2; ~2× less grad traffic)."""

    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, grad_shardings
        )

    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        return (loss, metrics), _pin(grads)

    k = microbatches

    def reshape(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by {k} microbatches"
        return x.reshape(k, b // k, *x.shape[1:])

    mbs = jax.tree_util.tree_map(reshape, batch)
    # (p·0) instead of zeros(): the accumulator inherits the PARAMETER
    # sharding through propagation. A bare zeros() tree is unsharded, which
    # makes XLA keep every microbatch's fp32 gradient fully replicated and
    # all-reduce it whole (~1.6 TB/dev/step on qwen3-235b) instead of
    # reduce-scattering into the FSDP shards (ZeRO-2).
    zero = jax.tree_util.tree_map(
        lambda p: (p * 0).astype(jnp.float32), params
    )

    zero = _pin(zero)

    def body(acc, mb):
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree_util.tree_map(
            lambda a, gg: a + gg.astype(jnp.float32), acc, g
        )
        return _pin(acc), (loss, metrics)

    grads, (losses, metrics) = jax.lax.scan(body, zero, mbs)
    grads = jax.tree_util.tree_map(lambda g: g / k, grads)
    loss = jnp.mean(losses)
    metrics = jax.tree_util.tree_map(jnp.mean, metrics)
    return (loss, metrics), grads
