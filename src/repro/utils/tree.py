"""Pytree utilities used across the framework.

Everything here is pure-JAX and shape-polymorphic; these helpers are the
vocabulary the federated layer (core/) uses to talk about "the model" without
knowing the architecture.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_map(f: Callable, *trees: Pytree) -> Pytree:
    return jax.tree_util.tree_map(f, *trees)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return tree_map(lambda x, y: x + y, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return tree_map(lambda x, y: x - y, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return tree_map(lambda x: x * s, a)


def tree_zeros_like(a: Pytree) -> Pytree:
    return tree_map(jnp.zeros_like, a)


def tree_dot(a: Pytree, b: Pytree) -> jax.Array:
    """Inner product between two pytrees (fp32 accumulate)."""
    leaves = tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sq_norm(a: Pytree) -> jax.Array:
    """Squared global L2 norm of a pytree (fp32 accumulate)."""
    leaves = tree_map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a: Pytree) -> jax.Array:
    return jnp.sqrt(tree_sq_norm(a))


def tree_count_params(a: Pytree) -> int:
    """Static parameter count (python int; works on ShapeDtypeStructs too)."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(a))


def tree_bytes(a: Pytree) -> int:
    """Static byte count of a pytree of arrays / ShapeDtypeStructs."""
    total = 0
    for x in jax.tree_util.tree_leaves(a):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_cast(a: Pytree, dtype) -> Pytree:
    return tree_map(lambda x: x.astype(dtype), a)


def tree_where(pred, a: Pytree, b: Pytree) -> Pytree:
    """Per-leaf jnp.where with a scalar predicate (select between pytrees)."""
    return tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_split_keys(key: jax.Array, tree: Pytree) -> Pytree:
    """One PRNG key per leaf, shaped like the tree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(n) < 1024.0 or unit == "PB":
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PB"


def fmt_flops(n: float) -> str:
    for unit in ("FLOP", "KFLOP", "MFLOP", "GFLOP", "TFLOP", "PFLOP"):
        if abs(n) < 1000.0 or unit == "PFLOP":
            return f"{n:.2f} {unit}"
        n /= 1000.0
    return f"{n:.2f} PFLOP"


def round_up(x: int, to: int) -> int:
    return int(math.ceil(x / to) * to)
