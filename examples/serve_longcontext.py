"""Serving example: batched generation with ring-buffer sliding-window
decode and the Pallas flash-decode kernel.

    PYTHONPATH=src python examples/serve_longcontext.py

Generates from three architecture families (dense + SWA ring cache, Griffin
hybrid with O(1) recurrent state, xLSTM matrix memory) and shows that state
stays constant while decoding past the window — the mechanism behind the
long_500k input shape. The dense model runs both the jnp decode path and
the Pallas kernel (interpret mode) and checks they agree."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import attention, build_model
from repro.utils.tree import tree_bytes

WINDOW = 16
DECODE_STEPS = 64   # 4x past the window


def decode_run(arch: str, use_kernel: bool = False):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    cache = model.init_cache(params, batch, max_seq=DECODE_STEPS, window=WINDOW)
    attention.set_decode_kernel(use_kernel)
    try:
        dec = jax.jit(lambda p, c, t: model.decode(p, c, t, window=WINDOW))
        tok = jnp.ones((2, 1), jnp.int32)
        outs = []
        t0 = time.time()
        for _ in range(DECODE_STEPS):
            cache, logits = dec(params, cache, tok)
            tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
            outs.append(logits)
        dt = time.time() - t0
    finally:
        attention.set_decode_kernel(False)
    return np.asarray(jnp.stack(outs, 1)), tree_bytes(cache), dt


def main():
    for arch in ("mistral-nemo-12b", "recurrentgemma-2b", "xlstm-125m"):
        logits, cache_bytes, dt = decode_run(arch)
        print(f"{arch:22s} decoded {DECODE_STEPS} steps past a {WINDOW}-token "
              f"window; state={cache_bytes/1e6:.2f} MB (constant); {dt:.1f}s")

    # kernel-vs-jnp agreement on the dense arch
    a, _, _ = decode_run("mistral-nemo-12b", use_kernel=False)
    b, _, _ = decode_run("mistral-nemo-12b", use_kernel=True)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    print(f"pallas flash-decode kernel vs jnp path: rel err {err:.2e}")


if __name__ == "__main__":
    main()
