"""Privacy-preserving cross-cloud training: DP clipping/noise + secure
aggregation (the paper's §3.1 "Ensure Data Security").

    PYTHONPATH=src python examples/private_training.py

Demonstrates:
 1. DP-FedAvg: per-cloud update clipping + calibrated Gaussian noise, with
    the privacy/utility trade-off across noise multipliers,
 2. secure aggregation: pairwise-masked updates whose masks cancel exactly
    in the cross-cloud sum (the server never sees an individual update)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core import privacy
from repro.core.federated import FederatedTrainer
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model
from repro.utils.tree import tree_map, tree_norm


def dp_sweep():
    print("=== DP-FedAvg: privacy/utility trade-off ===")
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.1)
    mix = dirichlet_mixtures(jax.random.PRNGKey(2), 3, 4, beta=0.3)
    for noise_mult in (0.0, 0.3, 1.0, 3.0):
        fed = FederatedConfig(
            n_clouds=3, local_steps=2, aggregation="fedavg",
            dp_clip=0.5, dp_noise_mult=noise_mult,
        )
        trainer = FederatedTrainer(model, fed, TrainConfig(steps=60, lr=3e-3, warmup_steps=6))
        state = trainer.init_state(jax.random.PRNGKey(0))
        step = jax.jit(trainer.train_step)
        losses = []
        for i in range(60):
            batch = federated_batch(
                corpus, jax.random.fold_in(jax.random.PRNGKey(3), i), mix, 4, 32
            )
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        print(f"  σ={noise_mult:3.1f}: final loss {np.mean(losses[-8:]):.4f}")


def secure_agg_demo():
    print("\n=== secure aggregation: masks cancel exactly ===")
    key = jax.random.PRNGKey(0)
    updates = [
        {"w": 0.01 * jax.random.normal(jax.random.fold_in(key, i), (4, 6))}
        for i in range(3)
    ]
    masked = [
        privacy.mask_update(privacy.to_fixed(u), i, 3, round_idx=0)
        for i, u in enumerate(updates)
    ]
    print("  raw update[0][:3]:     ", np.asarray(updates[0]["w"]).ravel()[:3])
    print("  masked transmit[0][:3]:", np.asarray(masked[0]["w"]).ravel()[:3],
          " <- uniform noise to the server")
    agg = privacy.from_fixed(privacy.secure_sum(masked), jnp.float32)
    plain = updates[0]
    for u in updates[1:]:
        plain = tree_map(lambda a, b: a + b, plain, u)
    err = float(tree_norm(tree_map(lambda a, b: a - b, agg, plain)))
    print(f"  |secure_sum - plain_sum| = {err:.2e} "
          f"(fixed-point quantization only)")


if __name__ == "__main__":
    dp_sweep()
    secure_agg_demo()
