"""Continuous-batching serving demo: slot pool, staggered arrivals,
immediate backfill.

    PYTHONPATH=src python examples/continuous_serving.py

Eight requests with different prompt/output lengths arrive over ~a second
and are served through a pool of THREE KV-cache slots. The engine admits
each request into a free slot the moment one exists (retired sequences are
backfilled immediately, no batch barrier), interleaves prefill with decode,
and — the property the test suite pins — produces exactly the tokens the
sequential single-batch oracle would have produced for every request.

The second half re-runs the same trace with a sliding-window ring cache and
with the Pallas flash-decode kernel (interpret mode on CPU) to show both
thread through the engine unchanged, then serves a burst of simultaneous
arrivals with batched multi-slot prefill (one forward per admission round)
and per-request temperature/top-k/top-p sampling.

The finale is the PAGED KV cache: the same trace through a shared page
pool (token-identical to the ring engine), then an OVERSUBSCRIBED pool —
half the memory, watermark admission, youngest-slot preemption with
token-exact resume — plus one request whose prompt+gen exceeds max_seq,
which ring mode must reject and the paged pool serves.

The LAST act is tensor-parallel serving: the same engine sharded over a
2-device ``model``-axis mesh (this script forces a 2-device CPU host
platform, so it runs anywhere). Every shard holds its attention-head
slice of EVERY page, so per-device KV bytes drop by the shard count while
the page budget stays whole — a long prompt that an engine confined to
one shard's proportional memory slice must reject (``AdmissionError:
exceeds_pool``) streams through the meshed pool, with tokens bitwise
identical to the single-device engine.
"""
import dataclasses
import os
import time

# XLA reads this once at jaxlib import — it cannot be set later, so the
# sharded finale provisions its 2 virtual CPU devices before ``import jax``
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2"
    ).strip()

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data import SyntheticCorpus
from repro.launch.engine import Request, ServeEngine
from repro.launch.sampling import SamplingParams
from repro.models import build_model

ARCH = "stablelm-1.6b"
SLOTS = 3


def build_trace(cfg, n=8, seed=0):
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.0)
    rng = np.random.default_rng(seed)
    reqs = []
    for r in range(n):
        plen = int(rng.choice([8, 16, 24]))
        gen = int(rng.choice([4, 8, 12]))
        prompt = np.asarray(
            corpus.sample(jax.random.PRNGKey(seed + r), np.ones(4) / 4, 1, plen)[
                "tokens"
            ][0],
            np.int32,
        )
        reqs.append(
            Request(
                uid=r, prompt=prompt, max_new_tokens=gen,
                arrival_time=float(r) * 0.15,
            )
        )
    return reqs


def serve(engine, reqs, label):
    t0 = time.time()
    outs = engine.run(reqs, realtime=True)
    wall = time.time() - t0
    total = sum(len(o.tokens) for o in outs)
    print(f"\n=== {label} ===")
    print(
        f"{len(outs)} requests, {total} tokens, {engine.steps} engine steps, "
        f"{wall:.2f}s ({total / max(wall, 1e-9):.1f} tok/s)"
    )
    for o in outs:
        print(
            f"  req {o.uid}: slot {o.slot}  prompt {len(o.prompt):2d}  "
            f"gen {len(o.tokens):2d} [{o.finish_reason}]  "
            f"ttft {o.ttft * 1e3:6.1f} ms  latency {o.latency * 1e3:6.1f} ms  "
            f"tokens {o.tokens[:6]}{'...' if len(o.tokens) > 6 else ''}"
        )
    reused = {
        uid: hist for uid, hist in engine.slot_history.items()
    }
    by_slot = {}
    for uid, hist in sorted(reused.items()):
        for s in hist:
            by_slot.setdefault(s, []).append(uid)
    for s in sorted(by_slot):
        print(f"  slot {s} served requests {by_slot[s]}")
    return outs


def main():
    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = build_trace(cfg)
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in reqs)

    engine = ServeEngine(model, params, num_slots=SLOTS, max_seq=max_seq)
    base = serve(engine, reqs, f"continuous batching · {SLOTS} slots")

    engine_w = ServeEngine(
        model, params, num_slots=SLOTS, max_seq=max_seq, window=8
    )
    serve(engine_w, build_trace(cfg), "sliding-window ring cache (window=8)")

    engine_k = ServeEngine(
        model, params, num_slots=SLOTS, max_seq=max_seq, use_kernel=True
    )
    kout = serve(engine_k, build_trace(cfg), "Pallas flash-decode kernel")
    agree = all(
        a.tokens == b.tokens for a, b in zip(base, kout)
    )
    print(f"\nkernel path token-identical to jnp path: {agree}")

    # burst: every request arrives at t=0; batched admission prefills each
    # scheduling round in ONE forward, and each request samples its
    # continuation on its own PRNG stream (engine seed + uid)
    burst = build_trace(cfg)
    for r in burst:
        r.arrival_time = 0.0
        r.sampling = SamplingParams(temperature=0.8, top_k=40, top_p=0.95)
    engine_s = ServeEngine(
        model, params, num_slots=SLOTS, max_seq=max_seq, seed=1
    )
    souts = serve(engine_s, burst, "burst arrivals · batched prefill + sampling")
    print(
        f"\nprefill dispatches for {len(souts)} burst requests: "
        f"{engine_s.prefill_dispatches} (batched multi-slot prefill)"
    )

    # paged KV cache: one shared page pool + per-slot page tables replaces
    # the per-slot rings — same tokens, bit for bit
    engine_p = ServeEngine(
        model, params, num_slots=SLOTS, max_seq=max_seq,
        paged_cache=True, page_size=8,
    )
    pouts = serve(engine_p, build_trace(cfg), "paged KV pool (ring-equivalent)")
    agree = all(a.tokens == b.tokens for a, b in zip(base, pouts))
    print(f"\npaged engine token-identical to ring engine: {agree}")

    # oversubscribed: half the pages. Admission throttles on a watermark,
    # decode OOM preempts the youngest slot back to the queue, and resumed
    # requests still finish with exactly the same tokens.
    pages_auto = engine_p.num_pages
    engine_t = ServeEngine(
        model, params, num_slots=SLOTS, max_seq=max_seq,
        paged_cache=True, page_size=8, num_pages=max(4, pages_auto // 2),
        watermark_pages=1,
    )
    touts = serve(
        engine_t, build_trace(cfg),
        f"oversubscribed pool · {engine_t.pool.capacity} pages "
        f"(vs {pages_auto - 1} ring-equivalent)",
    )
    agree = all(a.tokens == b.tokens for a, b in zip(base, touts))
    stats = engine_t.pool_stats
    print(
        f"\n{stats['preemptions']} preemptions, peak occupancy "
        f"{stats['occupancy_max']:.0%} — tokens still identical: {agree}"
    )

    # beyond ring capacity: prompt + gen > max_seq has no slot to fit in
    # ring mode (submit raises) but spans the shared pool in paged mode
    long_req = build_trace(cfg, n=1, seed=7)[0]
    long_req.max_new_tokens = max_seq  # prompt + gen ≈ 2× max_seq
    long_req.arrival_time = 0.0
    louts = engine_p.run([long_req])
    print(
        f"\noversized request (prompt {len(long_req.prompt)} + gen "
        f"{long_req.max_new_tokens} > max_seq {max_seq}): paged engine "
        f"generated {len(louts[0].tokens)} tokens from a "
        f"{engine_p.cap}-token logical ring"
    )

    # prefix sharing: every request opens with the same system prompt;
    # after the first retirement publishes its pages, later requests map
    # them and prefill only their unique tail — same tokens, a fraction
    # of the prefill compute
    rng = np.random.default_rng(3)
    system = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    shared = [
        Request(
            uid=100 + j,
            prompt=np.concatenate(
                [system, rng.integers(1, cfg.vocab_size, 4 + j).astype(np.int32)]
            ),
            max_new_tokens=6,
        )
        for j in range(6)
    ]
    engine_x = ServeEngine(
        model, params, num_slots=SLOTS, max_seq=max_seq + 16,
        paged_cache=True, page_size=8, prefix_cache=True,
    )
    engine_n = ServeEngine(
        model, params, num_slots=SLOTS, max_seq=max_seq + 16,
        paged_cache=True, page_size=8,
    )
    xouts = engine_x.run([dataclasses.replace(r) for r in shared])
    nouts = engine_n.run([dataclasses.replace(r) for r in shared])
    agree = all(a.tokens == b.tokens for a, b in zip(xouts, nouts))
    stats = engine_x.pool_stats
    print(
        f"\nshared system prompt · prefix cache: prefilled "
        f"{engine_x.prefill_tokens} tokens vs {engine_n.prefill_tokens} "
        f"without sharing (hit rate {stats['prefix_hit_rate']:.0%}, "
        f"{stats['prefix_hit_pages']} pages aliased) — "
        f"tokens identical: {agree}"
    )

    # tensor-parallel finale: shard the SAME engine over a 2-device
    # `model`-axis mesh. Heads and the pool's kv-head dim split across
    # shards; page tables stay host-side, so scheduling, preemption and
    # prefix sharing are untouched — and the output is bitwise identical.
    from repro.launch.engine import AdmissionError
    from repro.launch.mesh import make_serve_mesh

    S = 2
    engine_m = ServeEngine(
        model, params, num_slots=SLOTS, max_seq=2 * max_seq,
        paged_cache=True, page_size=8, mesh=make_serve_mesh(S),
    )
    mouts = serve(
        engine_m, build_trace(cfg), f"tensor-parallel · {S}-shard CPU mesh"
    )
    agree = all(a.tokens == b.tokens for a, b in zip(base, mouts))
    ps = engine_m.pool_stats
    print(
        f"\n{ps['shards']}-shard mesh {ps['mesh_axes']}: per-shard KV "
        f"bytes 1/{S} of the single-device pool — tokens bitwise "
        f"identical to the unsharded engine: {agree}"
    )

    # memory headroom: every shard holds its HEAD SLICE of every page, so
    # the meshed engine keeps the FULL page budget at 1/S the per-device
    # bytes. The alternative — one device holding a proportional 1/S-page
    # pool — must reject a long prompt the meshed pool streams through.
    cap = engine_m.pool.capacity
    long_req = build_trace(cfg, n=1, seed=11)[0]
    long_req.arrival_time = 0.0
    long_req.max_new_tokens = (cap // S + 2) * 8 - len(long_req.prompt)
    slice_engine = ServeEngine(
        model, params, num_slots=1, max_seq=2 * max_seq,
        paged_cache=True, page_size=8, num_pages=cap // S + 1,
    )
    try:
        slice_engine.run([dataclasses.replace(long_req)])
        raise AssertionError("1/S-slice pool admitted an oversized request")
    except AdmissionError as e:
        print(
            f"\n1/{S}-slice pool ({slice_engine.pool.capacity} pages) "
            f"rejects the {len(long_req.prompt)}+{long_req.max_new_tokens}"
            f"-token request: {e.reason}"
        )
    mlong = engine_m.run([dataclasses.replace(long_req)])
    print(
        f"meshed pool ({cap} pages × 1/{S} bytes each) serves it: "
        f"{len(mlong[0].tokens)} tokens generated"
    )


if __name__ == "__main__":
    main()
