"""End-to-end driver: federated-train a ~25M-parameter dense LM for a few
hundred steps across 3 simulated clouds, comparing the paper's three
aggregation algorithms, with checkpointing and held-out evaluation.

    PYTHONPATH=src python examples/federated_lm.py [--steps 300] [--d-model 320]

This is the "real run" example (Table 3's experiment at CPU scale): expect
next-token accuracy to climb toward the corpus oracle (0.9) as the model
learns the per-domain transition structure."""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import FederatedConfig, ModelConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model
from repro.utils.tree import tree_count_params


def model_config(d_model: int, n_layers: int) -> ModelConfig:
    return ModelConfig(
        name=f"dense-{d_model}x{n_layers}",
        arch_type="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=max(d_model // 64, 2),
        n_kv_heads=max(d_model // 128, 1),
        d_ff=int(d_model * 8 / 3) // 32 * 32,
        vocab_size=512,
        remat=False,
    )


def run(aggregation: str, args, corpus, mixtures) -> dict:
    cfg = model_config(args.d_model, args.layers)
    model = build_model(cfg)
    fed = FederatedConfig(
        n_clouds=args.clouds, local_steps=args.local_steps,
        aggregation=aggregation, compression=args.compression,
        topk_ratio=0.05, cloud_sample_counts=(2000, 3000, 5000),
    )
    tcfg = TrainConfig(steps=args.steps, lr=args.lr, warmup_steps=args.steps // 10)
    trainer = FederatedTrainer(model, fed, tcfg)
    state = trainer.init_state(jax.random.PRNGKey(args.seed))
    if aggregation == "fedavg":
        print(f"params: {tree_count_params(state['global']['params']):,}")
    ckpt = Checkpointer(f"/tmp/fedlm_{aggregation}") if args.checkpoint else None

    step = jax.jit(trainer.train_step)
    t0 = time.time()
    for i in range(args.steps):
        batch = federated_batch(
            corpus, jax.random.fold_in(jax.random.PRNGKey(args.seed + 3), i),
            mixtures, args.batch, args.seq,
        )
        state, metrics = step(state, batch)
        if (i + 1) % 50 == 0:
            print(f"  [{aggregation}] step {i+1:4d} loss {float(metrics['loss']):.4f} "
                  f"acc {float(metrics['accuracy']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
            if ckpt:
                ckpt.save(i + 1, state["global"]["params"])

    # held-out IID eval of the aggregated global model
    eval_batch = corpus.sample(
        jax.random.PRNGKey(777), jnp.ones(corpus.n_domains) / corpus.n_domains,
        64, args.seq,
    )
    loss, m = model.loss(
        state["global"]["params"],
        {"tokens": eval_batch["tokens"], "labels": eval_batch["labels"]},
    )
    return {"eval_loss": float(loss), "eval_acc": float(m["accuracy"])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=320)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--clouds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--compression", default="topk")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", action="store_true")
    ap.add_argument("--aggregators", default="fedavg,dynamic,gradient")
    args = ap.parse_args()

    corpus = SyntheticCorpus(vocab_size=512, n_domains=6, noise=0.1)
    mixtures = dirichlet_mixtures(jax.random.PRNGKey(9), args.clouds, 6, beta=args.beta)

    results = {}
    for aggregation in args.aggregators.split(","):
        print(f"=== {aggregation} ===")
        results[aggregation] = run(aggregation, args, corpus, mixtures)
    print("\nheld-out results (oracle acc 0.902):")
    for k, v in results.items():
        print(f"  {k:10s} loss={v['eval_loss']:.4f} acc={v['eval_acc']:.3f}")


if __name__ == "__main__":
    main()
