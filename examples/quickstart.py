"""Quickstart: 3 clouds federated-train a small LM in ~60 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API end to end: pick an architecture config, build the
model, configure the paper's federated knobs (aggregation formula, local
steps, compression, privacy), and train on a non-IID synthetic corpus."""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model
from repro.utils.tree import tree_count_params


def main():
    # 1. pick an architecture (any of the 10 assigned ids works; smoke = CPU-sized)
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)

    # 2. the paper's federated configuration (§3.1-3.3)
    fed = FederatedConfig(
        n_clouds=3,
        local_steps=4,               # H local steps between cross-cloud syncs
        aggregation="dynamic",       # formula 2: softmax(-loss) weighting
        compression="topk",          # §3.2 gradient/delta sparsification
        topk_ratio=0.05,
        error_feedback=True,
    )
    train = TrainConfig(steps=60, lr=3e-3, warmup_steps=6)
    trainer = FederatedTrainer(model, fed, train)

    state = trainer.init_state(jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  params={tree_count_params(state['global']['params']):,}")
    print(f"sync payload per cloud: "
          f"{trainer.sync_bytes_per_cloud(state['global']['params'])/1e6:.2f} MB "
          f"(raw would be {tree_count_params(state['global']['params'])*2/1e6:.2f} MB)")

    # 3. non-IID data: each cloud samples its own Dirichlet domain mixture
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.1)
    mixtures = dirichlet_mixtures(jax.random.PRNGKey(1), fed.n_clouds, 4, beta=0.2)

    # 4. train
    step = jax.jit(trainer.train_step)
    for i in range(train.steps):
        batch = federated_batch(
            corpus, jax.random.fold_in(jax.random.PRNGKey(2), i),
            mixtures, per_cloud_batch=4, seq=48,
        )
        state, metrics = step(state, batch)
        if (i + 1) % 10 == 0:
            print(f"step {i+1:3d}  loss {float(metrics['loss']):.4f}  "
                  f"acc {float(metrics['accuracy']):.3f}  "
                  f"synced={bool(metrics['synced'])}")

    print(f"done. oracle accuracy for this corpus: {corpus.oracle_accuracy():.3f}")


if __name__ == "__main__":
    main()
