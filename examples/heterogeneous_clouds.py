"""Heterogeneous clouds: dynamic partitioning + asynchronous aggregation.

    PYTHONPATH=src python examples/heterogeneous_clouds.py

Simulates three clouds with 1×/2×/4× accelerator speeds (the paper's §3.1
"Balance Load Across Platforms" + §3.3 async scenario):
 1. the dynamic partitioner learns per-cloud batch shares from observed
    throughput (including a mid-run slowdown on one cloud),
 2. the async aggregator (formula 4) trains against the event schedule and
    is compared with synchronous FedAvg at equal wall-clock (modeled)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.core.partition import Partitioner
from repro.core.scheduler import (
    CloudSpec, events_to_round_masks, simulate_async_schedule, sync_round_time,
)
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model

SPEEDS = [1.0, 2.0, 4.0]
STEPS = 80
H = 4


def partitioning_demo():
    print("=== dynamic partitioning (§3.1) ===")
    p = Partitioner(strategy="dynamic", n_clouds=3)
    state = p.init()
    speeds = np.asarray(SPEEDS)
    for r in range(30):
        if r == 15:
            speeds = np.asarray([1.0, 0.4, 4.0])
            print("  !! cloud-1 degrades to 0.4x at round 15")
        sizes = p.quantize(state, 128)
        state = p.observe(state, sizes, sizes / speeds)
        if r % 10 == 9 or r == 0:
            t = Partitioner.round_time(sizes, speeds)
            u = Partitioner.utilization(sizes, speeds)
            print(f"  round {r+1:2d}: shares={np.round(state.shares,2)} "
                  f"batch={sizes} round_time={t:.1f} util={u:.2f}")
    return state


def async_demo():
    print("\n=== async vs sync aggregation (§3.3 formula 4) ===")
    clouds = [CloudSpec(f"c{i}", s) for i, s in enumerate(SPEEDS)]
    n_rounds = STEPS // H
    events = simulate_async_schedule(clouds, H, n_rounds + 1, sync_bytes=1e8)
    arrived, alphas = events_to_round_masks(events, 3, n_rounds + 1)
    t_sync = n_rounds * sync_round_time(clouds, H, 1.0, 1e8)
    t_async = events[n_rounds - 1].time
    print(f"  modeled wall-clock for {n_rounds} rounds: "
          f"sync={t_sync:.0f}s async={t_async:.0f}s "
          f"(speedup {t_sync/t_async:.2f}x)")
    print(f"  mean staleness: {np.mean([e.staleness for e in events]):.2f}")

    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.1)
    mix = dirichlet_mixtures(jax.random.PRNGKey(4), 3, 4, beta=0.3)
    for aggregation in ("fedavg", "async"):
        fed = FederatedConfig(n_clouds=3, local_steps=H, aggregation=aggregation)
        trainer = FederatedTrainer(model, fed, TrainConfig(steps=STEPS, lr=3e-3, warmup_steps=8))
        state = trainer.init_state(jax.random.PRNGKey(0))
        step = jax.jit(trainer.train_step)
        losses = []
        for i in range(STEPS):
            batch = federated_batch(
                corpus, jax.random.fold_in(jax.random.PRNGKey(6), i), mix, 4, 32
            )
            rnd = i // H
            state, m = step(state, batch, jnp.asarray(arrived[rnd]), jnp.asarray(alphas[rnd]))
            losses.append(float(m["loss"]))
        print(f"  {aggregation:7s}: final loss {np.mean(losses[-8:]):.4f}")


if __name__ == "__main__":
    partitioning_demo()
    async_demo()
