"""Paper Table 3: convergence accuracy (%) and final loss for FedAvg /
Dynamic Weighted / Gradient Aggregation under non-IID cross-cloud data.

Real training runs (smoke-scale model, synthetic non-IID corpus with
Dirichlet β=0.05 — strongly skewed, the regime the paper targets). Metrics:
final next-token accuracy (% of the corpus oracle) and final loss, mirroring
the paper's two columns. The paper's qualitative claims to validate:
dynamic > fedavg, gradient ≥ dynamic on heterogeneous data."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_results
from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model

STEPS = 150
SEQ = 48
PCB = 8          # per-cloud batch
BETA = 0.05      # strong non-IID skew
N_CLOUDS = 3
H = 4


def train_one(aggregation: str, seed: int = 0) -> dict:
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=6, noise=0.1)
    mix = dirichlet_mixtures(jax.random.PRNGKey(99), N_CLOUDS, 6, beta=BETA)
    fed = FederatedConfig(
        n_clouds=N_CLOUDS, local_steps=H, aggregation=aggregation,
        # give clouds uneven sample counts (formula 1 weighting is active)
        cloud_sample_counts=(2000, 4000, 6000),
    )
    tcfg = TrainConfig(steps=STEPS, lr=3e-3, warmup_steps=10)
    trainer = FederatedTrainer(model, fed, tcfg)
    state = trainer.init_state(jax.random.PRNGKey(seed))
    step = jax.jit(trainer.train_step)
    t0 = time.time()
    losses, accs = [], []
    for i in range(STEPS):
        batch = federated_batch(
            corpus, jax.random.fold_in(jax.random.PRNGKey(seed + 5), i), mix, PCB, SEQ
        )
        rnd = i // H
        arrived = jnp.asarray([rnd % N_CLOUDS == j for j in range(N_CLOUDS)])
        state, m = step(state, batch, arrived, jnp.full((N_CLOUDS,), 0.5))
        losses.append(float(m["loss"]))
        accs.append(float(m["accuracy"]))
    wall = time.time() - t0

    # held-out IID evaluation of the GLOBAL model (the paper's accuracy col)
    eval_mix = jnp.ones(6) / 6
    eval_batch = corpus.sample(jax.random.PRNGKey(1234), eval_mix, 32, SEQ)
    loss, metrics = model.loss(
        state["global"]["params"],
        {"tokens": eval_batch["tokens"], "labels": eval_batch["labels"]},
    )
    return {
        "final_train_loss": float(np.mean(losses[-10:])),
        "eval_loss": float(loss),
        "eval_accuracy_pct": float(metrics["accuracy"]) * 100,
        "oracle_accuracy_pct": corpus.oracle_accuracy() * 100,
        "wall_seconds": wall,
        "us_per_step": wall / STEPS * 1e6,
        "loss_curve": losses[::10],
    }


def run() -> dict:
    rows = {}
    for aggregation in ("fedavg", "dynamic", "gradient"):
        r = train_one(aggregation)
        rows[aggregation] = r
        emit(
            f"table3/{aggregation}",
            r["us_per_step"],
            f"acc={r['eval_accuracy_pct']:.1f}%;loss={r['eval_loss']:.3f}",
        )
    save_results("table3_convergence", rows)
    return rows


if __name__ == "__main__":
    run()
