"""Paper Table 1 row "Communication Protocols: gRPC vs QUIC" (+ TCP baseline
and the multiplexing knob).

Applies the analytic WAN cost model (core/protocols.py) to the framework's
real sync payloads — uncompressed and compressed deltas of the full-size
stablelm-1.6b parameter set — across link profiles (clean LAN-like,
continental WAN, lossy intercontinental)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, save_results
from repro.configs import get_config
from repro.core.compression import Compressor
from repro.core.protocols import GRPC, QUIC, TCP, Link, sync_wall_time
from repro.models import build_model

LINKS = {
    "clean_10g": Link(latency_s=0.005, bandwidth=1.25e9, loss_rate=1e-6),
    "wan_cross_region": Link(latency_s=0.03, bandwidth=1.25e9, loss_rate=1e-4),
    "lossy_intercontinental": Link(latency_s=0.08, bandwidth=6.25e8, loss_rate=1e-3),
}


def run() -> dict:
    cfg = get_config("stablelm-1.6b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    payloads = {
        "raw": Compressor("none").bytes_per_sync(params),
        "topk1%": Compressor("topk", topk_ratio=0.01).bytes_per_sync(params),
        "int8": Compressor("int8").bytes_per_sync(params),
    }
    rows = {}
    for link_name, link in LINKS.items():
        for pay_name, nbytes in payloads.items():
            for proto in (TCP, GRPC, QUIC):
                t = sync_wall_time(nbytes, 3, proto, link)
                key = f"{link_name}/{pay_name}/{proto.name}"
                rows[key] = {"bytes": nbytes, "seconds": t}
                emit(f"protocols/{key}", t * 1e6, f"sync_s={t:.3f}")
    # multiplexing sweep on the paper's headline case
    link = LINKS["lossy_intercontinental"]
    for n in (1, 2, 4, 8, 16):
        t_grpc = GRPC.transfer_time(payloads["raw"], link, n_streams=n)
        t_quic = QUIC.transfer_time(payloads["raw"], link, n_streams=n)
        rows[f"multiplex/{n}"] = {"grpc": t_grpc, "quic": t_quic}
        emit(f"protocols/multiplex_{n}", t_quic * 1e6,
             f"grpc={t_grpc:.2f}s;quic={t_quic:.2f}s")
    save_results("protocols", rows)
    return rows


if __name__ == "__main__":
    run()
