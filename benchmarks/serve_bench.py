"""Continuous-batching serve benchmark: Poisson arrivals → tokens/sec and
p50/p95 request latency; burst arrivals → prefill-dispatch count and TTFT.

Drives ``launch/engine.py`` with a Poisson request trace (exponential
inter-arrival times, mixed prompt lengths) in realtime mode, and contrasts
it with the sequential oracle (``serve_batch``) running the same workload
as back-to-back fixed batches. The headline numbers:

* ``tokens_per_second`` — generated tokens / wall time over the trace
* ``latency_p50`` / ``latency_p95`` — per-request arrival→finish seconds
  (includes queueing: the p95 is where continuous batching pays off, a
  late-arriving request backfills a freed slot instead of waiting for the
  whole previous batch)
* ``ttft_p50`` — arrival→first-token seconds

``--burst N`` switches to a burst-arrival trace (N simultaneous arrivals
per burst) and runs the engine five ways — PAGED KV cache (shared page
pool + per-slot page tables, the serve-CLI default), paged with a TIGHT
(oversubscribed) pool that forces watermark admission + youngest-slot
preemption, ring-cache shape-bucketed batched prefill, unbucketed batched,
and one-dispatch-per-request — asserting all five emit identical greedy
tokens and reporting ``prefill_dispatches``, ``prefill_compiles``,
latency/TTFT percentiles, and (paged variants) pool occupancy +
preemption counts. Burst mode also probes the paged decode kernel in
isolation: mean decode-step time at low vs. full ring occupancy, paged
vs. unpaged vs. page-table mode (page skipping only helps rows far from
wrap, so the low-occupancy row is where the win shows), and runs the
SHARED-PREFIX probe: N requests over one common system prompt through the
paged engine with and without the prefix cache, asserting identical
greedy tokens, ≥ 50% fewer prefilled tokens, a nonzero prefix hit rate,
and an exercised copy-on-write split (``bench_shared_prefix``).

Burst mode also runs the SHARDED probe: the same burst trace through a
tensor-parallel engine on a ``model``-axis CPU mesh
(``ServeEngine(mesh=...)``) vs. the single-device paged engine, asserting
BITWISE-identical greedy tokens and reporting sharded tokens/sec,
per-shard occupancy, and compile counts. A one-device process (the plain
local run) re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so the trajectory
still carries real multi-shard numbers; under the CI sharded job (4
forced host devices) the probe runs in-process on 4 shards.
``--sharded-probe`` runs just this probe and prints its JSON — the CI
sharded job's entry point.

Burst mode also runs the ROUTER probe (``bench_router``): the burst trace
through a 2-replica fault-tolerant ``ServeRouter`` with replica 0 KILLED
mid-decode, asserting zero dropped requests and greedy+sampled token
identity against a fault-free single engine, and reporting the failover
round-trip (migrations, migrated requests, per-replica occupancy, sheds,
retries). ``--router-probe`` runs just this probe — the CI chaos smoke
job's entry point.

Burst mode also runs the KV-QUANT and TIERED-KV probes. ``bench_kv_int8``
sizes an int8 page pool (int8 payload + per-page-slot per-kv-head fp32
scales) to a float32 pool's exact device-byte budget
(``dataclasses.replace(cfg, dtype="float32")``) and asserts ≥ 2×
concurrent resident sequences, actually serving that many simultaneous
requests without a single preemption, and records the int8 engine's
greedy-token agreement against the float32 one. ``bench_tiered`` replays
an oversubscribed long-prompt trace with the host KV tier ON (preempted
pages swap to host, resume = device scatter) vs. OFF (resume = full
re-prefill), asserting bitwise-identical greedy tokens, real host-tier
swap-ins, and strictly fewer prefilled tokens with swap, and records both
walls — the swap-vs-recompute resume contrast in the trajectory.
``--tiered-probe`` runs just these two probes — the CI tiered smoke job's
entry point.

Burst mode also runs the SPEC-DECODE probe (``bench_spec``): the burst
trace target-only vs. draft-model speculative decoding (k-token lookahead
verified in one batched suffix-prefill dispatch per round). The
same-params draft row is the deterministic upper bound CI pins — greedy
tokens bitwise identical and ≥ 1.5× fewer target dispatches are both
asserted — and a foreign-seed draft row records realistic acceptance.
``--spec-probe`` runs just this probe — the CI spec smoke job's entry
point.

``--smoke`` is the CI-sized burst run. Besides the usual
``benchmarks/results.json`` entry it APPENDS a timestamped entry to
``BENCH_serve.json`` at the repo root — the perf trajectory future PRs
diff against (schema 2: ``{"schema": 2, "entries": [...]}``; a schema-1
file is migrated by wrapping its single snapshot as the first entry).

    PYTHONPATH=src python -m benchmarks.serve_bench --requests 12 --rate 2.0
    PYTHONPATH=src python -m benchmarks.serve_bench --burst 4 --requests 12
"""
from __future__ import annotations

import argparse
import dataclasses
import datetime
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit, save_results
from repro.configs import get_smoke_config
from repro.data import SyntheticCorpus
from repro.launch.engine import Request, ServeEngine
from repro.launch.serve import serve_batch
from repro.models import build_model

BENCH_SEED_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json",
)


def poisson_trace(
    cfg, *, n_requests: int, rate: float, prompt_lens: tuple[int, ...],
    gen_tokens: int, seed: int,
) -> list[Request]:
    """Poisson arrivals (rate req/s), prompt length sampled per request."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.0)
    reqs = []
    for r in range(n_requests):
        plen = int(rng.choice(prompt_lens))
        prompt = np.asarray(
            corpus.sample(
                jax.random.PRNGKey(seed + 100 + r), np.ones(4) / 4, 1, plen
            )["tokens"][0],
            np.int32,
        )
        reqs.append(
            Request(
                uid=r, prompt=prompt, max_new_tokens=gen_tokens,
                arrival_time=float(arrivals[r]),
            )
        )
    return reqs


def bench_engine(args) -> dict:
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = max(args.prompt_lens) + args.gen
    engine = ServeEngine(
        model, params, num_slots=args.slots, max_seq=max_seq,
        window=args.window, use_kernel=args.use_kernel, prefill=args.prefill,
        paged_cache=args.paged_cache, page_size=args.page_size,
        num_pages=args.num_pages,
    )
    reqs = poisson_trace(
        cfg, n_requests=args.requests, rate=args.rate,
        prompt_lens=tuple(args.prompt_lens), gen_tokens=args.gen,
        seed=args.seed,
    )
    # warm the jit caches outside the timed region so the trace measures
    # steady state, not compilation
    engine.warm(args.prompt_lens)

    t0 = time.time()
    outs = engine.run(reqs, realtime=True)
    wall = time.time() - t0
    total = sum(len(o.tokens) for o in outs)
    lat = np.asarray([o.latency for o in outs])
    ttft = np.asarray([o.ttft for o in outs])
    return {
        "mode": "continuous",
        "slots": args.slots,
        "requests": args.requests,
        "rate_req_per_s": args.rate,
        "prompt_lens": list(args.prompt_lens),
        "gen_tokens": args.gen,
        "window": args.window,
        "prefill": args.prefill,
        "use_kernel": args.use_kernel,
        "engine_steps": engine.steps,
        "wall_seconds": wall,
        "tokens_per_second": total / max(wall, 1e-9),
        "latency_p50": float(np.percentile(lat, 50)),
        "latency_p95": float(np.percentile(lat, 95)),
        "ttft_p50": float(np.percentile(ttft, 50)),
        "pool": engine.pool_stats,
    }


def burst_trace(
    cfg, *, n_requests: int, burst_size: int, gap: float,
    prompt_lens: tuple[int, ...], gen_tokens: int, seed: int,
) -> list[Request]:
    """Bursts of ``burst_size`` simultaneous arrivals, ``gap`` seconds apart
    — the arrival pattern iteration-level batched admission exists for."""
    rng = np.random.default_rng(seed)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.0)
    reqs = []
    for r in range(n_requests):
        plen = int(rng.choice(prompt_lens))
        prompt = np.asarray(
            corpus.sample(
                jax.random.PRNGKey(seed + 100 + r), np.ones(4) / 4, 1, plen
            )["tokens"][0],
            np.int32,
        )
        reqs.append(
            Request(
                uid=r, prompt=prompt, max_new_tokens=gen_tokens,
                arrival_time=(r // burst_size) * gap,
            )
        )
    return reqs


def bench_decode_occupancy(
    *, slots: int = 4, cap: int = 4096, iters: int = 5, shallow_pos: int = 16,
) -> dict:
    """Isolated decode-attention step time vs. ring occupancy, paged vs.
    unpaged kernel (interpret mode — relative, not absolute, numbers;
    shared probe in ``benchmarks.kernels_bench.decode_occupancy_sweep``).

    ``cap`` must be large enough to split into several pages (auto page is
    512), or there is nothing to skip: 4096 → 8 pages. ``low`` occupancy
    parks every slot at ``shallow_pos`` (one live page of the ring);
    ``full`` parks every slot past wrap (every page live). The paged
    kernel must win at LOW occupancy — that pair is the acceptance
    comparison. At full occupancy both kernels visit every page; any gap
    in the full rows is interpret-mode dispatch overhead, kept in the seed
    only as a noise floor for diffing the low rows against."""
    from benchmarks.kernels_bench import decode_occupancy_sweep

    sweep = decode_occupancy_sweep(
        {
            "low": [shallow_pos] * slots,
            "full": [cap + shallow_pos] * slots,
        },
        slots=slots, cap=cap, iters=iters,
    )
    return {"cap": cap, "slots": slots, "shallow_pos": shallow_pos, **sweep}


BURST_VARIANTS = (
    # label, batch_prefill, bucket_prefill, paged_cache, tight_pool
    ("paged", True, True, True, False),            # serve-CLI default
    ("paged_tight", True, True, True, True),       # oversubscribed pool:
    #                                                watermark + preemption
    ("batched", True, True, False, False),         # ring-cache contrast
    ("batched_unbucketed", True, False, False, False),
    ("per_request", False, False, False, False),   # one dispatch per request
)

TIGHT_POOL_FRACTION = 0.5  # tight pool ≈ half of ring-equivalent capacity


def shared_prefix_trace(
    cfg, *, n_requests: int, prefix_len: int, page_size: int,
    gen_tokens: int, seed: int,
) -> list[Request]:
    """N requests over one common system prompt: ``prefix_len`` shared
    tokens + a short unique user suffix each. Requests 0 and n-1 carry the
    IDENTICAL page-aligned prompt (different admission rounds), so a warm
    index serves the last one entirely from cache — the copy-on-write
    split path."""
    rng = np.random.default_rng(seed)
    vocab = cfg.vocab_size
    system = rng.integers(1, vocab, prefix_len).astype(np.int32)
    # page-aligned full duplicate: forces a 100% hit + CoW on its re-run
    dup_suffix = rng.integers(
        1, vocab, page_size - (prefix_len % page_size) or page_size
    ).astype(np.int32)
    reqs = []
    for r in range(n_requests):
        if r == 0 or r == n_requests - 1:
            suffix = dup_suffix
        else:
            suffix = rng.integers(1, vocab, 3 + (r % 5)).astype(np.int32)
        reqs.append(
            Request(
                uid=r,
                prompt=np.concatenate([system, suffix]),
                max_new_tokens=gen_tokens,
            )
        )
    return reqs


def bench_shared_prefix(args) -> dict:
    """The prefix-sharing probe: the same common-system-prompt burst
    through the paged engine WITH and WITHOUT the prefix cache.

    Asserted here (CI runs this under --smoke): identical greedy tokens,
    ≥ 50% fewer prefilled tokens with sharing, a nonzero prefix hit rate,
    at least one copy-on-write page split exercised (the fully cached
    duplicate prompt), and — since hit/cold round splitting — that warm
    rounds actually take the SUFFIX dispatch path (``suffix_dispatches``
    > 0 with sharing, 0 without) while the cold publish round stays on
    the cold trace. ``prefill_tokens_saved_frac`` is the headline —
    prefill FLOPs scale linearly in prefilled tokens at fixed width.
    ``steady_round_seconds`` times a SECOND identical warm burst (same
    prompts, fresh uids) after the first burst has paid the jit compiles:
    the on/off contrast is the suffix-round latency saving (suffix rounds
    attend over starts-bounded prefix pages + short suffixes instead of
    re-prefilling the full prompt)."""
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prefix_len = 3 * args.page_size
    max_seq = prefix_len + args.page_size + 8 + args.gen
    out = {}
    for label, prefix in (("prefix_on", True), ("prefix_off", False)):
        engine = ServeEngine(
            model, params, num_slots=args.slots, max_seq=max_seq,
            prefill="chunked", paged_cache=True, page_size=args.page_size,
            prefix_cache=prefix,
        )
        reqs = shared_prefix_trace(
            cfg, n_requests=args.requests, prefix_len=prefix_len,
            page_size=args.page_size, gen_tokens=args.gen, seed=args.seed,
        )
        # second identical warm burst (same prompts, fresh uids): by the
        # time it runs, burst #1 has paid every jit compile, so its wall
        # time is the steady-state warm-round latency
        burst2 = [
            Request(
                uid=1000 + r.uid, prompt=r.prompt,
                max_new_tokens=r.max_new_tokens,
            )
            for r in reqs[1:]
        ]
        t0 = time.time()
        # the first request runs alone (publishing the system prompt on
        # retirement), then the burst — otherwise the whole first
        # admission round is cold and the probe undercounts what a warm
        # system-prompt cache saves
        outs = engine.run(reqs[:1])
        outs += engine.run(reqs[1:])
        t_steady = time.time()
        outs += engine.run(burst2)
        t_end = time.time()
        out[label] = {
            "wall_seconds": t_end - t0,
            "steady_round_seconds": t_end - t_steady,
            "prefill_tokens": engine.prefill_tokens,
            "prefill_dispatches": engine.prefill_dispatches,
            "engine_steps": engine.steps,
            "pool": engine.pool_stats,
            "generated": [o.tokens for o in outs],
        }
    on, off = out["prefix_on"], out["prefix_off"]
    assert on["generated"] == off["generated"], (
        "prefix sharing changed greedy output"
    )
    saved = 1.0 - on["prefill_tokens"] / max(off["prefill_tokens"], 1)
    assert saved >= 0.5, (
        f"shared-prefix trace saved only {saved:.0%} prefilled tokens "
        f"({on['prefill_tokens']} vs {off['prefill_tokens']})"
    )
    assert on["pool"]["prefix_hit_rate"] > 0, "no prefix hits on a shared trace"
    assert on["pool"]["cow_copies"] > 0, (
        "fully cached duplicate prompt never exercised copy-on-write"
    )
    # hit/cold round splitting: warm rounds must dispatch the suffix
    # trace, the cold publish round the cold trace — and without the
    # prefix cache every round is cold
    assert on["pool"]["suffix_dispatches"] > 0, (
        "warm shared-prefix rounds never took the suffix dispatch path"
    )
    assert on["pool"]["cold_dispatches"] > 0, (
        "the cold publish round did not take the cold dispatch path"
    )
    assert off["pool"]["suffix_dispatches"] == 0, (
        "suffix dispatch fired with the prefix cache disabled"
    )
    for m in out.values():
        del m["generated"]
    return {
        "prefix_len": prefix_len,
        "prefill_tokens_saved_frac": saved,
        **out,
    }


def _pool_kv_bytes(cache) -> int:
    """Device bytes held by the shared KV pool — payload planes plus, when
    quantized, the fp32 scale planes. This is the HBM budget the residency
    probe equates across dtypes."""
    return sum(
        int(cache[n].size) * cache[n].dtype.itemsize
        for n in ("k", "v", "ks", "vs")
        if n in cache
    )


def bench_kv_int8(args) -> dict:
    """int8 KV residency probe: at an EQUAL pool byte budget, how many
    sequences stay resident with int8 pages vs. a float32 pool
    (``dataclasses.replace(cfg, dtype="float32")`` — same float32 weights
    drive both engines, only the pool dtype differs)?

    An int8 page costs ``head_dim + 4`` bytes per kv-head per token slot
    (1-byte payload + one fp32 scale each) against the float32 pool's
    ``4·head_dim`` — ×3.6 at head_dim 32 — so the probe sizes the int8
    pool to the float32 pool's measured byte budget, asserts ≥ 2× resident
    sequences, then actually serves that many SIMULTANEOUS requests
    through the int8 engine and asserts zero preemptions (the claim is
    residency, not arithmetic). Quantization quality is pinned in
    tests/test_kv_int8.py; here the greedy-token agreement against the
    float32 engine is just recorded (and sanity-bounded)."""
    cfg = dataclasses.replace(get_smoke_config(args.arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    plen = max(args.prompt_lens)
    max_seq = plen + args.gen
    pages_per_seq = -(-max_seq // args.page_size)
    base_slots = 2
    fp_pages = base_slots * pages_per_seq + 1  # + the reserved scratch page
    eng_fp = ServeEngine(
        model, params, num_slots=base_slots, max_seq=max_seq,
        prefill="chunked", paged_cache=True, page_size=args.page_size,
        num_pages=fp_pages,
    )
    fp_bytes = _pool_kv_bytes(eng_fp.cache)
    # int8 page bytes from the float32 pool's geometry: payload 4 → 1
    # byte/element plus one fp32 scale per (token slot, kv head) per page
    layers, _, page, hkv, hd = eng_fp.cache["k"].shape
    per_page_int8 = 2 * layers * page * hkv * (hd + 4)
    int8_pages = int(fp_bytes // per_page_int8)
    resident_fp = (fp_pages - 1) // pages_per_seq
    resident_int8 = (int8_pages - 1) // pages_per_seq
    assert resident_int8 >= 2 * resident_fp, (
        f"int8 pool at the float32 byte budget holds only {resident_int8} "
        f"resident sequences vs {resident_fp} float32 (< 2x)"
    )
    eng8 = ServeEngine(
        model, params, num_slots=resident_int8, max_seq=max_seq,
        prefill="chunked", paged_cache=True, page_size=args.page_size,
        num_pages=int8_pages, kv_dtype="int8",
    )
    int8_bytes = _pool_kv_bytes(eng8.cache)
    assert int8_bytes <= fp_bytes, (
        f"int8 pool ({int8_bytes}B) exceeds the float32 budget ({fp_bytes}B)"
    )
    rng = np.random.default_rng(args.seed)
    prompts = [
        rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        for _ in range(resident_int8)
    ]

    def trace():
        return [
            Request(uid=r, prompt=p, max_new_tokens=args.gen)
            for r, p in enumerate(prompts)
        ]

    t0 = time.time()
    outs8 = eng8.run(trace())
    wall8 = time.time() - t0
    pool8 = eng8.pool_stats
    assert pool8["preemptions"] == 0, (
        f"{resident_int8} sequences did not fit resident in the int8 pool "
        f"({pool8['preemptions']} preemptions)"
    )
    outs_fp = eng_fp.run(trace())  # 2 slots: same trace, serialized
    tok8 = [o.tokens for o in outs8]
    tokfp = [o.tokens for o in outs_fp]
    agreement = sum(a == b for a, b in zip(tok8, tokfp)) / len(tok8)
    assert agreement >= 0.5, (
        f"int8 engine agreed with float32 on only {agreement:.0%} of "
        "requests — quantization is off the rails, see tests/test_kv_int8.py"
    )
    return {
        "pool_bytes_fp32": fp_bytes,
        "pool_bytes_int8": int8_bytes,
        "pages_fp32": fp_pages,
        "pages_int8": int8_pages,
        "pages_per_seq": pages_per_seq,
        "resident_seqs_fp32": resident_fp,
        "resident_seqs_int8": resident_int8,
        "residency_ratio": resident_int8 / max(resident_fp, 1),
        "token_agreement": agreement,
        "wall_seconds_int8": wall8,
        "occupancy_max_int8": pool8["occupancy_max"],
    }


def bench_tiered(args) -> dict:
    """Tiered-KV resume probe: the SAME oversubscribed trace with the host
    tier ON (preempted pages swap out to host, resume = one device
    scatter + table rewrite) vs. OFF (resume = re-prefill the victim's
    whole token stream). Long prompts + short gens make the run
    prefill-dominated, so the recompute engine's extra resume prefills
    land directly in its wall time.

    Asserted: bitwise-identical greedy tokens across both engines, both
    engines actually preempt, the swap engine resumes from the host tier
    (``swapped_in_pages > 0`` — the CI smoke gate for the tiered path),
    and it prefills STRICTLY fewer tokens than the recompute engine (the
    deterministic form of "swap resume does no prefill work"). Walls for
    both engines go to the trajectory as the resume-cost contrast."""
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    plen = 24 * args.page_size  # long prompts: resume cost ≈ prefill cost
    gen = 4
    max_seq = plen + gen
    pages_per_seq = -(-max_seq // args.page_size)
    # both prompts fit, both COMPLETIONS don't: the collision lands
    # mid-decode, which is where a swap resume is a pure page scatter
    num_pages = 2 * pages_per_seq
    n_reqs = 4
    rng = np.random.default_rng(args.seed + 1)
    prompts = [
        rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        for _ in range(n_reqs)
    ]
    out = {}
    for label, host_pages in (("recompute", 0), ("swap", n_reqs * pages_per_seq)):
        engine = ServeEngine(
            model, params, num_slots=2, max_seq=max_seq, prefill="chunked",
            paged_cache=True, page_size=args.page_size, num_pages=num_pages,
            prefix_cache=False, host_pages=host_pages,
        )
        reqs = [
            Request(uid=r, prompt=prompts[r], max_new_tokens=gen)
            for r in range(n_reqs)
        ]
        engine.warm([plen])
        t0 = time.time()
        outs = engine.run(reqs)
        wall = time.time() - t0
        out[label] = {
            "wall_seconds": wall,
            "prefill_tokens": engine.prefill_tokens,
            "prefill_dispatches": engine.prefill_dispatches,
            "engine_steps": engine.steps,
            "pool": engine.pool_stats,
            "generated": [o.tokens for o in outs],
        }
    sw, rc = out["swap"], out["recompute"]
    assert sw["generated"] == rc["generated"], (
        "host-tier swap changed greedy output"
    )
    assert rc["pool"]["preemptions"] > 0 and sw["pool"]["preemptions"] > 0, (
        f"tight pool never preempted (recompute "
        f"{rc['pool']['preemptions']}, swap {sw['pool']['preemptions']}) — "
        "the probe is not exercising resume at all"
    )
    assert sw["pool"]["swapped_in_pages"] > 0, (
        "swap engine preempted but never resumed from the host tier"
    )
    assert rc["pool"]["swapped_in_pages"] == 0, (
        "recompute engine (host tier off) reported host swap-ins"
    )
    assert sw["prefill_tokens"] < rc["prefill_tokens"], (
        f"swap resume should prefill fewer tokens than recompute "
        f"({sw['prefill_tokens']} vs {rc['prefill_tokens']})"
    )
    # prefill-dominated by construction, so the extra resume prefills are
    # the wall-time story (locally ~2x; the margin absorbs CI jitter)
    assert sw["wall_seconds"] < rc["wall_seconds"], (
        f"swap resume was not faster than recompute "
        f"({sw['wall_seconds']:.3f}s vs {rc['wall_seconds']:.3f}s)"
    )
    for m in out.values():
        del m["generated"]
    return {
        "prompt_len": plen,
        "gen_tokens": gen,
        "num_pages": num_pages,
        "requests": n_reqs,
        **out,
    }


def _sharded_probe(args, shards: int) -> dict:
    """The same burst trace through the paged engine unsharded and
    tensor-parallel over ``shards`` devices (``model``-axis mesh,
    per-shard kv-head page pool). Sharding must be invisible in the
    output — the probe ASSERTS bitwise-identical greedy tokens — so the
    contrast rows measure pure engine overhead/speedup, never quality."""
    from repro.launch.mesh import make_serve_mesh

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = max(args.prompt_lens) + args.gen

    def trace():
        return burst_trace(
            cfg, n_requests=args.requests, burst_size=max(args.burst, 1),
            gap=0.0, prompt_lens=tuple(args.prompt_lens),
            gen_tokens=args.gen, seed=args.seed,
        )

    out = {}
    for label, mesh in (
        ("unsharded", None), ("sharded", make_serve_mesh(shards)),
    ):
        engine = ServeEngine(
            model, params, num_slots=args.slots, max_seq=max_seq,
            prefill="chunked", paged_cache=True, page_size=args.page_size,
            mesh=mesh,
        )
        engine.warm(args.prompt_lens)
        t0 = time.time()
        outs = engine.run(trace())
        wall = time.time() - t0
        total = sum(len(o.tokens) for o in outs)
        ps = engine.pool_stats
        out[label] = {
            "wall_seconds": wall,
            "tokens_per_second": total / max(wall, 1e-9),
            "engine_steps": engine.steps,
            "prefill_compiles": engine.prefill_compiles,
            "compiles": engine.compiles,
            "shards": ps["shards"],
            "mesh_axes": ps["mesh_axes"],
            "occupancy": ps["occupancy"],
            "occupancy_max": ps["occupancy_max"],
            "preemptions": ps["preemptions"],
            "generated": [o.tokens for o in outs],
        }
    assert out["sharded"]["generated"] == out["unsharded"]["generated"], (
        "tensor-parallel serving changed greedy output"
    )
    for m in out.values():
        del m["generated"]
    return {"shards": shards, **out}


def bench_router(args) -> dict:
    """The fault-tolerance probe: the burst trace through a 2-replica
    ``ServeRouter`` with replica 0 KILLED mid-decode, vs. a fault-free
    single engine — greedy AND sampled.

    Asserted here (CI runs this under --router-probe): zero dropped
    requests (every submitted uid completes), token streams BITWISE
    identical to the fault-free run in both decode modes, and the kill
    actually landed mid-flight (``migrated_requests`` > 0 — a kill that
    migrates nothing proves nothing). The reported numbers are the
    failover round-trip the trajectory tracks: migrations, migrated
    requests, per-replica occupancy, sheds, retries, and merged
    throughput under the fault."""
    from repro.launch.router import FaultPlan, ServeRouter
    from repro.launch.sampling import SamplingParams
    import dataclasses

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = max(args.prompt_lens) + args.gen
    engine_kw = dict(
        num_slots=args.slots, max_seq=max_seq, prefill="chunked",
        paged_cache=True, page_size=args.page_size, prefix_cache=True,
        seed=args.seed,
    )

    def trace(sampling):
        reqs = burst_trace(
            cfg, n_requests=args.requests, burst_size=max(args.burst, 1),
            gap=0.0, prompt_lens=tuple(args.prompt_lens),
            gen_tokens=args.gen, seed=args.seed,
        )
        if sampling is not None:
            for r in reqs:
                r.sampling = dataclasses.replace(
                    sampling, seed=sampling.seed + r.uid
                )
        return reqs

    out = {}
    for label, sampling in (
        ("greedy", None),
        ("sampled", SamplingParams(
            temperature=0.8, top_p=0.95, seed=args.seed + 17,
        )),
    ):
        baseline = ServeEngine(model, params, **engine_kw)
        baseline.warm(args.prompt_lens, sampling=sampling)
        base = {o.uid: o.tokens for o in baseline.run(trace(sampling))}

        router = ServeRouter(
            model, params, replicas=2,
            fault_plan=FaultPlan(kill={0: args.kill_step}), **engine_kw,
        )
        router.warm(args.prompt_lens, sampling=sampling)
        t0 = time.time()
        outs = router.run(trace(sampling))
        wall = time.time() - t0
        got = {o.uid: o.tokens for o in outs}
        rs = router.router_stats

        assert len(outs) == args.requests and not router.shed_errors, (
            f"[{label}] dropped requests under failover: "
            f"{len(outs)}/{args.requests} completed, "
            f"shed {[(e.uid, e.reason) for e in router.shed_errors]}"
        )
        assert got == base, (
            f"[{label}] failover changed output tokens"
        )
        assert rs["migrated_requests"] > 0, (
            f"[{label}] kill at step {args.kill_step} migrated nothing — "
            "the fault missed the in-flight window"
        )
        total = sum(len(t) for t in got.values())
        out[label] = {
            "wall_seconds": wall,
            "tokens_per_second": total / max(wall, 1e-9),
            "completed": len(outs),
            "migrations": rs["migrations"],
            "migrated_requests": rs["migrated_requests"],
            "shed_requests": rs["shed_requests"],
            "retries": rs["retries"],
            "occupancy": rs["occupancy"],
            "replica_requests": rs["replica_requests"],
            "replica_steps": rs["replica_steps"],
            "healthy": rs["healthy"],
            "affinity_routed": rs["affinity_routed"],
            "balance_routed": rs["balance_routed"],
        }
    return {
        "replicas": 2,
        "kill_step": args.kill_step,
        "token_identical": True,  # asserted above, recorded for the seed
        **out,
    }


def bench_spec(args) -> dict:
    """Speculative-decoding probe: the burst trace through the paged
    engine target-only vs. with a draft proposing ``--spec-tokens``
    lookahead tokens per slot per round, verified in one batched
    suffix-prefill dispatch.

    The CI-pinned upper bound uses a SAME-ARCH draft initialized from the
    SAME seed — identical parameters, so the target agrees with every
    proposal and acceptance sits at ~100%. That makes the probe
    deterministic: greedy tokens must be BITWISE identical to the
    target-only engine (asserted), and the engine must take ≥ 1.5× fewer
    target dispatches overall (asserted; at full acceptance a k-token
    round replaces k+1 decode steps, so the per-token dispatch rate
    approaches 1/(k+1) against the non-spec engine's 1.0). A second
    FOREIGN-seed draft row records the realistic-acceptance contrast —
    reported, not asserted, since a randomly initialized smoke draft's
    agreement is an accident of the seed."""
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = max(args.prompt_lens) + args.gen

    def trace():
        return burst_trace(
            cfg, n_requests=args.requests, burst_size=max(args.burst, 1),
            gap=0.0, prompt_lens=tuple(args.prompt_lens),
            gen_tokens=args.gen, seed=args.seed,
        )

    out = {}
    for label, draft_seed in (
        ("target_only", None), ("spec", args.seed), ("spec_foreign", None),
    ):
        kw = {}
        if label != "target_only":
            dseed = args.seed if draft_seed is not None else args.seed + 7
            dmodel = build_model(cfg)
            kw = dict(
                draft_model=dmodel,
                draft_params=dmodel.init(jax.random.PRNGKey(dseed)),
                spec_tokens=args.spec_tokens,
            )
        engine = ServeEngine(
            model, params, num_slots=args.slots, max_seq=max_seq,
            prefill="chunked", paged_cache=True, page_size=args.page_size,
            **kw,
        )
        t0 = time.time()
        outs = engine.run(trace())
        wall = time.time() - t0
        total = sum(len(o.tokens) for o in outs)
        ps = engine.pool_stats
        out[label] = {
            "wall_seconds": wall,
            "tokens_per_second": total / max(wall, 1e-9),
            "engine_steps": engine.steps,
            "spec_rounds": ps["spec_rounds"],
            "spec_accept_rate": ps["spec_accept_rate"],
            "spec_dispatches_per_token": ps["spec_dispatches_per_token"],
            "pool_occupancy_max": ps["occupancy_max"],
            "generated": [o.tokens for o in outs],
        }
    base, spec = out["target_only"], out["spec"]
    assert spec["generated"] == base["generated"], (
        "speculative decoding changed greedy output (same-params draft)"
    )
    assert out["spec_foreign"]["generated"] == base["generated"], (
        "speculative decoding changed greedy output (foreign draft)"
    )
    reduction = base["engine_steps"] / max(spec["engine_steps"], 1)
    assert reduction >= 1.5, (
        f"same-params draft cut target dispatches only {reduction:.2f}x "
        f"({base['engine_steps']} -> {spec['engine_steps']} steps) — "
        "lookahead is not landing"
    )
    for m in out.values():
        del m["generated"]
    return {
        "spec_tokens": args.spec_tokens,
        "dispatch_reduction": reduction,
        "token_identical": True,  # asserted above, recorded for the seed
        **out,
    }


_SHARDED_PROBE_MARK = "SHARDED_PROBE_JSON "


def bench_sharded(args) -> dict:
    """Run the sharded probe, in-process when this process already holds
    enough devices (the CI sharded job forces 4 host devices), otherwise
    by re-execing this module with a forced 2-device host platform —
    XLA reads ``--xla_force_host_platform_device_count`` once at jaxlib
    import, so an already-initialized one-device process can never shard
    itself."""
    ndev = len(jax.devices())
    shards = args.shards or min(4, ndev)
    if shards >= 2:
        if ndev < shards:
            raise RuntimeError(
                f"sharded probe wants {shards} shards but only {ndev} "
                "device(s) are visible; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={shards}"
            )
        return _sharded_probe(args, shards)
    if args.sharded_probe:
        # we ARE the re-exec (or the CI probe entry) — if the forced
        # device count did not take, recursing would loop forever
        raise RuntimeError(
            "--sharded-probe needs >= 2 devices; XLA_FLAGS="
            "--xla_force_host_platform_device_count was not applied"
        )
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [
        sys.executable, "-m", "benchmarks.serve_bench", "--sharded-probe",
        "--shards", "2", "--arch", args.arch, "--slots", str(args.slots),
        "--requests", str(args.requests), "--burst", str(max(args.burst, 1)),
        "--gen", str(args.gen), "--page-size", str(args.page_size),
        "--seed", str(args.seed), "--prompt-lens",
        *map(str, args.prompt_lens),
    ]
    r = subprocess.run(
        cmd, capture_output=True, text=True, timeout=900, cwd=root,
        env={**os.environ,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
             "JAX_PLATFORMS": "cpu", "PYTHONPATH": "src:."},
    )
    for line in r.stdout.splitlines():
        if line.startswith(_SHARDED_PROBE_MARK):
            return json.loads(line[len(_SHARDED_PROBE_MARK):])
    raise RuntimeError(
        f"sharded probe subprocess failed (exit {r.returncode}):\n"
        f"{r.stderr[-2000:]}"
    )


def bench_burst(args) -> dict:
    """Burst arrivals through the engine: bucketed-batched vs. unbucketed-
    batched vs. per-request prefill.

    The load-bearing numbers: ``prefill_dispatches`` (one per admission
    round when batched — a burst of N costs 1 forward, not N),
    ``prefill_compiles`` (shape bucketing bounds jit specializations by the
    bucket ladder instead of the trace's shape diversity) and TTFT p50/p95
    (the per-request path serializes N prefills before the burst's last
    request sees its first token). With the default ``--burst-gap 0``
    everything arrives at t=0 and runs in virtual time — deterministic and
    CI-safe; a positive gap switches to realtime so arrival-relative TTFT
    stays meaningful."""
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = max(args.prompt_lens) + args.gen
    pages_per_ring = -(-max_seq // args.page_size)
    tight_pages = max(
        pages_per_ring + 1,
        int(args.slots * pages_per_ring * TIGHT_POOL_FRACTION),
    ) + 1
    out = {}
    for label, batched, bucketed, paged, tight in BURST_VARIANTS:
        engine = ServeEngine(
            model, params, num_slots=args.slots, max_seq=max_seq,
            window=args.window, use_kernel=args.use_kernel, prefill="chunked",
            batch_prefill=batched, bucket_prefill=bucketed,
            paged_cache=paged, page_size=args.page_size,
            num_pages=tight_pages if tight else 0,
        )
        reqs = burst_trace(
            cfg, n_requests=args.requests, burst_size=args.burst,
            gap=args.burst_gap, prompt_lens=tuple(args.prompt_lens),
            gen_tokens=args.gen, seed=args.seed,
        )
        # warm every shape a round can dispatch outside the measured window
        # (jit compilation is not a scheduling effect). Compile counters
        # intentionally KEEP the warm traces — total specializations is the
        # number bucketing bounds.
        engine.warm(args.prompt_lens)
        t0 = time.time()
        # gap 0 (default): virtual time, deterministic. gap > 0: honor
        # arrivals against the wall clock so TTFT-from-arrival stays
        # meaningful (virtual time would race ahead of future arrivals and
        # report negative TTFT).
        outs = engine.run(reqs, realtime=args.burst_gap > 0)
        wall = time.time() - t0
        total = sum(len(o.tokens) for o in outs)
        ttft = np.asarray([o.ttft for o in outs])
        lat = np.asarray([o.latency for o in outs])
        out[label] = {
            "prefill_dispatches": engine.prefill_dispatches,
            "prefill_compiles": engine.prefill_compiles,
            "compiles": engine.compiles,
            "engine_steps": engine.steps,
            "wall_seconds": wall,
            "tokens_per_second": total / max(wall, 1e-9),
            "latency_p50": float(np.percentile(lat, 50)),
            "latency_p95": float(np.percentile(lat, 95)),
            "ttft_p50": float(np.percentile(ttft, 50)),
            "ttft_p95": float(np.percentile(ttft, 95)),
            "pool": engine.pool_stats,
            "generated": [o.tokens for o in outs],
        }
    ref = out["batched"]["generated"]
    for label, m in out.items():
        # the paged-vs-ring probe: EVERY variant — paged, tight-pool paged
        # (preempting), and all three ring admissions — must emit the same
        # greedy tokens; memory layout and scheduling are invisible
        assert m["generated"] == ref, (
            f"{label} admission changed greedy output"
        )
        del m["generated"]
    assert (
        out["paged_tight"]["pool"]["preemptions"] > 0
        or out["paged_tight"]["pool"]["occupancy_max"] >= 0.5
    ), "tight pool exercised neither preemption nor high occupancy"
    assert (
        out["batched"]["prefill_compiles"]
        <= out["batched_unbucketed"]["prefill_compiles"]
    ), "bucketed engine must not compile more than the unbucketed one"
    return {
        "mode": "burst",
        "slots": args.slots,
        "requests": args.requests,
        "burst_size": args.burst,
        "burst_gap": args.burst_gap,
        "prompt_lens": list(args.prompt_lens),
        "gen_tokens": args.gen,
        "window": args.window,
        "decode_occupancy": bench_decode_occupancy(slots=args.slots),
        "shared_prefix": bench_shared_prefix(args),
        "kv_int8": bench_kv_int8(args),
        "tiered": bench_tiered(args),
        "sharded": bench_sharded(args),
        "router": bench_router(args),
        "spec": bench_spec(args),
        **out,
    }


def write_bench_seed(res: dict) -> None:
    """APPEND a timestamped entry to the perf trajectory at the repo root.

    The file is ``{"schema": 2, "entries": [...]}`` — one entry per
    ``--smoke`` run, oldest first, so the repo root carries the actual
    perf history PR over PR instead of a single overwritten snapshot. A
    legacy schema-1 file (one flat snapshot) is migrated in place: its
    snapshot becomes the first entry (timestamp null). Entries are flat so
    future PRs diff field-by-field."""
    b = res["batched"]
    pg = res["paged"]
    tight = res["paged_tight"]
    occ = res["decode_occupancy"]
    sp = res["shared_prefix"]
    sh = res["sharded"]
    rt = res["router"]
    k8 = res["kv_int8"]
    td = res["tiered"]
    sd = res["spec"]
    entry = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "mode": res["mode"],
        "slots": res["slots"],
        "requests": res["requests"],
        "prompt_lens": res["prompt_lens"],
        "gen_tokens": res["gen_tokens"],
        "tokens_per_second": b["tokens_per_second"],
        "tokens_per_second_paged": pg["tokens_per_second"],
        "latency_p50": b["latency_p50"],
        "latency_p95": b["latency_p95"],
        "ttft_p95": b["ttft_p95"],
        "prefill_dispatches": b["prefill_dispatches"],
        "prefill_dispatches_per_request": res["per_request"][
            "prefill_dispatches"
        ],
        "prefill_compiles": b["prefill_compiles"],
        "prefill_compiles_unbucketed": res["batched_unbucketed"][
            "prefill_compiles"
        ],
        "compiles": b["compiles"],
        "pool_occupancy_mean": pg["pool"]["occupancy_mean"],
        "pool_occupancy_max": pg["pool"]["occupancy_max"],
        "pool_preemptions": pg["pool"]["preemptions"],
        "pool_tight_occupancy_max": tight["pool"]["occupancy_max"],
        "pool_tight_preemptions": tight["pool"]["preemptions"],
        "decode_step_paged_low_us": occ["paged_low_us"],
        "decode_step_unpaged_low_us": occ["unpaged_low_us"],
        "decode_step_paged_full_us": occ["paged_full_us"],
        "decode_step_unpaged_full_us": occ["unpaged_full_us"],
        "decode_step_table_low_us": occ.get("table_low_us"),
        "decode_step_table_full_us": occ.get("table_full_us"),
        "prefix_hit_rate": sp["prefix_on"]["pool"]["prefix_hit_rate"],
        "prefix_prefill_saved_frac": sp["prefill_tokens_saved_frac"],
        "prefix_cow_copies": sp["prefix_on"]["pool"]["cow_copies"],
        "prefix_suffix_dispatches": sp["prefix_on"]["pool"][
            "suffix_dispatches"
        ],
        "prefix_cold_dispatches": sp["prefix_on"]["pool"]["cold_dispatches"],
        "suffix_round_s": sp["prefix_on"]["steady_round_seconds"],
        "cold_round_s": sp["prefix_off"]["steady_round_seconds"],
        "sharded_shards": sh["shards"],
        "tokens_per_second_sharded": sh["sharded"]["tokens_per_second"],
        "tokens_per_second_sharded_base": sh["unsharded"][
            "tokens_per_second"
        ],
        "sharded_occupancy_max": sh["sharded"]["occupancy_max"],
        "sharded_prefill_compiles": sh["sharded"]["prefill_compiles"],
        "router_replicas": rt["replicas"],
        "router_kill_step": rt["kill_step"],
        "router_token_identical": rt["token_identical"],
        "router_migrations": rt["greedy"]["migrations"],
        "router_migrated_requests": rt["greedy"]["migrated_requests"],
        "router_shed_requests": rt["greedy"]["shed_requests"],
        "router_retries": rt["greedy"]["retries"],
        "router_replica_occupancy": rt["greedy"]["occupancy"],
        "router_tokens_per_second": rt["greedy"]["tokens_per_second"],
        "router_tokens_per_second_sampled": rt["sampled"][
            "tokens_per_second"
        ],
        "kv_int8_resident_seqs": k8["resident_seqs_int8"],
        "kv_int8_resident_seqs_fp32": k8["resident_seqs_fp32"],
        "kv_int8_residency_ratio": k8["residency_ratio"],
        "kv_int8_pool_bytes": k8["pool_bytes_int8"],
        "kv_fp32_pool_bytes": k8["pool_bytes_fp32"],
        "kv_int8_token_agreement": k8["token_agreement"],
        "tiered_preemptions": td["swap"]["pool"]["preemptions"],
        "tiered_swapped_out_pages": td["swap"]["pool"]["swapped_out_pages"],
        "tiered_swapped_in_pages": td["swap"]["pool"]["swapped_in_pages"],
        "tiered_wall_swap_s": td["swap"]["wall_seconds"],
        "tiered_wall_recompute_s": td["recompute"]["wall_seconds"],
        "tiered_prefill_tokens_swap": td["swap"]["prefill_tokens"],
        "tiered_prefill_tokens_recompute": td["recompute"]["prefill_tokens"],
        "spec_tokens_k": sd["spec_tokens"],
        "spec_accept_rate": sd["spec"]["spec_accept_rate"],
        "spec_tok_s": sd["spec"]["tokens_per_second"],
        "spec_tok_s_base": sd["target_only"]["tokens_per_second"],
        "spec_dispatches_per_token": sd["spec"]["spec_dispatches_per_token"],
        "spec_dispatch_reduction": sd["dispatch_reduction"],
        "spec_accept_rate_foreign": sd["spec_foreign"]["spec_accept_rate"],
    }
    trajectory = {"schema": 2, "entries": []}
    if os.path.exists(BENCH_SEED_PATH):
        try:
            with open(BENCH_SEED_PATH) as f:
                prior = json.load(f)
        except (OSError, json.JSONDecodeError):
            prior = None
        if isinstance(prior, dict) and isinstance(prior.get("entries"), list):
            trajectory = prior
        elif isinstance(prior, dict):  # schema-1 single snapshot
            prior.setdefault("timestamp", None)
            trajectory["entries"].append(prior)
    trajectory["schema"] = 2
    trajectory["entries"].append(entry)
    with open(BENCH_SEED_PATH, "w") as f:
        json.dump(trajectory, f, indent=1)
        f.write("\n")


def bench_oracle(args) -> dict:
    """Same token budget as sequential fixed batches (batch = slots): the
    baseline a continuous engine replaces."""
    n_batches = (args.requests + args.slots - 1) // args.slots
    plen = max(args.prompt_lens)
    t0 = time.time()
    for b in range(n_batches):
        serve_batch(
            args.arch, batch=args.slots, prompt_len=plen, gen_tokens=args.gen,
            window=args.window, use_kernel=args.use_kernel,
            seed=args.seed + b, log_fn=lambda *_: None,
        )
    wall = time.time() - t0
    total = n_batches * args.slots * args.gen
    return {
        "mode": "oracle-batches",
        "wall_seconds": wall,
        "tokens_per_second": total / max(wall, 1e-9),
    }


def _parser():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate, requests/sec")
    ap.add_argument("--prompt-lens", type=int, nargs="+", default=[16, 32])
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--prefill", choices=("chunked", "interleaved"),
                    default="chunked")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--no-paged-cache", dest="paged_cache",
                    action="store_false",
                    help="[poisson] ring KV caches instead of the paged pool")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per physical KV page (paged variants)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="[poisson] pool pages (0 = ring-equivalent)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-oracle", action="store_true")
    ap.add_argument("--burst", type=int, default=0,
                    help="burst-arrival mode: simultaneous arrivals per "
                    "burst (0 = Poisson trace)")
    ap.add_argument("--burst-gap", type=float, default=0.0,
                    help="seconds between bursts (0 = all at t=0 in "
                    "virtual time; > 0 runs realtime, honoring arrivals)")
    ap.add_argument("--shards", type=int, default=0,
                    help="model-axis shards for the sharded probe (0 = "
                    "auto: min(4, visible devices), subprocess fallback "
                    "on a one-device host)")
    ap.add_argument("--sharded-probe", action="store_true",
                    help="run ONLY the sharded-vs-unsharded probe and "
                    "print its JSON (the CI sharded job entry point; also "
                    "used internally by the one-device re-exec fallback)")
    ap.add_argument("--router-probe", action="store_true",
                    help="run ONLY the fault-tolerant router probe (2 "
                    "replicas, one injected kill mid-decode; asserts zero "
                    "dropped requests and greedy+sampled token identity "
                    "vs. a fault-free engine) and print its JSON — the CI "
                    "chaos smoke job entry point")
    ap.add_argument("--tiered-probe", action="store_true",
                    help="run ONLY the tiered-KV probes (int8 page pool "
                    "residency at the fp32 byte budget; swap-vs-recompute "
                    "preemption resume — asserts swapped_in_pages > 0, "
                    "fewer prefill tokens, and token identity) and print "
                    "their JSON — the CI tiered smoke job entry point")
    ap.add_argument("--spec-probe", action="store_true",
                    help="run ONLY the speculative-decoding probe (same-"
                    "params draft for the deterministic ~100%% acceptance "
                    "upper bound; asserts greedy token identity and >= "
                    "1.5x fewer target dispatches) and print its JSON — "
                    "the CI spec smoke job entry point")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="[spec probe] draft lookahead tokens per slot "
                    "per round")
    ap.add_argument("--kill-step", type=int, default=3,
                    help="[router probe] kill replica 0 at its own step "
                    "number (default lands mid-decode for smoke sizes)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized burst run: 8 requests in bursts of 4 "
                    "through 4 slots, mixed prompt lengths; writes the "
                    "BENCH_serve.json perf-trajectory seed at the repo root")
    return ap


def run(argv: list[str] | None = None):
    """Entry point for benchmarks/run.py (and the CLI)."""
    args = _parser().parse_args(argv if argv is not None else [])
    if args.smoke:
        args.burst = args.burst or 4
        args.requests = min(args.requests, 8)
        # mixed lengths so admission rounds span several shapes — the
        # prefill_compiles contrast (bucketed vs. not) needs diversity
        args.prompt_lens = [5, 9, 16]
        args.gen = 8

    if args.sharded_probe:
        res = bench_sharded(args)
        print(_SHARDED_PROBE_MARK + json.dumps(res))
        return res

    if args.router_probe:
        res = bench_router(args)
        g = res["greedy"]
        emit(
            "serve_router_failover",
            g["migrated_requests"],
            f"2 replicas, kill@{res['kill_step']}: {g['completed']} reqs "
            f"completed, {g['migrations']} migration "
            f"({g['migrated_requests']} reqs moved), "
            f"{g['shed_requests']} shed — greedy+sampled tokens identical "
            "to fault-free engine",
        )
        print("ROUTER_PROBE_JSON " + json.dumps(res))
        return res

    if args.spec_probe:
        res = bench_spec(args)
        sp_ = res["spec"]
        emit(
            "serve_spec_decode",
            res["dispatch_reduction"],
            f"k={res['spec_tokens']} same-params draft: accept "
            f"{sp_['spec_accept_rate']:.0%}, "
            f"{sp_['spec_dispatches_per_token']:.2f} dispatch/tok, "
            f"{res['dispatch_reduction']:.1f}x fewer target dispatches "
            f"({res['target_only']['engine_steps']} -> "
            f"{sp_['engine_steps']} steps); foreign-draft accept "
            f"{res['spec_foreign']['spec_accept_rate']:.0%} — greedy "
            "tokens identical",
        )
        print("SPEC_PROBE_JSON " + json.dumps(res))
        return res

    if args.tiered_probe:
        res = {"kv_int8": bench_kv_int8(args), "tiered": bench_tiered(args)}
        k8, td = res["kv_int8"], res["tiered"]
        emit(
            "serve_kv_int8",
            k8["residency_ratio"],
            f"int8 pool at the fp32 byte budget: {k8['resident_seqs_int8']} "
            f"resident seqs vs {k8['resident_seqs_fp32']} fp32 "
            f"({k8['pool_bytes_int8']}B vs {k8['pool_bytes_fp32']}B), 0 "
            f"preempt, token agreement {k8['token_agreement']:.0%}",
        )
        emit(
            "serve_tiered_kv",
            td["swap"]["pool"]["swapped_in_pages"],
            f"tight pool {td['num_pages']} pages: swap resume "
            f"{td['swap']['wall_seconds']:.2f}s "
            f"({td['swap']['pool']['swapped_out_pages']}↓/"
            f"{td['swap']['pool']['swapped_in_pages']}↑ pages, "
            f"{td['swap']['prefill_tokens']} prefill tok) vs recompute "
            f"{td['recompute']['wall_seconds']:.2f}s "
            f"({td['recompute']['prefill_tokens']} prefill tok) — tokens "
            "identical",
        )
        print("TIERED_PROBE_JSON " + json.dumps(res))
        return res

    if args.burst > 0:
        res = bench_burst(args)
        b, u, p = res["batched"], res["batched_unbucketed"], res["per_request"]
        pg, tight = res["paged"], res["paged_tight"]
        occ = res["decode_occupancy"]
        emit(
            "serve_burst_prefill",
            1e6 * b["wall_seconds"] / max(b["engine_steps"], 1),
            f"dispatches {b['prefill_dispatches']} (batched) vs "
            f"{p['prefill_dispatches']} (per-request); compiles "
            f"{b['prefill_compiles']} (bucketed) vs {u['prefill_compiles']} "
            f"(unbucketed); ttft95 {b['ttft_p95']:.3f}s vs "
            f"{p['ttft_p95']:.3f}s",
        )
        emit(
            "serve_paged_pool",
            1e6 * pg["wall_seconds"] / max(pg["engine_steps"], 1),
            f"paged {pg['tokens_per_second']:.1f} tok/s occ "
            f"{pg['pool']['occupancy_max']:.0%} "
            f"{pg['pool']['preemptions']} preempt; tight pool "
            f"({tight['pool']['allocatable_pages']} pages) occ "
            f"{tight['pool']['occupancy_max']:.0%} "
            f"{tight['pool']['preemptions']} preempt — tokens identical "
            "to ring",
        )
        emit(
            "serve_decode_occupancy",
            occ["paged_low_us"],
            f"paged low-occ {occ['paged_low_us']:.0f}us vs unpaged "
            f"{occ['unpaged_low_us']:.0f}us; full-occ "
            f"{occ['paged_full_us']:.0f}us vs {occ['unpaged_full_us']:.0f}us",
        )
        sp = res["shared_prefix"]
        emit(
            "serve_shared_prefix",
            sp["prefix_on"]["prefill_tokens"],
            f"prefilled {sp['prefix_on']['prefill_tokens']} tok shared vs "
            f"{sp['prefix_off']['prefill_tokens']} unshared "
            f"({sp['prefill_tokens_saved_frac']:.0%} saved, hit rate "
            f"{sp['prefix_on']['pool']['prefix_hit_rate']:.0%}, "
            f"{sp['prefix_on']['pool']['cow_copies']} CoW, "
            f"{sp['prefix_on']['pool']['suffix_dispatches']} suffix / "
            f"{sp['prefix_on']['pool']['cold_dispatches']} cold rounds; "
            f"steady warm round {sp['prefix_on']['steady_round_seconds']:.2f}s"
            f" vs {sp['prefix_off']['steady_round_seconds']:.2f}s cold) — "
            "tokens identical",
        )
        k8 = res["kv_int8"]
        emit(
            "serve_kv_int8",
            k8["residency_ratio"],
            f"int8 pool at the fp32 byte budget: {k8['resident_seqs_int8']} "
            f"resident seqs vs {k8['resident_seqs_fp32']} fp32 "
            f"({k8['pool_bytes_int8']}B vs {k8['pool_bytes_fp32']}B), 0 "
            f"preempt, token agreement {k8['token_agreement']:.0%}",
        )
        td = res["tiered"]
        emit(
            "serve_tiered_kv",
            td["swap"]["pool"]["swapped_in_pages"],
            f"tight pool {td['num_pages']} pages: swap resume "
            f"{td['swap']['wall_seconds']:.2f}s "
            f"({td['swap']['pool']['swapped_out_pages']}↓/"
            f"{td['swap']['pool']['swapped_in_pages']}↑ pages, "
            f"{td['swap']['prefill_tokens']} prefill tok) vs recompute "
            f"{td['recompute']['wall_seconds']:.2f}s "
            f"({td['recompute']['prefill_tokens']} prefill tok) — tokens "
            "identical",
        )
        sh = res["sharded"]
        emit(
            "serve_sharded",
            1e6 * sh["sharded"]["wall_seconds"]
            / max(sh["sharded"]["engine_steps"], 1),
            f"{sh['shards']}-shard mesh {sh['sharded']['tokens_per_second']:.1f}"
            f" tok/s vs {sh['unsharded']['tokens_per_second']:.1f} unsharded; "
            f"per-shard occ {sh['sharded']['occupancy_max']:.0%}, "
            f"{sh['sharded']['prefill_compiles']} prefill compiles — "
            "tokens bitwise identical",
        )
        sd = res["spec"]
        emit(
            "serve_spec_decode",
            sd["dispatch_reduction"],
            f"k={sd['spec_tokens']} same-params draft: accept "
            f"{sd['spec']['spec_accept_rate']:.0%}, "
            f"{sd['spec']['spec_dispatches_per_token']:.2f} dispatch/tok, "
            f"{sd['dispatch_reduction']:.1f}x fewer target dispatches; "
            f"foreign-draft accept "
            f"{sd['spec_foreign']['spec_accept_rate']:.0%} — greedy "
            "tokens identical",
        )
        rt = res["router"]
        emit(
            "serve_router_failover",
            rt["greedy"]["migrated_requests"],
            f"2 replicas, kill@{rt['kill_step']}: "
            f"{rt['greedy']['completed']} reqs completed, "
            f"{rt['greedy']['migrations']} migration "
            f"({rt['greedy']['migrated_requests']} reqs moved), "
            f"{rt['greedy']['shed_requests']} shed, occ "
            f"{['%.0f%%' % (100 * o) for o in rt['greedy']['occupancy']]} — "
            "greedy+sampled tokens identical to fault-free engine",
        )
        save_results("serve_bench_burst", res)
        if args.smoke:
            write_bench_seed(res)
        return res

    res = bench_engine(args)
    emit(
        "serve_continuous",
        1e6 * res["wall_seconds"] / max(res["engine_steps"], 1),
        f"{res['tokens_per_second']:.1f} tok/s "
        f"p50 {res['latency_p50']:.3f}s p95 {res['latency_p95']:.3f}s "
        f"ttft50 {res['ttft_p50']:.3f}s",
    )
    payload = {"continuous": res}
    if not args.skip_oracle:
        ob = bench_oracle(args)
        emit(
            "serve_oracle_batches",
            1e6 * ob["wall_seconds"] / max(args.requests * args.gen, 1),
            f"{ob['tokens_per_second']:.1f} tok/s (sequential fixed batches)",
        )
        payload["oracle"] = ob
    save_results("serve_bench", payload)
    return payload


if __name__ == "__main__":
    import sys

    run(sys.argv[1:])
