"""§3.3 claim: asynchronous aggregation reduces wall-clock latency on
heterogeneous clouds while maintaining accuracy.

Two measurements:
  (a) scheduler simulation — wall time for 100 aggregation rounds, sync vs
      async, as the speed spread between clouds widens;
  (b) real smoke training — async vs sync final loss at matched wall-clock
      budget (modeled), confirming the "small accuracy cost" caveat."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_results
from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.core.scheduler import (
    CloudSpec,
    events_to_round_masks,
    simulate_async_schedule,
    sync_round_time,
)
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model

ROUNDS = 100
H = 4


def schedule_comparison() -> dict:
    rows = {}
    for spread in (1.0, 2.0, 4.0):
        clouds = [
            CloudSpec("slow", 1.0), CloudSpec("mid", (1 + spread) / 2),
            CloudSpec("fast", spread),
        ]
        sync_total = ROUNDS * sync_round_time(clouds, H, 1.0, sync_bytes=3.2e9)
        events = simulate_async_schedule(clouds, H, ROUNDS, sync_bytes=3.2e9)
        async_total = events[-1].time
        rows[f"spread_{spread}x"] = {
            "sync_seconds": sync_total,
            "async_seconds": async_total,
            "speedup": sync_total / async_total,
            "mean_staleness": float(np.mean([e.staleness for e in events])),
        }
        emit(
            f"async/spread_{spread}x",
            async_total / ROUNDS * 1e6,
            f"speedup={sync_total/async_total:.2f}x",
        )
    return rows


def accuracy_comparison() -> dict:
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.1)
    mix = dirichlet_mixtures(jax.random.PRNGKey(11), 3, 4, beta=0.3)
    clouds = [CloudSpec("a", 1.0), CloudSpec("b", 2.0), CloudSpec("c", 4.0)]
    steps = 80
    events = simulate_async_schedule(clouds, H, steps // H + 1)
    arrived, alphas = events_to_round_masks(events, 3, steps // H + 1)
    out = {}
    for aggregation in ("fedavg", "async"):
        fed = FederatedConfig(n_clouds=3, local_steps=H, aggregation=aggregation)
        trainer = FederatedTrainer(model, fed, TrainConfig(steps=steps, lr=3e-3, warmup_steps=8))
        state = trainer.init_state(jax.random.PRNGKey(0))
        step = jax.jit(trainer.train_step)
        losses = []
        for i in range(steps):
            batch = federated_batch(
                corpus, jax.random.fold_in(jax.random.PRNGKey(13), i), mix, 4, 32
            )
            rnd = i // H
            state, m = step(
                state, batch, jnp.asarray(arrived[rnd]), jnp.asarray(alphas[rnd])
            )
            losses.append(float(m["loss"]))
        out[aggregation] = float(np.mean(losses[-8:]))
        emit(f"async/final_loss_{aggregation}", 0.0, f"loss={out[aggregation]:.3f}")
    return out


def run() -> dict:
    rows = {"schedule": schedule_comparison(), "accuracy": accuracy_comparison()}
    save_results("async", rows)
    return rows


if __name__ == "__main__":
    run()
