"""§3.2 ablation: compression method × ratio → wire bytes, reconstruction
error, and convergence impact (short training runs with error feedback)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_results
from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.compression import Compressor
from repro.core.federated import FederatedTrainer
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model

STEPS = 60


def convergence_with(compression: str, ratio: float, seed=0) -> float:
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.1)
    mix = dirichlet_mixtures(jax.random.PRNGKey(3), 3, 4, beta=0.3)
    fed = FederatedConfig(
        n_clouds=3, local_steps=2, aggregation="fedavg",
        compression=compression, topk_ratio=ratio,
    )
    trainer = FederatedTrainer(model, fed, TrainConfig(steps=STEPS, lr=3e-3, warmup_steps=5))
    state = trainer.init_state(jax.random.PRNGKey(seed))
    step = jax.jit(trainer.train_step)
    losses = []
    for i in range(STEPS):
        batch = federated_batch(
            corpus, jax.random.fold_in(jax.random.PRNGKey(seed + 7), i), mix, 4, 32
        )
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return float(np.mean(losses[-8:]))


def run() -> dict:
    rows = {}
    key = jax.random.PRNGKey(0)
    grad_like = {"w": jax.random.normal(key, (1 << 18,)) * 0.01}

    settings = [
        ("none", 1.0), ("int8", 1.0),
        ("topk", 0.10), ("topk", 0.01), ("topk+int8", 0.01),
    ]
    for method, ratio in settings:
        comp = Compressor(method, topk_ratio=ratio)
        recon = comp.roundtrip(grad_like)["w"]
        err = float(
            jnp.linalg.norm(recon - grad_like["w"]) / jnp.linalg.norm(grad_like["w"])
        )
        cr = comp.compression_ratio(grad_like)
        final_loss = convergence_with(method, ratio)
        name = f"{method}@{ratio}" if "topk" in method else method
        rows[name] = {
            "compression_ratio": cr,
            "recon_rel_error": err,
            "final_loss": final_loss,
        }
        emit(f"compression/{name}", 0.0,
             f"ratio={cr:.1f}x;err={err:.3f};loss={final_loss:.3f}")
    save_results("compression", rows)
    return rows


if __name__ == "__main__":
    run()
