"""Paper Table 1 row "Data Partitioning Strategy: Fixed vs Dynamic".

Simulates heterogeneous clouds (speeds 1×/2×/4×, plus a mid-run slowdown on
cloud 2 — the paper's "real-time monitoring and adjustment" scenario) and
compares synchronous-round latency and utilization under fixed, weighted,
and dynamic partitioning, sweeping the granularity knob."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_results
from repro.core.partition import Partitioner

GLOBAL_BATCH = 128
ROUNDS = 60


def simulate(strategy: str, granule: int = 1) -> dict:
    speeds = np.asarray([1.0, 2.0, 4.0])
    p = Partitioner(strategy=strategy, n_clouds=3, granule=granule)
    state = p.init(nominal_throughput=[1.0, 1.0, 1.0])  # mis-provisioned
    total_time = 0.0
    utils = []
    for r in range(ROUNDS):
        if r == ROUNDS // 2:
            speeds = np.asarray([1.0, 0.5, 4.0])  # cloud 1 degrades mid-run
        sizes = p.quantize(state, GLOBAL_BATCH)
        t = Partitioner.round_time(sizes, speeds)
        total_time += t
        utils.append(Partitioner.utilization(sizes, speeds))
        state = p.observe(state, sizes, sizes / speeds)
    return {
        "total_time": total_time,
        "mean_utilization": float(np.mean(utils)),
        "final_shares": state.shares.tolist(),
        "granule": granule,
    }


def run() -> dict:
    rows = {}
    for strategy in ("fixed", "weighted", "dynamic"):
        r = simulate(strategy)
        rows[strategy] = r
        emit(
            f"partitioning/{strategy}",
            r["total_time"] / ROUNDS * 1e6,
            f"util={r['mean_utilization']:.2f};time={r['total_time']:.1f}",
        )
    # granularity sweep (paper §3.1: "finding the right partition size")
    for granule in (1, 4, 16, 64):
        r = simulate("dynamic", granule)
        rows[f"dynamic_g{granule}"] = r
        emit(
            f"partitioning/granule_{granule}",
            r["total_time"] / ROUNDS * 1e6,
            f"util={r['mean_utilization']:.2f}",
        )
    save_results("partitioning", rows)
    return rows


if __name__ == "__main__":
    run()
